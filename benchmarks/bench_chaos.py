"""Chaos benchmark — seeded fault injection with zero cross-tenant blast
radius.

The fault-domain hypervisor's contract has three legs, and this bench
scores all of them against the same seeded workload:

* **Leg A (pool chaos, sim)** — three open-loop tenants on a 16-core pool
  replay the identical seeded Poisson trace with and without a seeded
  :class:`~repro.core.faults.FaultInjector` (core deaths + slow cores).
  Scored on **goodput retention** (chaos served / fault-free served),
  **recovery latency** (from the hypervisor's ``recovery_log``) and
  **determinism** (two chaos runs with the same seeds are identical —
  same fault schedule, same per-tenant service).
* **Leg B (serving chaos, jax)** — two tenant groups share a paged
  continuous batcher; KV-page corruption and a wedged chunk are injected
  into tenant A's slots only.  Tenant B's token streams must be
  **byte-identical** to a fault-free run (zero divergence outside the
  fault domain) while tenant A recovers to full completion with its
  pre-fault tokens preserved.

Acceptance (recorded in ``BENCH_chaos.json`` and gated by
``benchmarks/check_regression.py``):

* ``acceptance_goodput``      — Leg A retention >= 0.7,
* ``acceptance_recovery``     — every displaced tenant re-placed by the
  horizon, and tenant A completes with tokens preserved,
* ``acceptance_isolation``    — tenant B token-identical under chaos,
* ``acceptance_determinism``  — same seeds => identical fault schedule,
  service counts and token streams across two runs.

    PYTHONPATH=src python -m benchmarks.run chaos

``BENCH_CHAOS_SMOKE=1`` shortens the sim horizon and the decode lengths
(the CI smoke job).
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.core import (
    FaultInjector,
    Hypervisor,
    PoissonTraffic,
    ResourcePool,
    TenantSpec,
    VirtualEngine,
    fpga_small_core,
)

from .common import OUT_DIR, static_artifact, write_csv

POOL = 16
SMOKE = bool(int(os.environ.get("BENCH_CHAOS_SMOKE", "0")))
HORIZON = 10.0 if SMOKE else 30.0
FAULT_SEED = 1337
#: faults stop this long before the horizon so every repair + re-placement
#: lands inside the measured window
FAULT_TAIL = 3.0

#: tenant, model, priority, arrival, request rate, traffic seed
TENANTS = (
    ("gold",   "resnet50",  2.0, 0.0, 10.0, 11),
    ("silver", "mobilenet", 2.0, 0.0, 14.0, 22),
    ("bronze", "vgg16",     1.0, 0.0,  2.0, 33),
)


# ---------------------------------------------------------------------------
# Leg A — pool chaos over the seeded hypervisor sim
# ---------------------------------------------------------------------------

def _run_pool(inject_faults: bool) -> Dict:
    pool = ResourcePool(POOL)
    engine = VirtualEngine(pool, fpga_small_core(), straggler_threshold=1.3)
    hv = Hypervisor(pool, policy="even_split", executor=engine,
                    probe_interval=0.1)
    records = []
    for name, cnn, prio, t_on, rate, seed in TENANTS:
        spec = TenantSpec(name, requested_cores=POOL, min_cores=1,
                          priority=prio, artifact=static_artifact(cnn),
                          open_loop=True, arrival_rate=rate)
        hv.schedule_arrival(spec, at=t_on)
        records.extend(hv.open_traffic(
            name, PoissonTraffic(rate, seed=seed, start=t_on), HORIZON))
    faults = []
    if inject_faults:
        inj = FaultInjector(POOL, seed=FAULT_SEED, death_rate=0.3,
                            slow_rate=0.2, repair_after=1.5)
        faults = inj.inject(hv.queue, HORIZON - FAULT_TAIL)
    hv.run(HORIZON)

    served = {}
    for name, *_ in TENANTS:
        mine = [r for r in records if r.tenant == name]
        served[name] = sum(1 for r in mine if r.t_complete is not None)
    rec_lat = [r["recovery_latency"] for r in hv.recovery_log]
    return {
        "served": served,
        "served_total": sum(served.values()),
        "faults": [(f.fid, f.kind.value, round(f.time, 9), f.core)
                   for f in faults],
        "n_faults": len(faults),
        "displacements": len(hv.recovery_log) + len(hv._displaced_at),
        "recoveries": len(hv.recovery_log),
        "unrecovered": len(hv._displaced_at),
        "recovery_latency_mean": (round(float(np.mean(rec_lat)), 6)
                                  if rec_lat else 0.0),
        "recovery_latency_max": (round(float(np.max(rec_lat)), 6)
                                 if rec_lat else 0.0),
    }


def _leg_a() -> List[Dict]:
    base = _run_pool(inject_faults=False)
    chaos = _run_pool(inject_faults=True)
    rerun = _run_pool(inject_faults=True)

    retention = chaos["served_total"] / max(base["served_total"], 1)
    deterministic = (
        chaos["faults"] == rerun["faults"]
        and chaos["served"] == rerun["served"]
        and chaos["recoveries"] == rerun["recoveries"]
    )
    rows = []
    for mode, res in (("fault_free", base), ("chaos", chaos)):
        rows.append({
            "bench": "chaos", "leg": "pool", "mode": mode,
            "horizon_s": HORIZON,
            "served_total": res["served_total"],
            **{f"served_{t}": n for t, n in res["served"].items()},
            "n_faults": res["n_faults"],
            "displacements": res["displacements"],
            "recoveries": res["recoveries"],
            "unrecovered": res["unrecovered"],
            "recovery_latency_mean_s": res["recovery_latency_mean"],
            "recovery_latency_max_s": res["recovery_latency_max"],
            "goodput_retention": round(retention, 4) if mode == "chaos"
            else 1.0,
            "deterministic": deterministic,
        })
    return rows


# ---------------------------------------------------------------------------
# Leg B — serving chaos: corruption + stall in one tenant's slots only
# ---------------------------------------------------------------------------

MAX_NEW = 8 if SMOKE else 12
N_PER_TENANT = 4


def _requests(cfg):
    from repro.serving.batcher import Request
    rng = np.random.default_rng(5)
    # tenant A = rids 0..3 (submitted first -> first four slots),
    # tenant B = rids 4..7
    # short prompts: prompt + pre-fault output must fit the 8-token prompt
    # bucket so the requeue path KEEPS the already-emitted tokens
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab, size=2)
                    .astype(np.int32),
                    max_new=MAX_NEW)
            for i in range(2 * N_PER_TENANT)]


def _run_serving(qwen, inject: bool) -> Dict:
    from repro.serving import ServingConfig
    from repro.serving.batcher import ContinuousBatcher
    cfg, params = qwen
    b = ContinuousBatcher(
        params, cfg,
        ServingConfig(slots=4, prompt_len=8, max_len=64, chunk=2,
                      paged=True, page_size=8, watchdog_s=0.5, audit=True),
        clock=lambda: 0.0)
    for r in _requests(cfg):
        b.submit(r)
    outs: Dict[int, List[int]] = {}
    reqs = {r.rid: r for r in list(b.queue)}
    steps = 0
    while (any(b.slot_req) or b.queue) and steps < 4000:
        b.step()
        steps += 1
        if inject and steps == 1:
            # both faults target tenant-A slots only (rids 0..3)
            victims = [i for i, r in enumerate(b.slot_req)
                       if r is not None and r.rid < N_PER_TENANT]
            if victims:
                b.inject_kv_corruption(victims[0])
            if len(victims) > 1:
                b.inject_stall(victims[1], 1.0)
    for rid, r in reqs.items():
        outs[rid] = list(r.out)
    return {
        "outs": outs,
        "poisoned": b.stats.poisoned_slots,
        "watchdog_trips": b.stats.watchdog_trips,
        "audit_repairs": b.stats.audit_repairs,
        "quarantined": b.stats.quarantined_pages,
        "tokens_kept": b.stats.resumed_tokens_kept,
    }


def _leg_b() -> List[Dict]:
    from repro.configs import get_reduced
    from repro.models import init_params
    import jax

    cfg = get_reduced("qwen3-0.6b")
    qwen = (cfg, init_params(cfg, jax.random.PRNGKey(0)))

    clean = _run_serving(qwen, inject=False)
    chaos = _run_serving(qwen, inject=True)
    rerun = _run_serving(qwen, inject=True)

    b_rids = range(N_PER_TENANT, 2 * N_PER_TENANT)
    a_rids = range(N_PER_TENANT)
    isolation = all(chaos["outs"][i] == clean["outs"][i] for i in b_rids)
    recovered = (
        all(len(chaos["outs"][i]) == MAX_NEW for i in a_rids)
        and chaos["tokens_kept"] > 0
    )
    deterministic = chaos["outs"] == rerun["outs"]
    faults_fired = (chaos["audit_repairs"] >= 1
                    and chaos["watchdog_trips"] >= 1)
    rows = []
    for mode, res in (("fault_free", clean), ("chaos", chaos)):
        rows.append({
            "bench": "chaos", "leg": "serving", "mode": mode,
            "requests": 2 * N_PER_TENANT,
            "max_new": MAX_NEW,
            "completed": sum(1 for o in res["outs"].values()
                             if len(o) == MAX_NEW),
            "poisoned_slots": res["poisoned"],
            "watchdog_trips": res["watchdog_trips"],
            "audit_repairs": res["audit_repairs"],
            "quarantined_pages": res["quarantined"],
            "tokens_kept": res["tokens_kept"],
            "tenant_b_token_identical": isolation,
            "tenant_a_recovered": recovered,
            "faults_fired": faults_fired if mode == "chaos" else False,
            "deterministic": deterministic,
        })
    return rows


# ---------------------------------------------------------------------------


def run() -> List[Dict]:
    return _leg_a() + _leg_b()


def main() -> None:
    rows = run()
    path = write_csv("chaos", rows)

    for r in rows:
        if r["leg"] == "pool":
            print(f"pool    {r['mode']:>10}: served={r['served_total']} "
                  f"faults={r['n_faults']} recoveries={r['recoveries']} "
                  f"retention={r['goodput_retention']} "
                  f"rec_lat_mean={r['recovery_latency_mean_s']}s")
        else:
            print(f"serving {r['mode']:>10}: completed={r['completed']} "
                  f"audit={r['audit_repairs']} wdog={r['watchdog_trips']} "
                  f"B_identical={r['tenant_b_token_identical']} "
                  f"A_recovered={r['tenant_a_recovered']}")

    pool_chaos = next(r for r in rows
                      if r["leg"] == "pool" and r["mode"] == "chaos")
    srv_chaos = next(r for r in rows
                     if r["leg"] == "serving" and r["mode"] == "chaos")
    acceptance = {
        "acceptance_goodput": pool_chaos["goodput_retention"] >= 0.7,
        "acceptance_recovery": (pool_chaos["unrecovered"] == 0
                                and pool_chaos["recoveries"] > 0
                                and srv_chaos["tenant_a_recovered"]),
        "acceptance_isolation": (srv_chaos["tenant_b_token_identical"]
                                 and srv_chaos["faults_fired"]),
        "acceptance_determinism": (pool_chaos["deterministic"]
                                   and srv_chaos["deterministic"]),
    }
    snap = {
        "bench": "chaos",
        "unix_time": time.time(),
        "horizon_s": HORIZON,
        "fault_seed": FAULT_SEED,
        **acceptance,
        "rows": rows,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    jpath = os.path.join(OUT_DIR, "BENCH_chaos.json")
    with open(jpath, "w") as f:
        json.dump(snap, f, indent=2)
    print(f"wrote {path} and {jpath}")
    failed = [k for k, v in acceptance.items() if not v]
    assert not failed, f"chaos acceptance failed: {failed}"
    print("acceptance OK: goodput retained under chaos, every displaced "
          "tenant recovered, zero token divergence outside the fault "
          "domain, and the seeded schedule replays identically")


if __name__ == "__main__":
    main()
