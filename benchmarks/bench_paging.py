"""Paged KV pool benchmark — effective slot capacity at equal HBM.

The dense serving path must size every slot's ring buffer at ``max_len``
(the tenant contract: any request may run that long), so HBM caps slot
count at ``HBM / (max_len x bytes_per_token)``.  The paged pool spends the
same bytes on fixed-size pages and reserves only each request's *actual*
footprint (bucketed prompt + decode budget), so the same HBM hosts more
concurrent requests — the cache analogue of the paper's tiling-based
resource virtualization.

Three measured modes on the reduced qwen3-0.6b decode path:

* ``dense``            — the ring-buffer baseline at ``SLOTS`` slots;
* ``paged_equal_slots``— same slot count, pool sized to the same HBM: the
  tokens/s cost of gather/scatter paged attention (acceptance: within 15%
  of dense);
* ``paged_equal_hbm``  — same HBM, slot count raised to what reservations
  admit: effective capacity (measured as peak concurrently-resident
  requests; acceptance: >= 1.5x the dense slot count) and the throughput
  that extra concurrency buys;
* ``paged_pallas``     — paged_equal_slots with ``attn_impl="pallas"``:
  the in-kernel page-table walk vs the materialized gather.  The
  ``kernel_tokens_ratio`` (pallas / xla tokens/s) is gated >= 1.0 only
  when the kernel ran **compiled** (on TPU); in interpret mode (CPU CI)
  the ratio is recorded with ``"interpret": true`` and the gate is
  skipped — interpret-mode throughput measures the emulator, not the
  kernel.

Emits ``experiments/bench/paging.csv`` + ``BENCH_paging.json`` (gated by
``benchmarks/check_regression.py`` in the CI bench-smoke job).

    PYTHONPATH=src python -m benchmarks.run paging
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import OUT_DIR, write_csv

ARCH = "qwen3-0.6b"
SLOTS = 4                  # dense baseline slot count
PROMPT_LEN = 8
MAX_NEW = 16               # actual per-request budget << MAX_LEN
MAX_LEN = 64               # the per-request contract dense must provision
PAGE_SIZE = 8
N_REQUESTS = 24
CHUNK = 8

CAPACITY_FLOOR = 1.5       # paged capacity >= 1.5x dense at equal HBM
TOKENS_RATIO_FLOOR = 0.85  # paged tokens/s within 15% of dense
KERNEL_RATIO_FLOOR = 1.0   # compiled pallas never slower than the gather


def _requests(cfg, n: int):
    from repro.serving.batcher import Request

    rng = np.random.default_rng(0)
    return [
        Request(rid=i,
                prompt=rng.integers(1, cfg.vocab, size=2 + i % (PROMPT_LEN - 2)
                                    ).astype(np.int32),
                max_new=MAX_NEW)
        for i in range(n)
    ]


def _equal_hbm_pages(cfg) -> int:
    """Largest page pool whose bytes fit the dense baseline's cache tree."""
    from repro.serving.kv_cache import kv_cache_bytes, paged_kv_cache_bytes

    dense = kv_cache_bytes(cfg, SLOTS, MAX_LEN)
    n = 1
    while paged_kv_cache_bytes(cfg, n + 1, PAGE_SIZE) <= dense:
        n += 1
    return n


def _bench(params, cfg, *, paged: bool, slots: int, n_pages=None,
           attn_impl: str = "xla") -> Dict:
    import jax

    from repro.serving import ServingConfig
    from repro.serving.batcher import ContinuousBatcher
    from repro.serving.kv_cache import tree_bytes

    def batcher():
        kw = dict(slots=slots, prompt_len=PROMPT_LEN, max_len=MAX_LEN,
                  chunk=CHUNK, attn_impl=attn_impl)
        if paged:
            kw.update(paged=True, page_size=PAGE_SIZE, n_pages=n_pages)
        return ContinuousBatcher(params, cfg, ServingConfig(**kw))

    warm = batcher()                       # compile outside the timed region
    for r in _requests(cfg, slots + 1):
        warm.submit(r)
    warm.run(max_steps=2000)

    b = batcher()
    for r in _requests(cfg, N_REQUESTS):
        b.submit(r)
    t0 = time.perf_counter()
    stats = b.run(max_steps=20_000)
    jax.block_until_ready(b.caches)
    dt = time.perf_counter() - t0

    from repro.kernels.common import default_interpret

    row = {
        "arch": cfg.name,
        "mode": ("paged" if paged else "dense"),
        "attn_impl": attn_impl,
        # interpret-mode pallas measures the CPU emulator, not the kernel;
        # check_regression skips the kernel floor when this is set
        "interpret": bool(attn_impl == "pallas" and default_interpret()),
        "slots": slots,
        "requests": N_REQUESTS,
        "completed": stats.completed,
        "tokens": stats.tokens,
        "seconds": round(dt, 4),
        "tokens_per_s": round(stats.tokens / dt, 2),
        "cache_mb": round(tree_bytes(b.caches) / 2**20, 3),
        "dispatches_per_token": round(stats.dispatches_per_token, 4),
        "syncs_per_token": round(stats.syncs_per_token, 4),
        "occupancy": round(stats.occupancy, 4),
        "peak_resident": (stats.peak_resident if paged else slots),
        "n_pages": (b.n_pages if paged else 0),
        "peak_pages_in_use": (stats.peak_pages_in_use if paged else 0),
        "oom_requeues": (stats.oom_requeues if paged else 0),
    }
    assert stats.completed == N_REQUESTS, row
    return row


def run() -> List[Dict]:
    import jax

    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving.kv_cache import pages_for

    cfg = get_reduced(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pool_pages = _equal_hbm_pages(cfg)
    # how many concurrent worst-case reservations the equal-HBM pool admits
    capacity = pool_pages // pages_for(PROMPT_LEN + MAX_NEW, PAGE_SIZE)

    dense = _bench(params, cfg, paged=False, slots=SLOTS)
    equal_slots = _bench(params, cfg, paged=True, slots=SLOTS,
                         n_pages=pool_pages)
    equal_hbm = _bench(params, cfg, paged=True, slots=capacity,
                       n_pages=pool_pages)
    pallas = _bench(params, cfg, paged=True, slots=SLOTS,
                    n_pages=pool_pages, attn_impl="pallas")
    dense["mode"] = "dense"
    equal_slots["mode"] = "paged_equal_slots"
    equal_hbm["mode"] = "paged_equal_hbm"
    pallas["mode"] = "paged_pallas"
    rows = [dense, equal_slots, equal_hbm, pallas]
    for r in rows:
        r["tokens_ratio_vs_dense"] = round(
            r["tokens_per_s"] / max(dense["tokens_per_s"], 1e-9), 3)
        r["capacity_ratio_vs_dense"] = round(
            r["peak_resident"] / max(SLOTS, 1), 3)
        # the kernel leg's contract: pallas tokens/s vs the XLA gather leg
        # at identical slots/pool
        r["kernel_tokens_ratio"] = round(
            r["tokens_per_s"] / max(equal_slots["tokens_per_s"], 1e-9), 3)
    return rows


def main() -> None:
    rows = run()
    path = write_csv("paging", rows)
    by_mode = {r["mode"]: r for r in rows}
    dense = by_mode["dense"]
    eq_slots = by_mode["paged_equal_slots"]
    eq_hbm = by_mode["paged_equal_hbm"]
    pallas = by_mode["paged_pallas"]
    capacity_ratio = eq_hbm["capacity_ratio_vs_dense"]
    tokens_ratio = eq_slots["tokens_ratio_vs_dense"]
    kernel_ratio = pallas["kernel_tokens_ratio"]
    kernel_gated = not pallas["interpret"]
    snap = {
        "bench": "paging",
        "arch": ARCH,
        "unix_time": time.time(),
        "page_size": PAGE_SIZE,
        "max_len": MAX_LEN,
        "dense_slots": SLOTS,
        "capacity_ratio": capacity_ratio,
        "tokens_ratio": tokens_ratio,
        "kernel_tokens_ratio": kernel_ratio,
        "kernel_interpret": pallas["interpret"],
        "capacity_floor": CAPACITY_FLOOR,
        "tokens_ratio_floor": TOKENS_RATIO_FLOOR,
        "kernel_ratio_floor": KERNEL_RATIO_FLOOR,
        "acceptance_capacity": capacity_ratio >= CAPACITY_FLOOR,
        "acceptance_tokens": tokens_ratio >= TOKENS_RATIO_FLOOR,
        "acceptance_kernel": (not kernel_gated
                              or kernel_ratio >= KERNEL_RATIO_FLOOR),
        "rows": rows,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    jpath = os.path.join(OUT_DIR, "BENCH_paging.json")
    with open(jpath, "w") as f:
        json.dump(snap, f, indent=2)
    print(f"{'mode':>18} {'slots':>6} {'cache MB':>9} {'tok/s':>8} "
          f"{'vs dense':>9} {'peak res':>9} {'oom':>4}")
    for r in rows:
        print(f"{r['mode']:>18} {r['slots']:>6} {r['cache_mb']:>9} "
              f"{r['tokens_per_s']:>8} {r['tokens_ratio_vs_dense']:>9} "
              f"{r['peak_resident']:>9} {r['oom_requeues']:>4}")
    # acceptance: >=1.5x effective slots at equal HBM bytes, equal-slot
    # tokens/s within 15% of dense, compiled kernel never slower than the
    # gather leg
    assert eq_hbm["cache_mb"] <= dense["cache_mb"] + 1e-6, \
        "equal-HBM run used more cache bytes than dense"
    assert capacity_ratio >= CAPACITY_FLOOR, snap
    assert tokens_ratio >= TOKENS_RATIO_FLOOR, snap
    if kernel_gated:
        assert kernel_ratio >= KERNEL_RATIO_FLOOR, snap
    print(f"capacity x{capacity_ratio} at equal HBM "
          f"(floor {CAPACITY_FLOOR}), equal-slot tokens/s ratio "
          f"{tokens_ratio} (floor {TOKENS_RATIO_FLOOR}), kernel ratio "
          f"{kernel_ratio}"
          + ("" if kernel_gated else " (interpret mode — ungated)"))
    print(f"wrote {path} and {jpath}")


if __name__ == "__main__":
    main()
