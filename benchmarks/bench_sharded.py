"""Tensor-sharded decode scaling on an emulated 8-device pool.

Two legs, both under ``--xla_force_host_platform_device_count=8`` (the
module re-execs itself into a subprocess with that flag when the current
process initialized jax with fewer devices — the flag only takes effect
before backend init):

**TP scaling at an equal per-device KV budget.**  Each device can hold
``BASE_SLOTS`` slots' worth of KV, so a ``tp``-wide lease serves
``tp * BASE_SLOTS`` concurrent streams at the same bytes per device —
that is what an elastic resize buys.  The leg drives the *same* request
trace through tp ∈ {1, 2, 4} on a **large config** (4 layers, d_model
256 — per-step compute big enough that the fixed per-step dispatch
overhead, not the shard math, is what the extra slots amortize): the
narrow lease must drain the trace in ``tp``× more admission waves with
``tp``× fewer streams resident.  On a real multi-device host the wide
lease also parallelizes the math; on a 1-core CI host the win is pure
per-dispatch amortization over more resident rows — the measured
``tp=2 ≥ 1.15x tp=1`` tokens/s floor holds either way and is owned by
``check_regression.py`` (asserted here at generation time too).  The
chunk discipline (≤1 dispatch, ≤1 blocking sync per chunk) is asserted
at every width.

**Mixed-width packing.**  A :class:`VirtualAcceleratorPool` over all 8
devices leases 4 cores to one wide (tp=4) long-resident batch tenant and
1 core each to four narrow (tp=1) tenants running short interactive
decodes (disjoint device sets via ``tp_mesh_for``), then serves one
fixed mixed workload two ways: **exclusive** (tenants
time-share — each runs to completion alone, the pre-virtualization
baseline) vs **packed** (all five co-resident, round-robin).  Packing
must not cost pool throughput (``PACKING_TOKENS_RATIO_FLOOR``, ~parity
on a serial host; a real pool gains device parallelism on top) and must
cut mean tenant turnaround (``PACKING_TURNAROUND_RATIO_FLOOR`` — narrow
tenants stop waiting behind the wide one).  Both ratios are same-host
same-run, so they gate exactly.

Emits ``experiments/bench/sharded.csv`` + ``BENCH_sharded.json``.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.run sharded
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import OUT_DIR, write_csv

ARCH = "qwen3-0.6b"
PROMPT_LEN = 8
CHUNK = 8
BASE_SLOTS = 4                  # per-device slot budget; slots = tp * this
TPS = (1, 2, 4)

SMOKE = bool(os.environ.get("BENCH_SHARDED_SMOKE"))
MAX_NEW = 12 if SMOKE else 24
N_REQUESTS = 16                 # fixed trace across widths (4 tp=1 waves)
NARROW_REQUESTS = 4             # per narrow tenant in the packing leg
NARROW_MAX_NEW = CHUNK          # narrows are short interactive decodes
WIDE_REQUESTS = 64              # long-resident batch tenant (4 waves)
REPS = 2 if SMOKE else 3

# Floors are owned by check_regression.py; asserted here at generation
# time too so a bad snapshot can never be committed.  All three ratios
# are same-host same-run comparisons, so they gate exactly (host speed
# cancels).  Reference container: tp2 ~1.5x, packing ~0.91x / ~1.4x.
SHARDED_TP2_RATIO_FLOOR = 1.15
PACKING_TOKENS_RATIO_FLOOR = 0.85
PACKING_TURNAROUND_RATIO_FLOOR = 1.2


def _large_cfg():
    """The large-config leg: deep/wide enough that per-step compute
    dominates trace constants, and 4 KV heads so tp=4 divides them."""
    import dataclasses

    from repro.configs import get_reduced

    return dataclasses.replace(
        get_reduced(ARCH), n_layers=4, d_model=256, d_ff=768,
        n_heads=8, n_kv_heads=4, d_head=32)


def _requests(cfg, n: int, *, rid0: int = 0, max_new: int = MAX_NEW):
    from repro.serving.batcher import Request

    rng = np.random.default_rng(0)
    return [
        Request(rid=rid0 + i,
                prompt=rng.integers(1, cfg.vocab,
                                    size=2 + i % (PROMPT_LEN - 2)
                                    ).astype(np.int32),
                max_new=max_new)
        for i in range(n)
    ]


def _config(tp: int):
    from repro.serving import ServingConfig

    return ServingConfig(
        slots=BASE_SLOTS * tp, prompt_len=PROMPT_LEN,
        max_len=PROMPT_LEN + MAX_NEW + 2, chunk=CHUNK, tp=tp,
    )


def bench_tp(params, cfg, tp: int) -> Dict:
    """Best-of-REPS tokens/s draining the fixed trace at one TP width
    (equal per-device KV budget: slots = BASE_SLOTS * tp)."""
    import jax

    from repro.serving.batcher import ContinuousBatcher

    sc = _config(tp)

    def one_run():
        b = ContinuousBatcher(params, cfg, sc)
        for r in _requests(cfg, N_REQUESTS):
            b.submit(r)
        t0 = time.perf_counter()
        stats = b.run(max_steps=1_000_000)
        jax.block_until_ready(b.caches)
        return stats, time.perf_counter() - t0

    one_run()                                   # warmup / compile
    best, stats = 0.0, None
    for _ in range(REPS):
        st, dt = one_run()
        rate = st.tokens / dt
        if rate > best:
            best, stats = rate, st
    return {
        "arch": cfg.name,
        "mode": f"tp{tp}",
        "tp": tp,
        "slots": sc.slots,
        "requests": N_REQUESTS,
        "completed": stats.completed,
        "tokens": stats.tokens,
        "tokens_per_s": round(best, 2),
        "dispatches_per_token": round(stats.dispatches_per_token, 4),
        "syncs_per_token": round(stats.syncs_per_token, 4),
        "decode_dispatches_per_token": round(
            stats.decode_dispatches_per_token, 4),
        "occupancy": round(stats.occupancy, 4),
    }


def bench_packing(params, cfg) -> List[Dict]:
    """One mixed workload (1 wide + 4 narrow tenants on disjoint leases),
    served exclusively (time-shared) vs packed (co-resident)."""
    import jax

    from repro.serving.batcher import ContinuousBatcher
    from repro.serving.tenancy import VirtualAcceleratorPool

    def make_tenants():
        vpool = VirtualAcceleratorPool(devices=jax.devices()[:8],
                                       devices_per_core=1)
        wide = ContinuousBatcher(
            params, cfg, _config(4),
            mesh=vpool.tp_mesh_for(vpool.lease("wide", 4)))
        narrows = [
            ContinuousBatcher(
                params, cfg, _config(1),
                mesh=vpool.tp_mesh_for(vpool.lease(f"narrow{i}", 1)))
            for i in range(4)
        ]
        for r in _requests(cfg, WIDE_REQUESTS):
            wide.submit(r)
        for i, nb in enumerate(narrows):
            for r in _requests(cfg, NARROW_REQUESTS, rid0=100 * (i + 1),
                               max_new=NARROW_MAX_NEW):
                nb.submit(r)
        return [wide] + narrows

    def pending(b):
        return b.queue or any(r is not None for r in b.slot_req)

    def serve(packed: bool):
        """Returns (total tokens, makespan, per-tenant finish times)."""
        tenants = make_tenants()
        t0 = time.perf_counter()
        finish = [None] * len(tenants)
        if packed:
            live = list(range(len(tenants)))
            while live:
                for i in live:
                    tenants[i].step()
                for i in list(live):
                    if not pending(tenants[i]):
                        jax.block_until_ready(tenants[i].caches)
                        finish[i] = time.perf_counter() - t0
                        live.remove(i)
        else:
            for i, b in enumerate(tenants):
                b.run(max_steps=1_000_000)
                jax.block_until_ready(b.caches)
                finish[i] = time.perf_counter() - t0
        makespan = time.perf_counter() - t0
        return sum(b.stats.tokens for b in tenants), makespan, finish

    serve(packed=False)                         # warmup / compile (registry
    serve(packed=True)                          # is shared with the tp leg)
    best = {}
    for packed in (False, True):
        rate, row = 0.0, None
        for _ in range(REPS):
            toks, makespan, finish = serve(packed)
            if toks / makespan > rate:
                rate = toks / makespan
                row = (toks, makespan, finish)
        best[packed] = row

    rows = []
    for packed in (False, True):
        toks, makespan, finish = best[packed]
        rows.append({
            "arch": cfg.name,
            "mode": "packed" if packed else "exclusive",
            "tenants": 5,
            "wide_tp": 4,
            "narrow_tp": 1,
            "tokens": toks,
            "seconds": round(makespan, 4),
            "tokens_per_s": round(toks / makespan, 2),
            "mean_turnaround_s": round(float(np.mean(finish)), 4),
        })
    ex, pk = rows
    tokens_ratio = pk["tokens_per_s"] / max(ex["tokens_per_s"], 1e-9)
    turnaround_ratio = ex["mean_turnaround_s"] / max(
        pk["mean_turnaround_s"], 1e-9)
    for r in rows:
        r["packing_tokens_ratio"] = round(tokens_ratio, 3)
        r["packing_turnaround_ratio"] = round(turnaround_ratio, 3)
    return rows


def run() -> List[Dict]:
    import jax

    from repro.models import init_params

    cfg = _large_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rows = [bench_tp(params, cfg, tp) for tp in TPS]
    base = rows[0]
    for r in rows:
        r["speedup_vs_tp1"] = round(
            r["tokens_per_s"] / max(base["tokens_per_s"], 1e-9), 3)
    rows += bench_packing(params, cfg)
    return rows


def main() -> None:
    import jax

    if jax.device_count() < 8:
        # jax already initialized with too few devices in this process —
        # the host-device-count flag must be set before backend init, so
        # re-exec the bench as a child with the flag prepended.
        env = dict(os.environ)
        env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                            + env.get("XLA_FLAGS", "")).strip()
        p = subprocess.run([sys.executable, "-m", "benchmarks.bench_sharded"],
                           env=env)
        if p.returncode != 0:
            raise RuntimeError(
                f"bench_sharded subprocess exited {p.returncode}")
        return

    rows = run()
    path = write_csv("sharded", rows)
    by_mode = {r["mode"]: r for r in rows}
    tp2_ratio = by_mode["tp2"]["speedup_vs_tp1"]
    tokens_ratio = by_mode["packed"]["packing_tokens_ratio"]
    turnaround_ratio = by_mode["packed"]["packing_turnaround_ratio"]
    snap = {
        "bench": "sharded",
        "arch": ARCH,
        "unix_time": time.time(),
        "acceptance_tp2_scaling": tp2_ratio >= SHARDED_TP2_RATIO_FLOOR,
        "acceptance_packing_tokens":
            tokens_ratio >= PACKING_TOKENS_RATIO_FLOOR,
        "acceptance_packing_turnaround":
            turnaround_ratio >= PACKING_TURNAROUND_RATIO_FLOOR,
        "rows": rows,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    jpath = os.path.join(OUT_DIR, "BENCH_sharded.json")
    with open(jpath, "w") as f:
        json.dump(snap, f, indent=2)
    print(f"{'mode':>12} {'tp':>3} {'slots':>6} {'tok/s':>9} "
          f"{'disp/tok':>9} {'ratio':>7}")
    for r in rows:
        ratio = r.get("speedup_vs_tp1", r.get("packing_tokens_ratio", ""))
        print(f"{r['mode']:>12} {r.get('tp', ''):>3} {r.get('slots', ''):>6} "
              f"{r['tokens_per_s']:>9} "
              f"{r.get('dispatches_per_token', ''):>9} {ratio:>7}")
    # structural: sharding never breaks the chunked dispatch discipline
    for r in rows:
        if "decode_dispatches_per_token" in r:
            assert r["decode_dispatches_per_token"] <= 1.0 / CHUNK + 1e-9, r
            assert r["syncs_per_token"] <= 1.0 / CHUNK + 1e-9, r
    assert tp2_ratio >= SHARDED_TP2_RATIO_FLOOR, (
        f"tp=2 tokens/s at {tp2_ratio}x tp=1 < {SHARDED_TP2_RATIO_FLOOR} "
        f"floor: {by_mode['tp2']}")
    assert tokens_ratio >= PACKING_TOKENS_RATIO_FLOOR, (
        f"packed pool tokens/s at {tokens_ratio}x exclusive < "
        f"{PACKING_TOKENS_RATIO_FLOOR} floor: {by_mode['packed']}")
    assert turnaround_ratio >= PACKING_TURNAROUND_RATIO_FLOOR, (
        f"packed mean tenant turnaround only {turnaround_ratio}x better "
        f"than exclusive < {PACKING_TURNAROUND_RATIO_FLOOR} floor: "
        f"{by_mode['packed']}")
    print(f"wrote {path} and {jpath}")


if __name__ == "__main__":
    main()
