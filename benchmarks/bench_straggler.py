"""Beyond-paper: straggler mitigation via weighted re-allocation.

A slow core (thermal throttle / contended DDR bank on FPGA; a slow chip or
preempted host on TPU) stretches every layer barrier — the paper's
layer-wise sync makes the whole tenant run at the straggler's pace.  The
dynamic compiler's weighted allocator (heterogeneous-LPT over per-core
speeds) re-balances IFPs so the slow core receives proportionally less work.

Reports tenant throughput with: no straggler / straggler unmitigated /
straggler + re-balancing, across slowdown factors.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import Hypervisor, ResourcePool, TenantSpec, VirtualEngine

from .common import small_core, static_artifact, write_csv

HORIZON = 2.0
CORES = 8
PROBE_EVERY = 0.05   # hypervisor straggler-probe period (simulated seconds)


def _throughput(slowdown: float, mitigate: bool) -> tuple:
    """Mitigation is hypervisor-driven: periodic straggler-probe events sweep
    every tenant's lease and re-balance through the weighted dynamic compiler
    when a core exceeds the threshold."""
    pool = ResourcePool(n_cores=16)
    eng = VirtualEngine(pool, small_core(), straggler_threshold=1.3)
    art = static_artifact("resnet50")
    hv = Hypervisor(pool, policy="no_realloc", executor=eng,
                    probe_interval=PROBE_EVERY if mitigate else None)
    hv.schedule_arrival(TenantSpec("t0", CORES, artifact=art), at=0.0)
    if slowdown != 1.0:
        eng.core_slowdown[0] = slowdown   # core 0 of the lease is slow
    m = hv.run(HORIZON)
    return m["t0"].throughput(HORIZON), m["t0"].rebalances


def run() -> List[Dict]:
    rows: List[Dict] = []
    base, _ = _throughput(1.0, False)
    for slow in (1.5, 2.0, 4.0):
        fps_hit, _ = _throughput(slow, False)
        fps_fix, rebalances = _throughput(slow, True)
        rows.append({
            "bench": "straggler", "cores": CORES, "slowdown": slow,
            "fps_healthy": round(base, 1),
            "fps_straggler": round(fps_hit, 1),
            "fps_mitigated": round(fps_fix, 1),
            "rebalances": rebalances,
            "recovered_pct": round(
                100 * (fps_fix - fps_hit) / max(base - fps_hit, 1e-9), 1
            ),
        })
    return rows


def main() -> None:
    rows = run()
    path = write_csv("straggler", rows)
    print("\n# Straggler mitigation (8-core tenant, core 0 slowed)")
    print("slowdown  healthy  unmitigated  mitigated  recovered")
    for r in rows:
        print(
            f"{r['slowdown']:8.1f}  {r['fps_healthy']:7.1f}  "
            f"{r['fps_straggler']:11.1f}  {r['fps_mitigated']:9.1f}  "
            f"{r['recovered_pct']:8.1f}%"
        )
    print(f"csv -> {path}")


if __name__ == "__main__":
    main()
