"""Paper Table 1 analogue — resource utilization of the three designs.

The paper counts LUT/FF/BRAM/URAM/DSP on the U200/VU9P.  The portable
analogues our framework can measure honestly are:

* **compute units**: total parallelism (DSP analogue) — identical across
  designs by construction (2048 DSPs in the paper).
* **instruction/controller overhead**: instruction counts + controller state
  of the two-level IDM (the paper's virtualization adds ~1% logic on top of
  the static multi-core design; ours adds the L1 sync/context controllers and
  per-layer sync instructions — counted exactly).
* **on-chip memory**: per-core VMEM pool × cores (BRAM/URAM analogue) +
  static-artifact cache held by the hypervisor (host side).

Also reports the paper's own Table 1 rows for reference.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import DynamicCompiler

from .common import CNNS, small_core, static_artifact, write_csv

PAPER_TABLE1_U200 = {
    "static_single": {"LUT": 242135, "FF": 232588, "BRAM": 235, "URAM": 168, "DSP": 2048},
    "static_multi": {"LUT": 418282, "FF": 389777, "BRAM": 395, "URAM": 307, "DSP": 2048},
    "virtualized": {"LUT": 435710, "FF": 401832, "BRAM": 416, "URAM": 320, "DSP": 2048},
}


def run() -> List[Dict]:
    rows: List[Dict] = []
    hw = small_core()
    for cnn in CNNS:
        art = static_artifact(cnn)
        dyn = DynamicCompiler(art)
        sch16 = dyn.compile(list(range(16)), single_core_fastpath=False)
        sch1 = dyn.compile([1])
        n_ifps = sum(len(l.ifps) for l in art.luts.values())
        ifp_instrs = sum(len(i.program) for l in art.luts.values() for i in l.ifps)
        mono_instrs = sum(len(p) for p in art.mono)
        # virtualization overhead = per-layer sync System instructions +
        # two-level IDM bookkeeping vs. the plain multi-core schedule
        sync_instrs = sum(
            1 for layers in sch16.per_core_layers for c in layers
            for p in c.programs if len(p) == 1 and p.instrs[0].is_sync
        )
        total16 = sch16.instr_count
        rows.append({
            "bench": "resources", "cnn": cnn,
            "cached_ifps": n_ifps,
            "ifp_cache_instrs": ifp_instrs,
            "mono_instrs": mono_instrs,
            "sched16_instrs": total16,
            "sched1_instrs": sch1.instr_count,
            "sync_overhead_instrs": sync_instrs,
            "sync_overhead_pct": round(100 * sync_instrs / total16, 2),
            "vmem_total_mib": 16 * hw.vmem_bytes / 2**20,
        })
    # paper's silicon numbers, for the report table
    for design, r in PAPER_TABLE1_U200.items():
        d = {"bench": "resources_paper_u200", "cnn": "-", "design": design}
        d.update(r)
        virt = PAPER_TABLE1_U200["virtualized"]["LUT"]
        multi = PAPER_TABLE1_U200["static_multi"]["LUT"]
        if design == "virtualized":
            d["overhead_vs_static_multi_pct"] = round(100 * (virt - multi) / multi, 2)
        rows.append(d)
    return rows


def main() -> None:
    rows = run()
    path = write_csv("resources", rows)
    print("\n# Table 1 analogue: instruction/controller overhead of virtualization")
    for r in rows:
        if r["bench"] == "resources":
            print(
                f"{r['cnn']:14s} IFP cache: {r['cached_ifps']:4d} pkgs "
                f"({r['ifp_cache_instrs']:6d} instrs)  16-core sched: "
                f"{r['sched16_instrs']:6d} instrs, sync overhead "
                f"{r['sync_overhead_pct']:.2f}% (paper: ~1% LUT/FF)"
            )
    print(f"csv -> {path}")


if __name__ == "__main__":
    main()
