"""§Roofline — per-(arch × shape) roofline terms from the dry-run artifacts.

Reads experiments/dryrun/*.json (produced by ``python -m repro.launch.dryrun
--all``) and prints the single-pod roofline table: the three terms in
seconds, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline
fraction.  EXPERIMENTS.md §Roofline is generated from this output.

A second, *measured* section micro-benchmarks the paged decode-attention
kernel (``repro.kernels.paged_attention``): decode attention is pure
memory streaming (each K/V byte is read once per step, arithmetic
intensity ~ group/itemsize), so the figure of merit is achieved bytes/s
of mapped-page traffic vs the host's peak — measured on the same host by
timing a device-to-device copy of a pool-sized array, which keeps the
section host-independent (no hard-coded chip specs).  Swept over page
counts to show the walk amortizing: per-page overhead shrinks as the
resident context grows.  On non-TPU hosts the kernel runs in interpret
mode and the row is labelled so — emulator bytes/s, not kernel bytes/s.
"""

from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List

from .common import write_csv

DRYRUN_DIR = os.environ.get("DRYRUN_OUT", "experiments/dryrun")

# paged decode-attention micro-roofline shapes: serving-sized heads, page
# counts swept; maxp stays small enough that interpret mode (which unrolls
# the grid at trace time) compiles in seconds on CPU CI
PAGED_ATTN_PAGE_COUNTS = [2, 4, 8, 16]
PAGED_ATTN_SHAPE = dict(B=4, H=8, Hkv=4, dh=64, page_size=32)


def paged_attention_rows() -> List[Dict]:
    """Measured: achieved mapped-page bytes/s of the paged decode kernel vs
    a same-host copy-bandwidth peak, at several resident page counts."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.common import default_interpret
    from repro.kernels.paged_attention import ops

    B, H, Hkv, dh = (PAGED_ATTN_SHAPE[k] for k in ("B", "H", "Hkv", "dh"))
    ps = PAGED_ATTN_SHAPE["page_size"]
    maxp = max(PAGED_ATTN_PAGE_COUNTS)
    n_pool = B * maxp                       # every slot fully mappable
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    k_pool = jax.random.normal(kk, (n_pool + 1, ps, Hkv, dh), jnp.float32)
    v_pool = jax.random.normal(kv, (n_pool + 1, ps, Hkv, dh), jnp.float32)
    q = jax.random.normal(kq, (B, H, dh), jnp.float32)
    interp = bool(default_interpret())

    # same-host peak: bytes/s of a device copy of the pool (read + write)
    big = k_pool
    jax.block_until_ready(big)
    cp = jax.jit(lambda x: x + 0.0)
    jax.block_until_ready(cp(big))          # compile outside timing
    t0 = time.perf_counter()
    reps = 10
    for _ in range(reps):
        out = cp(big)
    jax.block_until_ready(out)
    copy_dt = (time.perf_counter() - t0) / reps
    peak_bps = 2 * big.size * big.dtype.itemsize / copy_dt

    rows: List[Dict] = []
    for n in PAGED_ATTN_PAGE_COUNTS:
        # n mapped pages per slot, distinct physical pages, rest unmapped
        tab = np.full((B, maxp), -1, np.int32)
        for b in range(B):
            tab[b, :n] = np.arange(n) * B + b
        table = jnp.asarray(tab)
        cur = jnp.full((B,), n * ps - 1, jnp.int32)
        fn = lambda: ops.paged_decode_attention(q, k_pool, v_pool, table, cur)
        jax.block_until_ready(fn())         # compile/trace outside timing
        t0 = time.perf_counter()
        iters = 20
        for _ in range(iters):
            out = fn()
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / iters
        # mapped K+V bytes streamed per call (the kernel's defining win:
        # unmapped logical pages move no bytes)
        bytes_moved = 2 * B * n * ps * Hkv * dh * k_pool.dtype.itemsize
        achieved = bytes_moved / dt
        rows.append({
            "bench": "roofline_paged_attn",
            "pages": n,
            "context": n * ps,
            "interpret": interp,
            "kv_mb": round(bytes_moved / 2**20, 3),
            "us_per_step": round(dt * 1e6, 1),
            "achieved_gbps": round(achieved / 1e9, 3),
            "peak_copy_gbps": round(peak_bps / 1e9, 3),
            "frac_of_peak": round(achieved / peak_bps, 4),
        })
    return rows


def run(mesh_tag: str = "pod16x16") -> List[Dict]:
    rows: List[Dict] = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh_tag}.json"))):
        rec = json.load(open(path))
        arch, shape = rec["arch"], rec["shape"]
        if rec.get("skipped"):
            rows.append({
                "bench": "roofline", "arch": arch, "shape": shape,
                "mesh": mesh_tag, "status": "skip", "reason": rec.get("reason", ""),
            })
            continue
        if not rec.get("ok"):
            rows.append({
                "bench": "roofline", "arch": arch, "shape": shape,
                "mesh": mesh_tag, "status": "FAIL",
                "reason": rec.get("error", "")[:120],
            })
            continue
        r = rec["roofline"]
        rows.append({
            "bench": "roofline", "arch": arch, "shape": shape, "mesh": mesh_tag,
            "status": "ok",
            "t_compute_s": f"{r['t_compute']:.3e}",
            "t_memory_s": f"{r['t_memory']:.3e}",
            "t_collective_s": f"{r['t_collective']:.3e}",
            "bound": r["bound"],
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "roofline_fraction": round(r["roofline_fraction"], 3),
            "per_device_gib": round((rec.get("per_device_bytes") or 0) / 2**30, 2),
            "compile_s": round(rec.get("compile_s", 0), 1),
        })
    return rows


def main() -> None:
    rows = run()
    path = write_csv("roofline", rows)
    print("\n# Roofline (single-pod 16x16 = 256 chips)")
    hdr = f"{'arch':22s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} {'bound':>10s} {'useful':>7s} {'frac':>6s} {'GiB/dev':>8s}"
    print(hdr)
    for r in rows:
        if r["status"] == "ok":
            print(
                f"{r['arch']:22s} {r['shape']:12s} {r['t_compute_s']:>9s} "
                f"{r['t_memory_s']:>9s} {r['t_collective_s']:>9s} "
                f"{r['bound']:>10s} {r['useful_flops_ratio']:>7} "
                f"{r['roofline_fraction']:>6} {r['per_device_gib']:>8}"
            )
        elif r["status"] == "skip":
            print(f"{r['arch']:22s} {r['shape']:12s} SKIP ({r['reason'][:60]})")
        else:
            print(f"{r['arch']:22s} {r['shape']:12s} FAIL ({r['reason'][:60]})")
    print(f"csv -> {path}")

    pa_rows = paged_attention_rows()
    pa_path = write_csv("roofline_paged_attn", pa_rows)
    mode = "interpret (emulator)" if pa_rows[0]["interpret"] else "compiled"
    print(f"\n# Paged decode-attention micro-roofline [{mode}]")
    print(f"{'pages':>6} {'context':>8} {'KV MB':>7} {'us/step':>9} "
          f"{'GB/s':>8} {'peak GB/s':>10} {'frac':>6}")
    for r in pa_rows:
        print(f"{r['pages']:>6} {r['context']:>8} {r['kv_mb']:>7} "
              f"{r['us_per_step']:>9} {r['achieved_gbps']:>8} "
              f"{r['peak_copy_gbps']:>10} {r['frac_of_peak']:>6}")
    print(f"csv -> {pa_path}")


if __name__ == "__main__":
    main()
