"""§Roofline — per-(arch × shape) roofline terms from the dry-run artifacts.

Reads experiments/dryrun/*.json (produced by ``python -m repro.launch.dryrun
--all``) and prints the single-pod roofline table: the three terms in
seconds, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline
fraction.  EXPERIMENTS.md §Roofline is generated from this output.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from .common import write_csv

DRYRUN_DIR = os.environ.get("DRYRUN_OUT", "experiments/dryrun")


def run(mesh_tag: str = "pod16x16") -> List[Dict]:
    rows: List[Dict] = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh_tag}.json"))):
        rec = json.load(open(path))
        arch, shape = rec["arch"], rec["shape"]
        if rec.get("skipped"):
            rows.append({
                "bench": "roofline", "arch": arch, "shape": shape,
                "mesh": mesh_tag, "status": "skip", "reason": rec.get("reason", ""),
            })
            continue
        if not rec.get("ok"):
            rows.append({
                "bench": "roofline", "arch": arch, "shape": shape,
                "mesh": mesh_tag, "status": "FAIL",
                "reason": rec.get("error", "")[:120],
            })
            continue
        r = rec["roofline"]
        rows.append({
            "bench": "roofline", "arch": arch, "shape": shape, "mesh": mesh_tag,
            "status": "ok",
            "t_compute_s": f"{r['t_compute']:.3e}",
            "t_memory_s": f"{r['t_memory']:.3e}",
            "t_collective_s": f"{r['t_collective']:.3e}",
            "bound": r["bound"],
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "roofline_fraction": round(r["roofline_fraction"], 3),
            "per_device_gib": round((rec.get("per_device_bytes") or 0) / 2**30, 2),
            "compile_s": round(rec.get("compile_s", 0), 1),
        })
    return rows


def main() -> None:
    rows = run()
    path = write_csv("roofline", rows)
    print("\n# Roofline (single-pod 16x16 = 256 chips)")
    hdr = f"{'arch':22s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} {'bound':>10s} {'useful':>7s} {'frac':>6s} {'GiB/dev':>8s}"
    print(hdr)
    for r in rows:
        if r["status"] == "ok":
            print(
                f"{r['arch']:22s} {r['shape']:12s} {r['t_compute_s']:>9s} "
                f"{r['t_memory_s']:>9s} {r['t_collective_s']:>9s} "
                f"{r['bound']:>10s} {r['useful_flops_ratio']:>7} "
                f"{r['roofline_fraction']:>6} {r['per_device_gib']:>8}"
            )
        elif r["status"] == "skip":
            print(f"{r['arch']:22s} {r['shape']:12s} SKIP ({r['reason'][:60]})")
        else:
            print(f"{r['arch']:22s} {r['shape']:12s} FAIL ({r['reason'][:60]})")
    print(f"csv -> {path}")


if __name__ == "__main__":
    main()
