"""Benchmark regression gate — compare a fresh run against the committed
baseline snapshots.

Used by the CI benchmark-smoke job: after running ``bench_serving`` (and
``bench_slo``) into a scratch ``BENCH_OUT`` directory, this script fails the
build when

* any serving mode's decode ``tokens_per_s`` dropped more than
  ``--tolerance`` (default 25%) below the committed ``BENCH_serving.json``
  baseline, or the fresh mixed-trace leg no longer shows speculative
  decode + admission/decode overlap at >= 1.3x the serial batcher's decode
  tokens/s (same host, same run — gated exactly), or
* the fresh ``BENCH_slo.json`` no longer records the ``latency_slo`` policy
  strictly beating ``even_split`` and ``no_realloc`` on SLO attainment, or
* the fresh ``BENCH_paging.json`` no longer meets the paged-KV acceptance:
  effective slot capacity at equal HBM below its floor (1.5x dense) or
  equal-slot paged tokens/s below its floor (within 15% of dense).  Both
  ratios are measured dense-vs-paged inside one run on one host, so they
  are gated exactly, not against the committed absolute numbers, or
* the fresh ``BENCH_prefix.json`` no longer meets the shared-prefix-cache
  acceptance at 90% prompt overlap: cached admission throughput below
  1.3x cold, prefill tokens skipped below 80%, or cache hit rate below
  0.8 — again cached-vs-cold on one host, gated exactly, or
* a ``paged_pallas`` / ``cached_pallas`` kernel leg that ran **compiled**
  (``"interpret": false`` in the row) fell below 1.0x the XLA leg's
  tokens/s.  Kernel-vs-XLA is same-host/same-run, so the floor is exact
  and host-independent; interpret-mode legs (CPU CI) record the ratio but
  are never gated — they measure the Pallas emulator, not the kernel, or
* the fresh ``BENCH_chaos.json`` no longer meets the fault-tolerance
  acceptance: goodput retention under the seeded fault schedule below
  0.7, a displaced tenant never re-placed, any token divergence outside
  the fault domain, or a non-deterministic seeded replay, or
* the fresh ``BENCH_obs.json`` no longer meets the telemetry-plane
  acceptance: tracer overhead at or above 3% decode tokens/s (widened by
  ``--tolerance`` for loaded runners — the on/off legs share one host so
  the ratio itself is exact, but the ceiling is tight enough that
  scheduler noise needs headroom), the ≤1-dispatch/≤1-sync-per-chunk
  contract broken with telemetry enabled, device counters that never
  rode back in the per-chunk fetch, or a missing/empty exported trace.

Absolute tokens/s moves with the host, so the tolerance is deliberately
loose; the ``CHECK_TOLERANCE`` env var (or ``--tolerance``) can widen it for
known-slow runners.  Structural metrics (dispatches per token, the SLO
policy ordering) are host-independent and checked tightly.

A missing, unparseable, or schema-drifted snapshot is itself a gate
failure, reported as a one-line ``REGRESSION:`` message — never a
traceback.

    python -m benchmarks.check_regression \
        --baseline experiments/bench --fresh "$BENCH_OUT"
"""

from __future__ import annotations

import argparse
import json
import os
import sys


class SnapshotError(Exception):
    """A BENCH_*.json that cannot be used: absent, unparseable, or not the
    shape the checkers expect.  Reported as a clear gate failure, never a
    traceback."""


def _load(path: str) -> dict:
    try:
        with open(path) as f:
            snap = json.load(f)
    except FileNotFoundError:
        raise SnapshotError(f"{path} missing (did the bench run?)")
    except json.JSONDecodeError as e:
        raise SnapshotError(f"{path} is not valid JSON: {e}")
    if not isinstance(snap, dict):
        raise SnapshotError(
            f"{path} holds a {type(snap).__name__}, expected a snapshot "
            f"object — re-generate it with `python -m benchmarks.run`")
    return snap


def _serving_rows(snapshot: dict) -> dict:
    return {row["mode"]: row for row in snapshot["rows"]}


def check_serving(baseline: dict, fresh: dict, tolerance: float) -> list:
    """tokens/s per mode within tolerance of the committed baseline, and the
    structural dispatch amortization preserved exactly."""
    errors = []
    base_rows = _serving_rows(baseline)
    fresh_rows = _serving_rows(fresh)
    missing = set(base_rows) - set(fresh_rows)
    if missing:
        errors.append(f"serving: fresh run lacks modes {sorted(missing)}")
    for mode, base in base_rows.items():
        row = fresh_rows.get(mode)
        if row is None or mode.startswith("mixed_"):
            continue                       # mixed legs gate same-run below
        floor = base["tokens_per_s"] * (1.0 - tolerance)
        if row["tokens_per_s"] < floor:
            errors.append(
                f"serving[{mode}]: tokens/s regressed "
                f"{base['tokens_per_s']} -> {row['tokens_per_s']} "
                f"(> {tolerance:.0%} drop)"
            )
        # host-independent: chunked decode must keep its dispatch amortization
        if row["chunk"] >= 8 and row["decode_dispatches_per_token"] > 1.0 / 8 + 1e-9:
            errors.append(
                f"serving[{mode}]: decode dispatches/token "
                f"{row['decode_dispatches_per_token']} > 1/8"
            )
    # mixed-trace speculative+overlap leg: recorded acceptance bit AND the
    # re-derived ratio itself.  Both legs ran on the same host in the same
    # fresh run, so the floor gates exactly (host speed cancels).
    if not fresh.get("acceptance_spec_overlap"):
        errors.append(
            "serving: snapshot does not record the spec+overlap acceptance")
    serial = fresh_rows.get("mixed_serial")
    both = fresh_rows.get("mixed_spec_overlap")
    if not (serial and both):
        errors.append(
            f"serving: mixed-trace rows missing, have {sorted(fresh_rows)}")
    else:
        ratio = both["decode_tokens_per_s"] / max(
            serial["decode_tokens_per_s"], 1e-9)
        if ratio < SPEC_OVERLAP_RATIO_FLOOR:
            errors.append(
                f"serving: mixed-trace spec+overlap decode tokens/s at "
                f"{ratio:.3f}x serial < {SPEC_OVERLAP_RATIO_FLOOR} floor")
        if both["acceptance_rate"] <= 0:
            errors.append(
                "serving: spec+overlap leg recorded zero draft acceptance "
                "(drafter not engaged?)")
    return errors


def check_slo(fresh: dict) -> list:
    """The recorded acceptance bit and the per-load ordering itself."""
    errors = []
    if not fresh.get("acceptance_latency_slo_strictly_best"):
        errors.append("slo: snapshot does not record latency_slo as strictly best")
    by_load: dict = {}
    for row in fresh.get("rows", []):
        by_load.setdefault(row["load"], {})[row["policy"]] = row["attainment"]
    for load, pols in sorted(by_load.items()):
        if not (pols["latency_slo"] > pols["even_split"]
                and pols["latency_slo"] > pols["no_realloc"]):
            errors.append(f"slo[load={load}]: latency_slo not strictly best: {pols}")
    return errors


# The paging/prefix acceptance floors are owned HERE, not read from the
# snapshot — a fresh run cannot relax its own gate (bench_paging.py /
# bench_prefix.py assert the same bars at generation time; keep them in
# sync deliberately).
SPEC_OVERLAP_RATIO_FLOOR = 1.3
PAGING_CAPACITY_FLOOR = 1.5
PAGING_TOKENS_RATIO_FLOOR = 0.85
PREFIX_ADMIT_RATIO_FLOOR = 1.3
PREFIX_SKIPPED_FRAC_FLOOR = 0.8
PREFIX_HIT_RATE_FLOOR = 0.8
KERNEL_TOKENS_RATIO_FLOOR = 1.0
CHAOS_GOODPUT_FLOOR = 0.7
SHARDED_TP2_RATIO_FLOOR = 1.15
SHARDED_PACKING_TOKENS_FLOOR = 0.85
SHARDED_PACKING_TURNAROUND_FLOOR = 1.2
OBS_OVERHEAD_CEILING = 0.03     # keep in sync with bench_obs.py


def _check_kernel_leg(bench: str, row: dict, xla_row: dict) -> list:
    """Compiled pallas leg never slower than the same run's XLA leg.

    Same host, same run — the ratio gates exactly.  Interpret-mode rows
    (CPU CI) are skipped: they measure the emulator, not the kernel."""
    if row is None:
        return [f"{bench}: pallas kernel leg missing from snapshot"]
    if row.get("interpret"):
        return []
    ratio = row["tokens_per_s"] / max(xla_row["tokens_per_s"], 1e-9)
    if ratio < KERNEL_TOKENS_RATIO_FLOOR:
        return [f"{bench}: compiled pallas leg at {ratio:.3f}x the XLA leg "
                f"< {KERNEL_TOKENS_RATIO_FLOOR} floor"]
    return []


def check_paging(fresh: dict) -> list:
    """Recorded acceptance bits AND the re-derived ratios themselves."""
    errors = []
    cap_floor = PAGING_CAPACITY_FLOOR
    tok_floor = PAGING_TOKENS_RATIO_FLOOR
    if not fresh.get("acceptance_capacity"):
        errors.append("paging: snapshot does not record the capacity acceptance")
    if not fresh.get("acceptance_tokens"):
        errors.append("paging: snapshot does not record the tokens/s acceptance")
    by_mode = {row["mode"]: row for row in fresh.get("rows", [])}
    dense = by_mode.get("dense")
    eq_slots = by_mode.get("paged_equal_slots")
    eq_hbm = by_mode.get("paged_equal_hbm")
    if not (dense and eq_slots and eq_hbm):
        errors.append(f"paging: rows missing, have {sorted(by_mode)}")
        return errors
    if eq_hbm["cache_mb"] > dense["cache_mb"] + 1e-6:
        errors.append(
            f"paging: equal-HBM run used {eq_hbm['cache_mb']} MB "
            f"> dense {dense['cache_mb']} MB"
        )
    cap = eq_hbm["peak_resident"] / max(dense["slots"], 1)
    if cap < cap_floor:
        errors.append(
            f"paging: effective capacity {cap:.2f}x dense < {cap_floor}x floor")
    tok = eq_slots["tokens_per_s"] / max(dense["tokens_per_s"], 1e-9)
    if tok < tok_floor:
        errors.append(
            f"paging: equal-slot tokens/s ratio {tok:.3f} < {tok_floor} floor")
    errors.extend(
        _check_kernel_leg("paging", by_mode.get("paged_pallas"), eq_slots))
    return errors


def check_prefix(fresh: dict) -> list:
    """Recorded acceptance bits AND the re-derived 90%-overlap ratios.  All
    three are cached-vs-cold on the same host in one run, so they gate
    exactly (host speed cancels)."""
    errors = []
    for bit in ("acceptance_admit_ratio", "acceptance_skipped_frac",
                "acceptance_hit_rate"):
        if not fresh.get(bit):
            errors.append(f"prefix: snapshot does not record {bit}")
    at90 = {row["mode"]: row for row in fresh.get("rows", [])
            if row.get("overlap") == 0.9}
    cold, cached = at90.get("cold"), at90.get("cached")
    if not (cold and cached):
        errors.append(f"prefix: 90%-overlap rows missing, have {sorted(at90)}")
        return errors
    ratio = cached["admit_throughput_rps"] / max(
        cold["admit_throughput_rps"], 1e-9)
    if ratio < PREFIX_ADMIT_RATIO_FLOOR:
        errors.append(
            f"prefix: admission throughput {ratio:.2f}x cold "
            f"< {PREFIX_ADMIT_RATIO_FLOOR}x floor at 90% overlap")
    if cached["skipped_frac"] < PREFIX_SKIPPED_FRAC_FLOOR:
        errors.append(
            f"prefix: prefill tokens skipped {cached['skipped_frac']:.2f} "
            f"< {PREFIX_SKIPPED_FRAC_FLOOR} floor at 90% overlap")
    if cached["hit_rate"] < PREFIX_HIT_RATE_FLOOR:
        errors.append(
            f"prefix: hit rate {cached['hit_rate']:.2f} "
            f"< {PREFIX_HIT_RATE_FLOOR} floor at 90% overlap")
    errors.extend(
        _check_kernel_leg("prefix", at90.get("cached_pallas"), cached))
    return errors


def check_chaos(fresh: dict) -> list:
    """Recorded acceptance bits AND the re-derived fault-tolerance floors:
    goodput retention under the seeded fault schedule, full recovery of
    every displaced tenant, zero token divergence outside the fault
    domain, and a deterministic replay."""
    errors = []
    for bit in ("acceptance_goodput", "acceptance_recovery",
                "acceptance_isolation", "acceptance_determinism"):
        if not fresh.get(bit):
            errors.append(f"chaos: snapshot does not record {bit}")
    rows = {(row["leg"], row["mode"]): row for row in fresh.get("rows", [])}
    pool = rows.get(("pool", "chaos"))
    srv = rows.get(("serving", "chaos"))
    if not (pool and srv):
        errors.append(f"chaos: chaos-mode rows missing, have {sorted(rows)}")
        return errors
    if pool["goodput_retention"] < CHAOS_GOODPUT_FLOOR:
        errors.append(
            f"chaos: goodput retention {pool['goodput_retention']:.3f} "
            f"< {CHAOS_GOODPUT_FLOOR} floor under the seeded faults")
    if pool["unrecovered"]:
        errors.append(
            f"chaos: {pool['unrecovered']} displaced tenant(s) never "
            f"re-placed by the horizon")
    if not srv["tenant_b_token_identical"]:
        errors.append(
            "chaos: fault-free tenant's token streams diverged under "
            "injected faults (cross-tenant blast radius)")
    if not (pool["deterministic"] and srv["deterministic"]):
        errors.append("chaos: seeded chaos replay was not deterministic")
    return errors


def check_sharded(fresh: dict) -> list:
    """Recorded acceptance bits AND the re-derived tensor-parallel ratios.
    Both are same-host same-run comparisons (tp legs and packing legs run
    back to back in one process), so they gate exactly."""
    errors = []
    for bit in ("acceptance_tp2_scaling", "acceptance_packing_tokens",
                "acceptance_packing_turnaround"):
        if not fresh.get(bit):
            errors.append(f"sharded: snapshot does not record {bit}")
    by_mode = {row["mode"]: row for row in fresh.get("rows", [])}
    tp1, tp2 = by_mode.get("tp1"), by_mode.get("tp2")
    if not (tp1 and tp2):
        errors.append(f"sharded: tp rows missing, have {sorted(by_mode)}")
        return errors
    ratio = tp2["tokens_per_s"] / max(tp1["tokens_per_s"], 1e-9)
    if ratio < SHARDED_TP2_RATIO_FLOOR:
        errors.append(
            f"sharded: tp=2 decode tokens/s at {ratio:.3f}x tp=1 "
            f"< {SHARDED_TP2_RATIO_FLOOR} floor")
    # host-independent: sharding must keep the chunked dispatch discipline
    for mode, row in by_mode.items():
        if "decode_dispatches_per_token" in row and \
                row["decode_dispatches_per_token"] > 1.0 / 8 + 1e-9:
            errors.append(
                f"sharded[{mode}]: decode dispatches/token "
                f"{row['decode_dispatches_per_token']} > 1/8")
        if "syncs_per_token" in row and \
                row["syncs_per_token"] > 1.0 / 8 + 1e-9:
            errors.append(
                f"sharded[{mode}]: host syncs/token "
                f"{row['syncs_per_token']} > 1/8")
    exclusive = by_mode.get("exclusive")
    packed = by_mode.get("packed")
    if not (exclusive and packed):
        errors.append(
            f"sharded: packing rows missing, have {sorted(by_mode)}")
        return errors
    pk = packed["tokens_per_s"] / max(exclusive["tokens_per_s"], 1e-9)
    if pk < SHARDED_PACKING_TOKENS_FLOOR:
        errors.append(
            f"sharded: packed pool tokens/s at {pk:.3f}x exclusive "
            f"time-sharing < {SHARDED_PACKING_TOKENS_FLOOR} floor")
    ta = exclusive["mean_turnaround_s"] / max(
        packed["mean_turnaround_s"], 1e-9)
    if ta < SHARDED_PACKING_TURNAROUND_FLOOR:
        errors.append(
            f"sharded: packed mean tenant turnaround only {ta:.3f}x better "
            f"than exclusive < {SHARDED_PACKING_TURNAROUND_FLOOR} floor")
    return errors


def check_obs(fresh: dict, tolerance: float) -> list:
    """Recorded acceptance bits AND the re-derived telemetry gates.  The
    overhead ratio is on-vs-off on one host in one run, but the 3% ceiling
    is tight enough that scheduler noise needs the same ``--tolerance``
    headroom the tokens/s floors get; the contract, device counters, and
    trace checks are host-independent and gated exactly."""
    errors = []
    for bit in ("acceptance_overhead", "acceptance_contract",
                "acceptance_device_counters", "acceptance_trace"):
        if not fresh.get(bit):
            errors.append(f"obs: snapshot does not record {bit}")
    ceiling = OBS_OVERHEAD_CEILING * (1.0 + tolerance)
    if fresh["overhead_frac"] >= ceiling:
        errors.append(
            f"obs: telemetry overhead {fresh['overhead_frac']:.1%} >= "
            f"{ceiling:.1%} ceiling")
    by_mode = {row["mode"]: row for row in fresh.get("rows", [])}
    off = by_mode.get("telemetry_off")
    on = by_mode.get("telemetry_on")
    if not (off and on):
        errors.append(f"obs: telemetry rows missing, have {sorted(by_mode)}")
        return errors
    for mode, row in by_mode.items():
        budget = row["chunks"] + row["prefills"]
        if row["dispatches"] > budget or row["host_syncs"] > budget:
            errors.append(
                f"obs[{mode}]: contract broken — {row['dispatches']} "
                f"dispatches / {row['host_syncs']} syncs for "
                f"{row['chunks']} chunks + {row['prefills']} prefills")
    if on["device_pages_popped"] <= 0:
        errors.append("obs: device counters never rode back "
                      "(device_pages_popped == 0 in a paged run)")
    if on["trace_events"] <= 0 or on["trace_tracks"] < 1:
        errors.append(
            f"obs: exported trace is empty ({on.get('trace_events')} "
            f"events, {on.get('trace_tracks')} tracks)")
    return errors


def _guard(name: str, fn, *snaps) -> list:
    """Run one checker, translating schema drift into a clear gate failure
    instead of a traceback: a malformed snapshot IS a regression."""
    try:
        return fn(*snaps)
    except (KeyError, TypeError, AttributeError, IndexError) as e:
        return [f"{name}: snapshot schema mismatch "
                f"({type(e).__name__}: {e}) — re-generate it with "
                f"`python -m benchmarks.run {name}`"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="experiments/bench",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh", default=os.environ.get("BENCH_OUT", "experiments/bench"),
                    help="directory holding the freshly produced BENCH_*.json")
    ap.add_argument("--tolerance",
                    type=float,
                    default=float(os.environ.get("CHECK_TOLERANCE", "0.25")),
                    help="allowed fractional tokens/s drop vs baseline")
    args = ap.parse_args(argv)

    errors = []
    try:
        errors = _guard(
            "serving", check_serving,
            _load(os.path.join(args.baseline, "BENCH_serving.json")),
            _load(os.path.join(args.fresh, "BENCH_serving.json")),
            args.tolerance,
        )
    except SnapshotError as e:
        errors.append(f"serving: {e}")
    for name, checker in (("slo", check_slo), ("paging", check_paging),
                          ("prefix", check_prefix), ("chaos", check_chaos),
                          ("sharded", check_sharded)):
        try:
            snap = _load(os.path.join(args.fresh, f"BENCH_{name}.json"))
        except SnapshotError as e:
            errors.append(f"{name}: {e}")
            continue
        errors.extend(_guard(name, checker, snap))
    # obs gets the tolerance (its ceiling is noise-sensitive), so it can't
    # ride the single-snapshot loop above
    try:
        snap = _load(os.path.join(args.fresh, "BENCH_obs.json"))
        errors.extend(_guard("obs", check_obs, snap, args.tolerance))
    except SnapshotError as e:
        errors.append(f"obs: {e}")

    if errors:
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        return 1
    print(f"benchmark gate OK (tokens/s tolerance {args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
