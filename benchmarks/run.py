"""Benchmark driver: one module per paper table/figure, CSV per bench.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run single_task

Order mirrors the paper: Table 1 (resources), Table 2 (context switch),
Table 3/Fig 6 (single-task tiling), Fig 5 (isolation), Fig 7 (multi-task),
plus the beyond-paper straggler bench and the §Roofline table.
"""

from __future__ import annotations

import sys
import time


BENCHES = [
    ("resources", "bench_resources", "Table 1 — resource/overhead accounting"),
    ("context_switch", "bench_context_switch", "Table 2 — two-stage compile + ctx switch"),
    ("single_task", "bench_single_task", "Table 3/Fig 6 — single-task tiling throughput"),
    ("isolation", "bench_isolation", "Fig 5 — performance isolation"),
    ("multi_task", "bench_multi_task", "Fig 7 — multi-task dynamic workload"),
    ("straggler", "bench_straggler", "beyond-paper — straggler mitigation"),
    ("roofline", "bench_roofline", "§Roofline — dry-run derived terms"),
    ("serving", "bench_serving", "beyond-paper — chunked/donated decode hot path"),
    ("slo", "bench_slo", "beyond-paper — SLO attainment under open-loop Poisson traffic"),
    ("paging", "bench_paging", "beyond-paper — paged KV pool capacity at equal HBM"),
    ("prefix", "bench_prefix", "beyond-paper — shared-prefix KV cache admission speedup"),
    ("chaos", "bench_chaos", "beyond-paper — seeded fault injection, recovery, blast radius"),
    ("sharded", "bench_sharded", "beyond-paper — tensor-sharded decode scaling on an emulated 8-device pool"),
    ("obs", "bench_obs", "beyond-paper — telemetry plane overhead gate + trace export"),
]


def main() -> int:
    only = set(sys.argv[1:])
    failures = 0
    t_all = time.time()
    for name, module, desc in BENCHES:
        if only and name not in only:
            continue
        print(f"\n{'='*78}\n== {name}: {desc}\n{'='*78}")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{module}", fromlist=["main"])
            mod.main()
            print(f"[{name}] done in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001 — run every bench, report at end
            failures += 1
            import traceback

            traceback.print_exc()
            print(f"[{name}] FAILED: {type(e).__name__}: {e}")
    print(f"\nbenchmarks finished in {time.time()-t_all:.1f}s, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
