"""Serving hot-path benchmark — per-step vs chunked continuous batching.

Measures, on the reduced qwen3-0.6b decode path, what the chunked/donated
overhaul buys: decode tokens/s, device dispatches per generated token, host
syncs per token, and the cost of one admission (right-sized prefill +
per-slot scatter).  ``chunk=1`` is the per-step baseline (one dispatch and
one blocking sync per token — the pre-overhaul behavior); larger chunks
amortize both by T.

Emits ``experiments/bench/serving.csv`` plus a ``BENCH_serving.json``
snapshot so the serving-perf trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run serving
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import OUT_DIR, write_csv

ARCH = "qwen3-0.6b"
SLOTS = 4
PROMPT_LEN = 8
MAX_NEW = 16
N_REQUESTS = 16
CHUNKS = (1, 4, 8, 16)


def _requests(cfg, n: int):
    from repro.serving.batcher import Request

    rng = np.random.default_rng(0)
    return [
        Request(rid=i,
                prompt=rng.integers(1, cfg.vocab, size=2 + i % (PROMPT_LEN - 2)
                                    ).astype(np.int32),
                max_new=MAX_NEW)
        for i in range(n)
    ]


def _batcher(params, cfg, chunk: int):
    from repro.serving.batcher import ContinuousBatcher

    return ContinuousBatcher(
        params, cfg, slots=SLOTS, prompt_len=PROMPT_LEN,
        max_len=PROMPT_LEN + MAX_NEW + 2, chunk=chunk,
    )


def bench_mode(params, cfg, chunk: int) -> Dict:
    import jax

    # warmup: compile the admit + chunk programs outside the timed region
    warm = _batcher(params, cfg, chunk)
    for r in _requests(cfg, SLOTS + 1):
        warm.submit(r)
    warm.run(max_steps=1000)

    # admission micro-benchmark: one bucketed prefill + scatter dispatch
    b = _batcher(params, cfg, chunk)
    for r in _requests(cfg, SLOTS):
        b.submit(r)
    t0 = time.perf_counter()
    b._admit()
    jax.block_until_ready(b.caches)
    admit_s = time.perf_counter() - t0

    # steady-state throughput
    b = _batcher(params, cfg, chunk)
    for r in _requests(cfg, N_REQUESTS):
        b.submit(r)
    t0 = time.perf_counter()
    stats = b.run(max_steps=10_000)
    jax.block_until_ready(b.caches)
    dt = time.perf_counter() - t0

    return {
        "arch": cfg.name,
        "mode": "per_step" if chunk == 1 else f"chunked_{chunk}",
        "chunk": chunk,
        "requests": N_REQUESTS,
        "completed": stats.completed,
        "tokens": stats.tokens,
        "seconds": round(dt, 4),
        "tokens_per_s": round(stats.tokens / dt, 2),
        "dispatches": stats.dispatches,
        "host_syncs": stats.host_syncs,
        "dispatches_per_token": round(stats.dispatches_per_token, 4),
        "syncs_per_token": round(stats.syncs_per_token, 4),
        "decode_dispatches_per_token": round(
            stats.decode_dispatches_per_token, 4),
        "admit_ms": round(admit_s * 1e3, 3),
        "admit_scatter_mb": round(stats.admit_scatter_bytes / 2**20, 3),
        "cache_mb": round(stats.cache_bytes / 2**20, 3),
        "occupancy": round(stats.occupancy, 4),
    }


def run() -> List[Dict]:
    import jax

    from repro.configs import get_reduced
    from repro.models import init_params

    cfg = get_reduced(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rows = [bench_mode(params, cfg, c) for c in CHUNKS]

    base = rows[0]
    for r in rows:
        r["speedup_vs_per_step"] = round(
            r["tokens_per_s"] / max(base["tokens_per_s"], 1e-9), 3)
    return rows


def main() -> None:
    rows = run()
    path = write_csv("serving", rows)
    snap = {
        "bench": "serving",
        "arch": ARCH,
        "unix_time": time.time(),
        "rows": rows,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    jpath = os.path.join(OUT_DIR, "BENCH_serving.json")
    with open(jpath, "w") as f:
        json.dump(snap, f, indent=2)
    print(f"{'mode':>12} {'tok/s':>8} {'disp/tok':>9} {'sync/tok':>9} "
          f"{'admit ms':>9} {'speedup':>8}")
    for r in rows:
        print(f"{r['mode']:>12} {r['tokens_per_s']:>8} "
              f"{r['dispatches_per_token']:>9} {r['syncs_per_token']:>9} "
              f"{r['admit_ms']:>9} {r['speedup_vs_per_step']:>8}")
    # the overhaul's acceptance bar: ≤1 dispatch and ≤1 blocking sync per
    # T=8 decode tokens once chunks are ≥8 deep (adaptive sizing may run
    # shorter chunks under queue pressure, never more than one dispatch
    # per 8 tokens in steady state)
    for r in rows:
        if r["chunk"] >= 8:
            assert r["decode_dispatches_per_token"] <= 1.0 / 8 + 1e-9, r
            assert r["syncs_per_token"] <= 1.0 / 8 + 1e-9, r
    print(f"wrote {path} and {jpath}")


if __name__ == "__main__":
    main()
