"""Serving hot-path benchmark — per-step vs chunked continuous batching.

Measures, on the reduced qwen3-0.6b decode path, what the chunked/donated
overhaul buys: decode tokens/s, device dispatches per generated token, host
syncs per token, and the cost of one admission (right-sized prefill +
per-slot scatter).  ``chunk=1`` is the per-step baseline (one dispatch and
one blocking sync per token — the pre-overhaul behavior); larger chunks
amortize both by T.

A second leg drives a mixed decode-deep trace (continuous admissions at
full occupancy, long generations) through the serial batcher and through
the speculative + overlapped one (``ServingConfig(speculative=True,
overlap=True)``): decode tokens/s with both features off vs both on, same
host, same run.  The ratio floor (>= 1.3x) is owned by
``check_regression.py``; this bench asserts it at generation time too.

Emits ``experiments/bench/serving.csv`` plus a ``BENCH_serving.json``
snapshot so the serving-perf trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run serving
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import OUT_DIR, write_csv

ARCH = "qwen3-0.6b"
SLOTS = 4
PROMPT_LEN = 8
MAX_NEW = 16
N_REQUESTS = 16
CHUNKS = (1, 4, 8, 16)

# mixed decode-deep trace: admissions keep interleaving with resident
# decodes while streams run deep enough for the n-gram drafter to pay
# (acceptance climbs with depth as greedy settles into loops)
MIXED_MAX_NEW = 384
MIXED_N_REQUESTS = 12
MIXED_DRAFT_WINDOW = 6
MIXED_REPS = 3
SPEC_OVERLAP_RATIO_FLOOR = 1.3


def _requests(cfg, n: int, *, max_new: int = MAX_NEW):
    from repro.serving.batcher import Request

    rng = np.random.default_rng(0)
    return [
        Request(rid=i,
                prompt=rng.integers(1, cfg.vocab, size=2 + i % (PROMPT_LEN - 2)
                                    ).astype(np.int32),
                max_new=max_new)
        for i in range(n)
    ]


def _batcher(params, cfg, chunk: int):
    from repro.serving import ServingConfig
    from repro.serving.batcher import ContinuousBatcher

    return ContinuousBatcher(
        params, cfg,
        ServingConfig(slots=SLOTS, prompt_len=PROMPT_LEN,
                      max_len=PROMPT_LEN + MAX_NEW + 2, chunk=chunk),
    )


def bench_mode(params, cfg, chunk: int) -> Dict:
    import jax

    # warmup: compile the admit + chunk programs outside the timed region
    warm = _batcher(params, cfg, chunk)
    for r in _requests(cfg, SLOTS + 1):
        warm.submit(r)
    warm.run(max_steps=1000)

    # admission micro-benchmark: one bucketed prefill + scatter dispatch
    b = _batcher(params, cfg, chunk)
    for r in _requests(cfg, SLOTS):
        b.submit(r)
    t0 = time.perf_counter()
    b._admit()
    jax.block_until_ready(b.caches)
    admit_s = time.perf_counter() - t0

    # steady-state throughput
    b = _batcher(params, cfg, chunk)
    for r in _requests(cfg, N_REQUESTS):
        b.submit(r)
    t0 = time.perf_counter()
    stats = b.run(max_steps=10_000)
    jax.block_until_ready(b.caches)
    dt = time.perf_counter() - t0

    return {
        "arch": cfg.name,
        "mode": "per_step" if chunk == 1 else f"chunked_{chunk}",
        "chunk": chunk,
        "requests": N_REQUESTS,
        "completed": stats.completed,
        "tokens": stats.tokens,
        "seconds": round(dt, 4),
        "tokens_per_s": round(stats.tokens / dt, 2),
        "dispatches": stats.dispatches,
        "host_syncs": stats.host_syncs,
        "dispatches_per_token": round(stats.dispatches_per_token, 4),
        "syncs_per_token": round(stats.syncs_per_token, 4),
        "decode_dispatches_per_token": round(
            stats.decode_dispatches_per_token, 4),
        "admit_ms": round(admit_s * 1e3, 3),
        "admit_scatter_mb": round(stats.admit_scatter_bytes / 2**20, 3),
        "cache_mb": round(stats.cache_bytes / 2**20, 3),
        "occupancy": round(stats.occupancy, 4),
    }


def _mixed_config(speculative: bool, overlap: bool):
    from repro.serving import ServingConfig

    return ServingConfig(
        slots=SLOTS, prompt_len=PROMPT_LEN,
        max_len=PROMPT_LEN + MIXED_MAX_NEW + 8, attn_impl="xla", chunk=8,
        paged=True, page_size=16, n_pages=256,
        speculative=speculative, draft_window=MIXED_DRAFT_WINDOW,
        overlap=overlap,
    )


def bench_mixed(params, cfg, *, speculative: bool, overlap: bool) -> Dict:
    """One mixed-trace leg: best decode tokens/s over MIXED_REPS runs
    (best-of-N because the ratio gate compares two same-host legs — the
    noise is one-sided slowdown, so max is the stable estimator)."""
    import jax

    from repro.serving.batcher import ContinuousBatcher

    sc = _mixed_config(speculative, overlap)

    def one_run():
        b = ContinuousBatcher(params, cfg, sc)
        for r in _requests(cfg, MIXED_N_REQUESTS, max_new=MIXED_MAX_NEW):
            b.submit(r)
        t0 = time.perf_counter()
        stats = b.run(max_steps=10_000_000)
        jax.block_until_ready(b.caches)
        return stats, time.perf_counter() - t0

    one_run()                                   # warmup / compile
    best, stats = 0.0, None
    for _ in range(MIXED_REPS):
        st, dt = one_run()
        rate = st.decode_tokens / dt
        if rate > best:
            best, stats = rate, st
    tag = ("spec_overlap" if speculative and overlap
           else "serial" if not (speculative or overlap)
           else f"spec{int(speculative)}_ovl{int(overlap)}")
    return {
        "arch": cfg.name,
        "mode": f"mixed_{tag}",
        "chunk": 8,
        "requests": MIXED_N_REQUESTS,
        "completed": stats.completed,
        "tokens": stats.tokens,
        "decode_tokens_per_s": round(best, 2),
        "acceptance_rate": round(stats.acceptance_rate, 4),
        "spec_windows": stats.spec_windows,
        "overlap_rounds": stats.overlap_rounds,
        "occupancy": round(stats.occupancy, 4),
    }


def run() -> List[Dict]:
    import jax

    from repro.configs import get_reduced
    from repro.models import init_params

    cfg = get_reduced(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rows = [bench_mode(params, cfg, c) for c in CHUNKS]

    base = rows[0]
    for r in rows:
        r["speedup_vs_per_step"] = round(
            r["tokens_per_s"] / max(base["tokens_per_s"], 1e-9), 3)

    serial = bench_mixed(params, cfg, speculative=False, overlap=False)
    both = bench_mixed(params, cfg, speculative=True, overlap=True)
    ratio = both["decode_tokens_per_s"] / max(
        serial["decode_tokens_per_s"], 1e-9)
    for r in (serial, both):
        r["spec_overlap_ratio"] = round(ratio, 3)
    rows += [serial, both]
    return rows


def main() -> None:
    rows = run()
    path = write_csv("serving", rows)
    mixed = {r["mode"]: r for r in rows if r["mode"].startswith("mixed_")}
    ratio = mixed["mixed_spec_overlap"]["spec_overlap_ratio"]
    snap = {
        "bench": "serving",
        "arch": ARCH,
        "unix_time": time.time(),
        "acceptance_spec_overlap": ratio >= SPEC_OVERLAP_RATIO_FLOOR,
        "rows": rows,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    jpath = os.path.join(OUT_DIR, "BENCH_serving.json")
    with open(jpath, "w") as f:
        json.dump(snap, f, indent=2)
    print(f"{'mode':>18} {'tok/s':>8} {'disp/tok':>9} {'sync/tok':>9} "
          f"{'admit ms':>9} {'speedup':>8}")
    for r in rows:
        if r["mode"].startswith("mixed_"):
            print(f"{r['mode']:>18} {r['decode_tokens_per_s']:>8} "
                  f"{'accept=' + str(r['acceptance_rate']):>9} "
                  f"{'ovl=' + str(r['overlap_rounds']):>9} "
                  f"{'':>9} {r['spec_overlap_ratio']:>8}")
        else:
            print(f"{r['mode']:>18} {r['tokens_per_s']:>8} "
                  f"{r['dispatches_per_token']:>9} {r['syncs_per_token']:>9} "
                  f"{r['admit_ms']:>9} {r['speedup_vs_per_step']:>8}")
    # the overhaul's acceptance bar: ≤1 dispatch and ≤1 blocking sync per
    # T=8 decode tokens once chunks are ≥8 deep (adaptive sizing may run
    # shorter chunks under queue pressure, never more than one dispatch
    # per 8 tokens in steady state)
    for r in rows:
        if r["chunk"] >= 8 and "decode_dispatches_per_token" in r:
            assert r["decode_dispatches_per_token"] <= 1.0 / 8 + 1e-9, r
            assert r["syncs_per_token"] <= 1.0 / 8 + 1e-9, r
    # the speculative+overlap acceptance bar: both-on must beat both-off by
    # the floor on the mixed trace (same host, same run — gates exactly;
    # check_regression.py owns the same floor)
    assert ratio >= SPEC_OVERLAP_RATIO_FLOOR, (
        f"mixed-trace spec+overlap ratio {ratio} < "
        f"{SPEC_OVERLAP_RATIO_FLOOR} floor: {mixed}")
    print(f"wrote {path} and {jpath}")


if __name__ == "__main__":
    main()
