"""Shared-prefix KV cache benchmark — admission speedup vs prompt overlap.

Cloud prompt streams are heavily templated: many requests share a system
prompt / few-shot preamble per tenant namespace.  The prefix cache turns
that overlap into skipped prefill compute (the suffix program runs only the
uncached tail against gathered prefix pages) and deduplicated pages (one
physical copy, refcounted) — the serving analogue of the paper's two-stage
compile: reuse the heavy static artifact, recompile only the cheap dynamic
part.

Measured: an admission-dominated workload (``MAX_NEW = 2``: every request
is one prefill + one decode token) at 0 / 50 / 90 % prompt overlap, prefix
cache on vs off on the same host:

* ``admit_throughput`` — requests completed per second (admission-bound);
* ``prefill_tokens_skipped`` — prompt tokens served from cached pages
  instead of recomputed (the FLOPs-saved proxy; the true attention saving
  is super-linear in the skipped span);
* ``hit_rate`` — admissions that mapped >= 1 cached page.

At peak overlap a third ``cached_pallas`` leg runs the same workload with
``attn_impl="pallas"`` (prefix-context + paged-decode kernels); its
``kernel_tokens_ratio`` vs the XLA concat leg is gated >= 1.0 only when
compiled (``"interpret": false`` in the row) — interpret-mode throughput
measures the CPU emulator, not the kernel.

Acceptance (asserted here AND gated in ``check_regression.py``): at 90 %
overlap the cached path admits >= 1.3x faster than cold, skips >= 80 % of
prefill tokens, and hits on >= 80 % of admissions.

Emits ``experiments/bench/prefix.csv`` + ``BENCH_prefix.json``.

    PYTHONPATH=src python -m benchmarks.run prefix
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import OUT_DIR, write_csv

ARCH = "qwen3-0.6b"
SLOTS = 4
PROMPT_LEN = 128           # long prompts: admission cost is prefill-bound,
PAGE_SIZE = 8              # so the cached/cold ratio is headroom, not noise
MAX_NEW = 2                # admission-dominated: 1 prefill + 1 decode token
MAX_LEN = 160
N_REQUESTS = 64
OVERLAPS = [0.0, 0.5, 0.9]

ADMIT_RATIO_FLOOR = 1.3    # cached/cold admission throughput at 90% overlap
SKIPPED_FRAC_FLOOR = 0.8   # prefill tokens skipped at 90% overlap
HIT_RATE_FLOOR = 0.8       # admissions hitting the cache at 90% overlap
KERNEL_RATIO_FLOOR = 1.0   # compiled pallas never slower than the concat


def _requests(cfg, n: int, overlap: float, *, seed: int = 0):
    from repro.serving.batcher import Request

    rng = np.random.default_rng(seed)
    shared = int(round(PROMPT_LEN * overlap))
    head = rng.integers(1, cfg.vocab, size=shared).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(1, cfg.vocab,
                            size=PROMPT_LEN - shared).astype(np.int32)
        reqs.append(Request(rid=i, prompt=np.concatenate([head, tail]),
                            max_new=MAX_NEW, namespace="bench"))
    return reqs


def _bench(params, cfg, *, overlap: float, cached: bool,
           attn_impl: str = "xla") -> Dict:
    import jax

    from repro.serving import ServingConfig
    from repro.serving.batcher import ContinuousBatcher

    def batcher():
        return ContinuousBatcher(
            params, cfg,
            ServingConfig(slots=SLOTS, prompt_len=PROMPT_LEN,
                          max_len=MAX_LEN, chunk=4, paged=True,
                          page_size=PAGE_SIZE, prefix_cache=cached,
                          attn_impl=attn_impl))

    warm = batcher()                     # compile outside the timed region
    for r in _requests(cfg, 2 * SLOTS, overlap, seed=99):
        warm.submit(r)
    warm.run(max_steps=2000)

    b = batcher()
    reqs = _requests(cfg, N_REQUESTS, overlap)
    for r in reqs:
        b.submit(r)
    t0 = time.perf_counter()
    stats = b.run(max_steps=20_000)
    jax.block_until_ready(b.caches)
    dt = time.perf_counter() - t0
    assert stats.completed == N_REQUESTS, (overlap, cached, stats)

    from repro.kernels.common import default_interpret

    total_prompt_tokens = N_REQUESTS * PROMPT_LEN
    return {
        "arch": cfg.name,
        "overlap": overlap,
        "mode": "cached" if cached else "cold",
        "attn_impl": attn_impl,
        "interpret": bool(attn_impl == "pallas" and default_interpret()),
        "requests": N_REQUESTS,
        "seconds": round(dt, 4),
        "admit_throughput_rps": round(N_REQUESTS / dt, 2),
        "admit_latency_ms": round(1000.0 * dt / N_REQUESTS, 3),
        "tokens_per_s": round(stats.tokens / dt, 2),
        "prefix_hits": stats.prefix_hits,
        "hit_rate": round(stats.prefix_hits / N_REQUESTS, 4),
        "prefill_tokens_skipped": stats.prefill_tokens_skipped,
        "skipped_frac": round(
            stats.prefill_tokens_skipped / total_prompt_tokens, 4),
        "shared_pages": stats.shared_pages,
        "prefix_inserts": stats.prefix_inserts,
        "dispatches_per_token": round(stats.dispatches_per_token, 4),
    }


def run() -> List[Dict]:
    import jax

    from repro.configs import get_reduced
    from repro.models import init_params

    cfg = get_reduced(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rows = []
    for overlap in OVERLAPS:
        cold = _bench(params, cfg, overlap=overlap, cached=False)
        cached = _bench(params, cfg, overlap=overlap, cached=True)
        legs = [cold, cached]
        if overlap == OVERLAPS[-1]:
            # kernel leg at peak overlap only: cached admission through the
            # prefix-context kernel + paged-decode kernel vs the XLA concat
            pallas = _bench(params, cfg, overlap=overlap, cached=True,
                            attn_impl="pallas")
            pallas["mode"] = "cached_pallas"
            legs.append(pallas)
        for r in legs:
            r["admit_ratio_vs_cold"] = round(
                r["admit_throughput_rps"]
                / max(cold["admit_throughput_rps"], 1e-9), 3)
            r["kernel_tokens_ratio"] = round(
                r["tokens_per_s"] / max(cached["tokens_per_s"], 1e-9), 3)
        rows.extend(legs)
    return rows


def main() -> None:
    rows = run()
    path = write_csv("prefix", rows)
    at90 = {r["mode"]: r for r in rows if r["overlap"] == 0.9}
    ratio = at90["cached"]["admit_ratio_vs_cold"]
    skipped = at90["cached"]["skipped_frac"]
    hit_rate = at90["cached"]["hit_rate"]
    pallas = at90["cached_pallas"]
    kernel_ratio = pallas["kernel_tokens_ratio"]
    kernel_gated = not pallas["interpret"]
    snap = {
        "bench": "prefix",
        "arch": ARCH,
        "unix_time": time.time(),
        "prompt_len": PROMPT_LEN,
        "page_size": PAGE_SIZE,
        "max_new": MAX_NEW,
        "n_requests": N_REQUESTS,
        "admit_ratio_90": ratio,
        "skipped_frac_90": skipped,
        "hit_rate_90": hit_rate,
        "kernel_tokens_ratio": kernel_ratio,
        "kernel_interpret": pallas["interpret"],
        "admit_ratio_floor": ADMIT_RATIO_FLOOR,
        "skipped_frac_floor": SKIPPED_FRAC_FLOOR,
        "hit_rate_floor": HIT_RATE_FLOOR,
        "kernel_ratio_floor": KERNEL_RATIO_FLOOR,
        "acceptance_admit_ratio": ratio >= ADMIT_RATIO_FLOOR,
        "acceptance_skipped_frac": skipped >= SKIPPED_FRAC_FLOOR,
        "acceptance_hit_rate": hit_rate >= HIT_RATE_FLOOR,
        "acceptance_kernel": (not kernel_gated
                              or kernel_ratio >= KERNEL_RATIO_FLOOR),
        "rows": rows,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    jpath = os.path.join(OUT_DIR, "BENCH_prefix.json")
    with open(jpath, "w") as f:
        json.dump(snap, f, indent=2)
    print(f"{'overlap':>8} {'mode':>7} {'req/s':>8} {'ms/req':>8} "
          f"{'vs cold':>8} {'hit%':>6} {'skip%':>6} {'shared':>7}")
    for r in rows:
        print(f"{r['overlap']:>8} {r['mode']:>7} "
              f"{r['admit_throughput_rps']:>8} {r['admit_latency_ms']:>8} "
              f"{r['admit_ratio_vs_cold']:>8} {100*r['hit_rate']:>5.0f}% "
              f"{100*r['skipped_frac']:>5.0f}% {r['shared_pages']:>7}")
    assert ratio >= ADMIT_RATIO_FLOOR, snap
    assert skipped >= SKIPPED_FRAC_FLOOR, snap
    assert hit_rate >= HIT_RATE_FLOOR, snap
    # cached==cold is token-pinned by tests; here pin the perf contract
    assert pallas["hit_rate"] >= HIT_RATE_FLOOR, snap
    if kernel_gated:
        assert kernel_ratio >= KERNEL_RATIO_FLOOR, snap
    print(f"admission x{ratio} at 90% overlap (floor {ADMIT_RATIO_FLOOR}), "
          f"{100*skipped:.0f}% prefill tokens skipped "
          f"(floor {100*SKIPPED_FRAC_FLOOR:.0f}%), "
          f"hit rate {hit_rate} (floor {HIT_RATE_FLOOR})")
    print(f"wrote {path} and {jpath}")


if __name__ == "__main__":
    main()
