"""Paper Figure 7 — multi-task throughput under dynamic workload.

Three designs over T ∈ {1..16} concurrent tasks on one FPGA:

* **virtualized multi-core** (ours): the hypervisor re-allocates the 16-core
  pool evenly on every task arrival via the ~1 ms dynamic compiler; a tenant
  holding exactly one core gets the §6.3.3 single-core fastpath instructions.
* **static multi-core**: 16 small cores with immutable single-core programs —
  each task occupies one core; cores beyond T idle (the low-workload loser).
* **static single-core**: one 8192-parallelism core, time-division
  multiplexed — aggregate throughput is flat (the high-workload loser due to
  the non-linear resources→performance curve of Fig. 6).

The paper reports 1.07-1.69× over static single-core and 1.88-3.12× over
static multi-core across the dynamic-workload regime.
"""

from __future__ import annotations

import functools
from typing import Dict, List

from repro.core import Hypervisor, ResourcePool, TenantSpec

from .common import CNNS, multi_core_fps, single_core_fps, write_csv

POOL = 16


@functools.lru_cache(maxsize=None)
def _policy_split(tasks: int) -> tuple:
    """Core split decided by the hypervisor's ``even_split`` policy as the T
    tasks arrive one after another (the paper re-allocates the whole pool on
    every task arrival via the ~1 ms dynamic compiler)."""
    pool = ResourcePool(POOL)
    hv = Hypervisor(pool, policy="even_split")
    for i in range(tasks):
        hv.schedule_arrival(TenantSpec(f"task{i:02d}", requested_cores=POOL), at=0.0)
    hv.run(0.0)
    assert not hv.waiting_tenants()
    return tuple(lease.n_cores for lease in pool.leases.values())


def run() -> List[Dict]:
    rows: List[Dict] = []
    bands: Dict[str, List[float]] = {"vs_single": [], "vs_multi": []}
    bands_mod: Dict[str, List[float]] = {"vs_single": [], "vs_multi": []}
    for cnn in CNNS:
        fps1 = multi_core_fps(cnn, 1)                 # one small core
        tdm_total = single_core_fps(cnn, 8192)        # flat vs T
        for T in range(1, POOL + 1):
            virt = sum(multi_core_fps(cnn, k) for k in _policy_split(T))
            static_multi = T * fps1
            r_single = virt / tdm_total
            r_multi = virt / static_multi
            rows.append({
                "bench": "multi_task", "cnn": cnn, "tasks": T,
                "virtualized_fps": round(virt, 1),
                "static_multi_fps": round(static_multi, 1),
                "static_single_fps": round(tdm_total, 1),
                "x_vs_single": round(r_single, 2),
                "x_vs_multi": round(r_multi, 2),
            })
            if 1 < T < POOL:      # any partial load
                bands["vs_single"].append(r_single)
                bands["vs_multi"].append(r_multi)
            if 4 <= T <= 12:      # the paper's dynamic-workload regime
                bands_mod["vs_single"].append(r_single)
                bands_mod["vs_multi"].append(r_multi)
    rows.append({
        "bench": "multi_task_bands", "cnn": "all", "tasks": 0,
        "x_vs_single_min": round(min(bands["vs_single"]), 2),
        "x_vs_single_max": round(max(bands["vs_single"]), 2),
        "x_vs_multi_min": round(min(bands["vs_multi"]), 2),
        "x_vs_multi_max": round(max(bands["vs_multi"]), 2),
        "mod_vs_single_min": round(min(bands_mod["vs_single"]), 2),
        "mod_vs_single_max": round(max(bands_mod["vs_single"]), 2),
        "mod_vs_multi_min": round(min(bands_mod["vs_multi"]), 2),
        "mod_vs_multi_max": round(max(bands_mod["vs_multi"]), 2),
        "paper_vs_single": "1.07-1.69",
        "paper_vs_multi": "1.88-3.12",
    })
    return rows


def main() -> None:
    rows = run()
    path = write_csv("multi_task", rows)
    print("\n# Fig 7: multi-task throughput (resnet50 shown)")
    print("tasks  virt   static-multi  static-single(TDM)  x/single  x/multi")
    for r in rows:
        if r.get("cnn") == "resnet50" and r["bench"] == "multi_task":
            print(
                f"{r['tasks']:5d}  {r['virtualized_fps']:6.1f} {r['static_multi_fps']:12.1f} "
                f"{r['static_single_fps']:17.1f}  {r['x_vs_single']:8.2f}  {r['x_vs_multi']:7.2f}"
            )
    b = rows[-1]
    print(
        f"bands over all CNNs, 1<T<16: vs-single {b['x_vs_single_min']}-"
        f"{b['x_vs_single_max']} (paper {b['paper_vs_single']}), "
        f"vs-multi {b['x_vs_multi_min']}-{b['x_vs_multi_max']} "
        f"(paper {b['paper_vs_multi']})"
    )
    print(
        f"moderate load (4<=T<=12): vs-single {b['mod_vs_single_min']}-"
        f"{b['mod_vs_single_max']}, vs-multi {b['mod_vs_multi_min']}-"
        f"{b['mod_vs_multi_max']}"
    )
    print(f"csv -> {path}")


if __name__ == "__main__":
    main()
