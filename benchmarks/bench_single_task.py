"""Paper Table 3 / Figure 6 — single-task throughput of the virtualized
multi-core design under three tiling strategies (W / OC / optimized) vs. the
static single-core baseline, across computation parallelism 512..16×512.

Also reproduces the §6.3.2 MobileNet bandwidth ablation: MobileNet's
parameter/compute ratio makes the 128-bit small core bandwidth-bound; the
optimized multi-core loss collapses once the memory bandwidth is doubled.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import Strategy

from .common import (
    CNNS, PAPER_TABLE3_RESNET50, multi_core_fps, single_core_fps, write_csv,
)

CORE_COUNTS = (1, 2, 4, 8, 16)


def run() -> List[Dict]:
    rows: List[Dict] = []
    for cnn in CNNS:
        for k in CORE_COUNTS:
            fps_w = multi_core_fps(cnn, k, strategy=Strategy.WIDTH)
            fps_oc = multi_core_fps(cnn, k, strategy=Strategy.OC)
            fps_opt = multi_core_fps(cnn, k)          # per-layer optimized
            fps_single = single_core_fps(cnn, 512 * k)
            row = {
                "bench": "single_task", "cnn": cnn, "cores": k,
                "fps_W": round(fps_w, 1), "fps_OC": round(fps_oc, 1),
                "fps_opt": round(fps_opt, 1), "fps_single": round(fps_single, 1),
                "loss_opt_vs_single_pct": round(100 * (1 - fps_opt / fps_single), 2),
            }
            if cnn == "resnet50":
                for key, val in PAPER_TABLE3_RESNET50[k].items():
                    if key != "linear":
                        row[f"paper_{key}"] = val
            rows.append(row)

    # ---- MobileNet 2x-bandwidth ablation (§6.3.2) -------------------------
    for bw in (1.0, 2.0):
        losses = []
        for k in CORE_COUNTS:
            fps_opt = multi_core_fps("mobilenet", k, bw_factor=bw)
            fps_single = single_core_fps("mobilenet", 512 * k, bw_factor=bw)
            losses.append(1 - fps_opt / fps_single)
        rows.append({
            "bench": "mobilenet_bw_ablation", "cnn": "mobilenet",
            "bw_factor": bw,
            "avg_loss_pct": round(100 * sum(losses) / len(losses), 2),
            "paper_avg_loss_pct": 31.64 if bw == 1.0 else 5.33,
        })
    return rows


def main() -> None:
    rows = run()
    path = write_csv("single_task", rows)
    # compact console table for the ResNet50 row (the calibration target)
    print("\n# Table 3 (ResNet50): ours vs paper")
    print("cores  W(o/p)        OC(o/p)       opt(o/p)      single(o/p)")
    for r in rows:
        if r.get("cnn") == "resnet50" and r.get("bench") == "single_task":
            p = PAPER_TABLE3_RESNET50[r["cores"]]
            print(
                f"{r['cores']:5d}  {r['fps_W']:5.1f}/{p['W']:5.1f}  "
                f"{r['fps_OC']:6.1f}/{p['OC']:5.1f}  "
                f"{r['fps_opt']:6.1f}/{p['opt']:5.1f}  "
                f"{r['fps_single']:6.1f}/{p['single']:5.1f}"
            )
    for r in rows:
        if r.get("bench") == "mobilenet_bw_ablation":
            print(
                f"mobilenet bw x{r['bw_factor']:.0f}: avg opt loss "
                f"{r['avg_loss_pct']:.2f}% (paper {r['paper_avg_loss_pct']}%)"
            )
    print(f"csv -> {path}")


if __name__ == "__main__":
    main()
