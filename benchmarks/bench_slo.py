"""SLO-attainment benchmark — open-loop Poisson traffic over the hypervisor.

The paper's public-cloud claim is *guaranteed performance under sharing*.
This bench measures it the way a cloud operator would: four tenants with
per-request latency SLOs arrive on a shared 16-core pool and offer seeded
open-loop Poisson traffic (arrivals don't slow down because the system is
busy); a late high-priority burst tenant lands mid-run and leaves again.
Every reallocation policy sees the *identical* seeded event stream, and we
score each on

* **SLO attainment** — fraction of offered requests served within their SLO
  (unserved requests count against it), and
* **goodput** — SLO-met completions per second,

across a sweep of load multipliers (the attainment/goodput curves).

``latency_slo`` runs with backfill admission and preemptive eviction — the
full PR-3 scheduling stack; ``even_split`` (the paper's Fig.-7 elastic
scheme), ``priority``, and ``no_realloc`` (the seed engine) are baselines.

Acceptance (checked in ``main`` and recorded in ``BENCH_slo.json``):
``latency_slo`` attains strictly more than ``even_split`` and
``no_realloc`` at every load point.

    PYTHONPATH=src python -m benchmarks.run slo

``BENCH_SLO_SMOKE=1`` shrinks the sweep to one load point and a short
horizon (the CI smoke job).
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from repro.core import (
    Hypervisor,
    PoissonTraffic,
    ResourcePool,
    TenantSpec,
    VirtualEngine,
    fpga_small_core,
)
from repro.core.hypervisor import SLO_HEADROOM, queueing_latency
from repro.obs import percentile as _percentile

from .common import OUT_DIR, static_artifact, write_csv

POOL = 16
SMOKE = bool(int(os.environ.get("BENCH_SLO_SMOKE", "0")))
HORIZON = 12.0 if SMOKE else 30.0
LOADS = (1.0,) if SMOKE else (0.7, 1.0, 1.3)

#: tenant, model, priority, arrival, departure (None = stays), base
#: request rate (req/s at load x1.0), SLO calibration core count (the SLO is
#: set so that k cores meet it with headroom; see ``_scenario``), seed.
#: Demands are deliberately asymmetric — gold needs half the pool, silver
#: and bronze a couple of cores each — which is exactly what uniform
#: sharing cannot express: ``even_split`` caps gold at pool/T cores and
#: burns the surplus on tenants that don't need it.  When the
#: high-priority burst lands mid-run the pool saturates (13 + 4 > 16) and
#: the SLO policy sheds load from the lowest-priority tenant only.
TENANTS = (
    ("gold",   "resnet50",     2.0, 0.0,  None, 12.0, 8, 11),
    ("silver", "mobilenet",    2.0, 1.0,  None, 15.0, 2, 22),
    ("bronze", "vgg16",        1.0, 2.0,  None,  2.0, 3, 33),
    ("burst",  "inception_v3", 3.0, 12.0, 20.0,  6.0, 4, 44),
)

POLICIES = (
    ("latency_slo", dict(policy="latency_slo", admission="backfill",
                         preemptive=True)),
    ("even_split", dict(policy="even_split")),
    ("priority", dict(policy="priority")),
    ("no_realloc", dict(policy="no_realloc")),
)


def _scenario(load: float):
    """The shared scenario at one load multiplier: (tenant specs with SLOs
    calibrated against the engine's own latency model, per-tenant traffic).
    SLOs are load-independent; only the offered rates scale."""
    probe = VirtualEngine(ResourcePool(POOL), fpga_small_core())
    out = []
    for name, cnn, prio, t_on, t_off, rate, slo_k, seed in TENANTS:
        artifact = static_artifact(cnn)
        spec = TenantSpec(name, requested_cores=POOL, priority=prio,
                          artifact=artifact, open_loop=True)
        # target: the queue-adjusted latency at slo_k cores and base load
        # sits under headroom x SLO with a 1.35x margin — wide enough that
        # the Poisson wait *tail* (the mean-wait model underestimates p95 by
        # ~2-3x) still fits at slo_k cores, narrow enough that slo_k - 1
        # cores never do, so the policy's demand lands at exactly slo_k
        adjusted = queueing_latency(probe.estimate_latency(spec, slo_k), rate)
        spec.latency_slo = adjusted * 1.35 / SLO_HEADROOM
        spec.arrival_rate = rate * load
        traffic = PoissonTraffic(rate * load, seed=seed, start=t_on)
        out.append((spec, t_on, t_off, traffic))
    return out


def _run_policy(name: str, hv_kwargs: Dict, load: float) -> Dict:
    pool = ResourcePool(POOL)
    engine = VirtualEngine(pool, fpga_small_core())
    hv = Hypervisor(pool, executor=engine, **hv_kwargs)
    scenario = _scenario(load)
    records = []
    for spec, t_on, t_off, traffic in scenario:
        hv.schedule_arrival(spec, at=t_on)
        end = min(t_off, HORIZON) if t_off is not None else HORIZON
        records.extend(hv.open_traffic(spec.name, traffic, end,
                                       slo=spec.latency_slo))
        if t_off is not None:
            hv.schedule_departure(spec.name, at=t_off)
    metrics = hv.run(HORIZON)

    offered = len(records)
    served = [r for r in records if r.t_complete is not None]
    met = sum(1 for r in records if r.slo_met)
    latencies = [r.latency for r in served]
    per_tenant = {}
    for spec, _, _, _ in scenario:
        mine = [r for r in records if r.tenant == spec.name]
        per_tenant[spec.name] = round(
            sum(1 for r in mine if r.slo_met) / max(len(mine), 1), 4)
    return {
        "bench": "slo",
        "policy": name,
        "load": load,
        "horizon_s": HORIZON,
        "offered": offered,
        "served": len(served),
        "unserved": offered - len(served),
        "slo_met": met,
        "attainment": round(met / max(offered, 1), 4),
        "goodput_rps": round(met / HORIZON, 3),
        "p50_latency_ms": round(_percentile(latencies, 0.50) * 1e3, 2),
        "p95_latency_ms": round(_percentile(latencies, 0.95) * 1e3, 2),
        "p99_latency_ms": round(_percentile(latencies, 0.99) * 1e3, 2),
        "preemptions": len(hv.preemptions),
        "still_waiting": len(hv.waiting_tenants()),
        "completion_events": len(hv.completion_log),
        "ctx_switches": sum(m.ctx_switches for m in metrics.values()),
        "ctx_overhead_ms": round(
            sum(m.ctx_overhead for m in metrics.values()) * 1e3, 3),
        **{f"attain_{t}": v for t, v in per_tenant.items()},
    }


def run() -> List[Dict]:
    rows = []
    for load in LOADS:
        for name, kwargs in POLICIES:
            rows.append(_run_policy(name, dict(kwargs), load))
    return rows


def main() -> None:
    rows = run()
    path = write_csv("slo", rows)

    print(f"{'policy':>12} {'load':>5} {'offered':>8} {'attain':>7} "
          f"{'goodput':>8} {'p95 ms':>8} {'preempt':>8}")
    for r in rows:
        print(f"{r['policy']:>12} {r['load']:>5} {r['offered']:>8} "
              f"{r['attainment']:>7} {r['goodput_rps']:>8} "
              f"{r['p95_latency_ms']:>8} {r['preemptions']:>8}")

    # acceptance: the SLO-aware policy strictly beats the elastic and static
    # baselines on attainment at every load point of the same seeded trace
    by_load: Dict[float, Dict[str, float]] = {}
    for r in rows:
        by_load.setdefault(r["load"], {})[r["policy"]] = r["attainment"]
    ok = all(
        pols["latency_slo"] > pols["even_split"]
        and pols["latency_slo"] > pols["no_realloc"]
        for pols in by_load.values()
    )
    snap = {
        "bench": "slo",
        "unix_time": time.time(),
        "horizon_s": HORIZON,
        "loads": list(LOADS),
        "acceptance_latency_slo_strictly_best": ok,
        "rows": rows,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    jpath = os.path.join(OUT_DIR, "BENCH_slo.json")
    with open(jpath, "w") as f:
        json.dump(snap, f, indent=2)
    print(f"wrote {path} and {jpath}")
    assert ok, (
        "latency_slo must strictly beat even_split and no_realloc on SLO "
        f"attainment at every load: {by_load}"
    )
    print("acceptance OK: latency_slo strictly beats even_split and "
          "no_realloc at every load")


if __name__ == "__main__":
    main()
