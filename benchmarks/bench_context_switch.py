"""Paper Table 2 — two-stage compilation and context-switch cost.

Static compilation happens once at deployment; dynamic (re)compilation runs
on every hardware re-allocation and must stay ~1 ms.  Context switch cost
(Eq. 7) = T_recompile + T_transfer.  Measured as wall-clock over re-allocated
core counts {1, 2, 4, 8, 16}, exactly like the paper's Table 2.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import DynamicCompiler, StaticCompiler, CNN_WORKLOADS

from .common import CNNS, PAPER_TABLE2, small_core, write_csv

CORE_COUNTS = (1, 2, 4, 8, 16)
REPEATS = 7


def run() -> List[Dict]:
    rows: List[Dict] = []
    hw = small_core()
    for cnn in CNNS:
        wl = CNN_WORKLOADS[cnn]()
        t0 = time.perf_counter()
        art = StaticCompiler(hw, n_tiles=16).compile(wl)
        static_s = time.perf_counter() - t0
        dyn = DynamicCompiler(art)
        dyn_ms, ctx_ms, xfer_ms = [], [], []
        for k in CORE_COUNTS:
            best = None
            for _ in range(REPEATS):
                sch = dyn.compile(list(range(k)))
                cost = dyn.context_switch_cost(sch, hw)
                if best is None or cost["t_context"] < best["t_context"]:
                    best = cost
            dyn_ms.append(best["t_recompile"] * 1e3)
            xfer_ms.append(best["t_transfer"] * 1e3)
            ctx_ms.append(best["t_context"] * 1e3)
        paper = PAPER_TABLE2[cnn]
        rows.append({
            "bench": "context_switch", "cnn": cnn,
            "static_s": round(static_s, 3),
            "dynamic_ms_min": round(min(dyn_ms), 3),
            "dynamic_ms_max": round(max(dyn_ms), 3),
            "transfer_ms_min": round(min(xfer_ms), 4),
            "transfer_ms_max": round(max(xfer_ms), 4),
            "ctx_ms_min": round(min(ctx_ms), 3),
            "ctx_ms_max": round(max(ctx_ms), 3),
            "paper_static_s": paper["static_s"],
            "paper_dynamic_ms": f"{paper['dynamic_ms'][0]}-{paper['dynamic_ms'][1]}",
            "paper_ctx_ms": f"{paper['ctx_ms'][0]}-{paper['ctx_ms'][1]}",
            "static_over_dynamic": round(static_s * 1e3 / max(max(dyn_ms), 1e-9)),
        })
    return rows


def main() -> None:
    rows = run()
    path = write_csv("context_switch", rows)
    print("\n# Table 2: compilation + context switch (ours vs paper)")
    print(f"{'cnn':14s} {'static_s':>9s} {'dyn_ms':>13s} {'ctx_ms':>13s}  paper_ctx_ms  static/dyn")
    for r in rows:
        print(
            f"{r['cnn']:14s} {r['static_s']:9.3f} "
            f"{r['dynamic_ms_min']:.2f}-{r['dynamic_ms_max']:<7.2f} "
            f"{r['ctx_ms_min']:.2f}-{r['ctx_ms_max']:<7.2f}  "
            f"{r['paper_ctx_ms']:>11s}  {r['static_over_dynamic']:>8d}x"
        )
    print(f"csv -> {path}")


if __name__ == "__main__":
    main()
