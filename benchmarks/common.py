"""Shared helpers for the paper-reproduction benchmarks.

Every bench_*.py exposes ``run() -> list[dict]`` returning flat row dicts;
``benchmarks/run.py`` drives them all and emits CSV + a summary.
"""

from __future__ import annotations

import functools
import os
from typing import Dict, List, Optional

from repro.core import (
    CNN_WORKLOADS,
    DynamicCompiler,
    StaticArtifact,
    StaticCompiler,
    Strategy,
    allocate,
    fpga_core,
)

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")

#: Table 3 of the paper (ResNet50 fps) — the calibration/validation target.
PAPER_TABLE3_RESNET50 = {
    1: {"W": 6.8, "OC": 4.2, "opt": 6.8, "single": 7.6, "linear": 7.6},
    2: {"W": 12.4, "OC": 9.0, "opt": 13.1, "single": 14.3, "linear": 15.1},
    4: {"W": 21.9, "OC": 26.8, "opt": 27.2, "single": 28.5, "linear": 30.2},
    8: {"W": 29.6, "OC": 46.1, "opt": 53.5, "single": 53.6, "linear": 60.5},
    16: {"W": 33.3, "OC": 85.5, "opt": 98.9, "single": 84.4, "linear": 120.9},
}

#: Table 2 of the paper (ms).
PAPER_TABLE2 = {
    "vgg16": {"static_s": 44.8, "dynamic_ms": (0.4, 0.65), "ctx_ms": (0.45, 0.83)},
    "resnet50": {"static_s": 46.8, "dynamic_ms": (0.86, 1.06), "ctx_ms": (0.89, 1.21)},
    "inception_v3": {"static_s": 34.9, "dynamic_ms": (1.06, 1.5), "ctx_ms": (1.12, 1.70)},
    "mobilenet": {"static_s": 14.7, "dynamic_ms": (0.53, 0.67), "ctx_ms": (0.56, 0.82)},
}

CNNS = ("vgg16", "resnet50", "inception_v3", "mobilenet")


@functools.lru_cache(maxsize=64)
def small_core(bw_factor: float = 1.0):
    hw = fpga_core(parallelism=512, ddr_port_bits=128)
    return hw.with_bandwidth(bw_factor) if bw_factor != 1.0 else hw


@functools.lru_cache(maxsize=64)
def static_artifact(cnn: str, n_tiles: int = 16, bw_factor: float = 1.0) -> StaticArtifact:
    wl = CNN_WORKLOADS[cnn]()
    return StaticCompiler(small_core(bw_factor), n_tiles=n_tiles).compile(wl)


@functools.lru_cache(maxsize=64)
def single_core_artifact(cnn: str, parallelism: int, bw_factor: float = 1.0):
    """Static single-core design at a given parallelism (paper baseline):
    ddr ports scale with size up to the 4-bank budget."""
    ddr = min(128 * (parallelism // 512), 4 * 512)
    hw = fpga_core(parallelism=parallelism, ddr_port_bits=max(ddr, 128))
    if bw_factor != 1.0:
        hw = hw.with_bandwidth(bw_factor)
    wl = CNN_WORKLOADS[cnn]()
    art = StaticCompiler(hw, n_tiles=1).compile(wl)
    return art, hw


def multi_core_fps(cnn: str, k: int, *, strategy: Optional[Strategy] = None,
                   bw_factor: float = 1.0, fastpath: bool = True) -> float:
    """fps of one task on k small cores.  ``strategy=None`` = optimized
    per-layer choice (the paper's two-stage compiler); otherwise forced."""
    art = static_artifact(cnn, bw_factor=bw_factor)
    hw = small_core(bw_factor)
    if strategy is None:
        dyn = DynamicCompiler(art)
        sch = dyn.compile(list(range(k)), single_core_fastpath=fastpath)
        return 1.0 / sch.estimated_latency(hw)
    total = 0.0
    for li in range(len(art.workload)):
        lut = art.lut(li, strategy)
        _, ms = allocate(lut.cached, k, run_overhead=lut.run_overhead,
                         precomputed=lut.precomputed)
        total += ms + hw.sync_latency
    return 1.0 / total


def single_core_fps(cnn: str, parallelism: int, *, bw_factor: float = 1.0) -> float:
    art, hw = single_core_artifact(cnn, parallelism, bw_factor)
    sch = DynamicCompiler(art).compile([0])
    return 1.0 / sch.estimated_latency(hw)


def write_csv(name: str, rows: List[Dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    if not rows:
        return path
    keys = list(rows[0].keys())
    with open(path, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(k, "")) for k in keys) + "\n")
    return path
