"""Telemetry-plane benchmark — overhead gate + contract + sample artifacts.

Runs the same paged serving trace twice on the reduced qwen3-0.6b decode
path: once with telemetry disabled (``NULL_TRACER`` — the default every
layer gets) and once with a live :class:`repro.obs.Tracer` collecting
spans/instants from every batcher round.  The metrics registry backs
``BatcherStats`` in *both* legs (there is no registry-off mode — counters
ARE the stats now), so the measured delta is the tracer's marginal cost.

Acceptance (asserted here at generation time AND re-derived by
``check_regression.check_obs``):

* telemetry overhead < 3% decode tokens/s vs disabled (paired reps:
  both legs back-to-back per rep, min of the per-pair on/off overhead
  ratios — same host, one-sided noise, so the calmest pair is the
  stable estimator);
* the ≤1-dispatch/≤1-sync-per-chunk contract holds **with telemetry
  enabled** (dispatches ≤ chunks + prefills, syncs ≤ chunks + prefills);
* the enabled leg exports a valid Chrome-trace JSON (≥1 span, ≥1 track)
  and a registry snapshot — both written next to the CSV so CI uploads
  them as artifacts.

    PYTHONPATH=src python -m benchmarks.run obs
    BENCH_OBS_SMOKE=1 ... # CI: fewer requests/reps, same gates
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import OUT_DIR, write_csv

ARCH = "qwen3-0.6b"
SLOTS = 4
PROMPT_LEN = 8
SMOKE = bool(os.environ.get("BENCH_OBS_SMOKE"))
MAX_NEW = 96 if SMOKE else 256
N_REQUESTS = 8 if SMOKE else 12
# paired estimator: each rep runs both legs back-to-back (order
# alternating) and contributes one on/off ratio; host load that slows a
# whole pair cancels in the ratio, and the MIN overhead across reps means
# a single calm pair suffices.  The 3% ceiling is far tighter than the
# repo's ratio floors, so independent per-leg best-of is not robust here.
REPS = 7
OBS_OVERHEAD_CEILING = 0.03     # keep in sync with check_regression.py
# smoke (CI) runs on shared loaded runners: allow scheduler noise on top
# of the ceiling at generation time — the same 35% allowance CI's
# CHECK_TOLERANCE grants check_obs — while the committed full-mode
# snapshot stays strictly <3%
GEN_CEILING = OBS_OVERHEAD_CEILING * (1.35 if SMOKE else 1.0)


def _requests(cfg, n: int):
    from repro.serving.batcher import Request

    rng = np.random.default_rng(0)
    return [
        Request(rid=i,
                prompt=rng.integers(1, cfg.vocab,
                                    size=2 + i % (PROMPT_LEN - 2)
                                    ).astype(np.int32),
                max_new=MAX_NEW)
        for i in range(n)
    ]


def _config():
    from repro.serving import ServingConfig

    return ServingConfig(
        slots=SLOTS, prompt_len=PROMPT_LEN,
        max_len=PROMPT_LEN + MAX_NEW + 8, attn_impl="xla", chunk=8,
        paged=True, page_size=16, n_pages=192, overlap=True,
    )


def _one_run(params, cfg, sc, telemetry):
    import jax

    from repro.serving.batcher import ContinuousBatcher

    b = ContinuousBatcher(params, cfg, sc, telemetry=telemetry)
    for r in _requests(cfg, N_REQUESTS):
        b.submit(r)
    t0 = time.perf_counter()
    stats = b.run(max_steps=100_000)
    jax.block_until_ready(b.caches)
    return stats, time.perf_counter() - t0


def run() -> List[Dict]:
    import jax

    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.obs import Telemetry, Tracer

    cfg = get_reduced(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0))
    sc = _config()

    _one_run(params, cfg, sc, Telemetry())          # warmup / compile

    # paired reps: both legs back-to-back per rep (order alternating so
    # neither leg always runs into the same cache/GC state), one on/off
    # ratio per pair, MIN overhead across pairs — load that slows a whole
    # pair cancels in the ratio, and a single calm pair suffices
    best = {"off": 0.0, "on": 0.0}
    kept = {"off": None, "on": None}
    trace = None
    overhead = float("inf")
    for i in range(REPS):
        legs = ("off", "on") if i % 2 == 0 else ("on", "off")
        rate = {}
        for leg in legs:
            tel = Telemetry() if leg == "off" else Telemetry(
                tracer=Tracer(max_events=500_000))
            stats, dt = _one_run(params, cfg, sc, tel)
            rate[leg] = stats.decode_tokens / dt
            if rate[leg] > best[leg]:
                best[leg], kept[leg] = rate[leg], stats
                if leg == "on":
                    trace = (tel.tracer, tel.registry)
        overhead = min(overhead,
                       1.0 - rate["on"] / max(rate["off"], 1e-9))

    rows = []
    for leg in ("off", "on"):
        st = kept[leg]
        rows.append({
            "arch": cfg.name,
            "mode": f"telemetry_{leg}",
            "requests": N_REQUESTS,
            "completed": st.completed,
            "tokens": st.tokens,
            "decode_tokens_per_s": round(best[leg], 2),
            "chunks": st.chunks,
            "prefills": st.prefills,
            "dispatches": st.dispatches,
            "host_syncs": st.host_syncs,
            "device_pages_popped": st.device_pages_popped,
            "device_pages_pushed": st.device_pages_pushed,
            "fault_denied_slots": st.fault_denied_slots,
            "overhead_frac": round(overhead, 4),
        })

    # artifacts from the kept enabled leg: Perfetto trace + registry dump
    tracer, registry = trace
    os.makedirs(OUT_DIR, exist_ok=True)
    trace_path = tracer.export(os.path.join(OUT_DIR, "obs_trace.json"))
    metrics_path = registry.export(os.path.join(OUT_DIR, "obs_metrics.json"))
    rows[1]["trace_events"] = len(tracer.events)
    rows[1]["trace_tracks"] = len(tracer.tracks())
    rows[1]["trace_path"] = trace_path
    rows[1]["metrics_path"] = metrics_path
    return rows


def main() -> None:
    rows = run()
    path = write_csv("obs", rows)
    on = next(r for r in rows if r["mode"] == "telemetry_on")
    overhead = on["overhead_frac"]
    contract_ok = all(
        r["dispatches"] <= r["chunks"] + r["prefills"]
        and r["host_syncs"] <= r["chunks"] + r["prefills"]
        for r in rows)
    # the device counters must actually have ridden back: a paged run pops
    # at least one page per resident request inside the scan
    counters_ok = on["device_pages_popped"] > 0
    trace_ok = on["trace_events"] > 0 and on["trace_tracks"] >= 1
    snap = {
        "bench": "obs",
        "arch": ARCH,
        "unix_time": time.time(),
        "smoke": SMOKE,
        "overhead_frac": overhead,
        "overhead_ceiling": GEN_CEILING,
        "acceptance_overhead": overhead < GEN_CEILING,
        "acceptance_contract": bool(contract_ok),
        "acceptance_device_counters": bool(counters_ok),
        "acceptance_trace": bool(trace_ok),
        "rows": rows,
    }
    jpath = os.path.join(OUT_DIR, "BENCH_obs.json")
    with open(jpath, "w") as f:
        json.dump(snap, f, indent=2)
    print(f"{'mode':>14} {'tok/s':>9} {'disp':>6} {'syncs':>6} "
          f"{'pages±':>12} {'overhead':>9}")
    for r in rows:
        print(f"{r['mode']:>14} {r['decode_tokens_per_s']:>9} "
              f"{r['dispatches']:>6} {r['host_syncs']:>6} "
              f"{str(r['device_pages_popped']) + '/' + str(r['device_pages_pushed']):>12} "
              f"{r['overhead_frac']:>9}")
    assert contract_ok, (
        "≤1-dispatch/≤1-sync per chunk violated with telemetry enabled: "
        f"{rows}")
    assert counters_ok, f"device counters never rode back: {on}"
    assert trace_ok, f"exported trace is empty: {on}"
    assert overhead < GEN_CEILING, (
        f"telemetry overhead {overhead:.1%} >= "
        f"{GEN_CEILING:.1%} generation ceiling")
    print(f"wrote {path} and {jpath} (+ obs_trace.json, obs_metrics.json)")


if __name__ == "__main__":
    main()
