"""Paper Figure 5 — performance isolation in the public cloud scenario.

A fixed tenant holds x ∈ {100%, 75%, 50%, 25%} of the 16-core pool; the
remaining cores are occupied by other tenants in every proportion.  The
metric is the fixed tenant's throughput deviation (max-min)/max across the
co-tenant mixes — the paper's SDM design keeps it <1% (vs 5.5-13.1% for the
CUDA-MPS GPU baseline).

Also runs the TDM counter-example the paper argues against: a single
time-sliced core gives each tenant throughput that *depends on the number of
co-tenants*, i.e. no isolation at all.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import Hypervisor, ResourcePool, TenantSpec, VirtualEngine

from .common import small_core, static_artifact, write_csv

POOL = 16
HORIZON = 2.0  # simulated seconds


def _partitions(total: int, parts: int) -> List[List[int]]:
    """All compositions of ``total`` into ``parts`` positive integers."""
    if parts == 1:
        return [[total]]
    out = []
    for first in range(1, total - parts + 2):
        for rest in _partitions(total - first, parts - 1):
            out.append([first] + rest)
    return out


def fixed_tenant_fps(cnn: str, fixed_cores: int, others: List[int]) -> float:
    """All tenants arrive through the hypervisor's admission path; the
    ``no_realloc`` policy grants exactly the requested cores — the paper's
    public-cloud contract (a tenant's share never moves under co-tenancy)."""
    pool = ResourcePool(n_cores=POOL)
    eng = VirtualEngine(pool, small_core())
    hv = Hypervisor(pool, policy="no_realloc", executor=eng)
    art = static_artifact(cnn)
    hv.schedule_arrival(TenantSpec("fixed", fixed_cores, artifact=art), at=0.0)
    for i, n in enumerate(others):
        hv.schedule_arrival(TenantSpec(f"bg{i}", n, artifact=art), at=0.0)
    metrics = hv.run(HORIZON)
    return metrics["fixed"].throughput(HORIZON)


def run() -> List[Dict]:
    rows: List[Dict] = []
    for cnn in ("resnet50", "mobilenet"):
        for frac, fixed in ((1.0, 16), (0.75, 12), (0.5, 8), (0.25, 4)):
            free = POOL - fixed
            fps_list = []
            if free == 0:
                fps_list.append(fixed_tenant_fps(cnn, fixed, []))
            else:
                # co-tenant mixes: 1..3 background tenants in all proportions
                seen = set()
                for nbg in (1, 2, 3):
                    if free < nbg:
                        continue
                    for comp in _partitions(free, nbg):
                        key = tuple(sorted(comp))
                        if key in seen:
                            continue
                        seen.add(key)
                        fps_list.append(fixed_tenant_fps(cnn, fixed, comp))
            dev = (max(fps_list) - min(fps_list)) / max(fps_list) if len(fps_list) > 1 else 0.0
            rows.append({
                "bench": "isolation_sdm", "cnn": cnn,
                "fixed_pct": int(frac * 100), "fixed_cores": fixed,
                "mixes": len(fps_list),
                "fps_min": round(min(fps_list), 2), "fps_max": round(max(fps_list), 2),
                "deviation_pct": round(100 * dev, 3),
                "paper_gpu_deviation_pct": {100: 0.0, 75: "7.1-13.1", 50: "5.5-10.9", 25: "6.5-8.1"}[int(frac * 100)],
            })

    # ---- non-group-aligned leases: bounded arbiter crosstalk --------------
    # the paper's x values (75/50/25%) align to whole DDR banks, giving
    # structurally-zero crosstalk; odd-sized leases share a bank and see the
    # §4.2.2 arbiter penalty — must stay bounded under the paper's 1%.
    for fixed in (6, 10):
        free = POOL - fixed
        fps_list = [
            fixed_tenant_fps("resnet50", fixed, comp)
            for nbg in (1, 2)
            if free >= nbg
            for comp in _partitions(free, nbg)[:6]
        ]
        dev = (max(fps_list) - min(fps_list)) / max(fps_list)
        rows.append({
            "bench": "isolation_sdm_unaligned", "cnn": "resnet50",
            "fixed_pct": round(100 * fixed / POOL), "fixed_cores": fixed,
            "mixes": len(fps_list),
            "fps_min": round(min(fps_list), 2), "fps_max": round(max(fps_list), 2),
            "deviation_pct": round(100 * dev, 3),
            "paper_gpu_deviation_pct": "-",
        })

    # ---- TDM single-core counter-example ---------------------------------
    # one big core time-sliced: tenant throughput = single-core fps / n_tenants
    from .common import single_core_fps

    base = single_core_fps("resnet50", 8192)
    for n in (1, 2, 4):
        rows.append({
            "bench": "isolation_tdm", "cnn": "resnet50",
            "co_tenants": n - 1,
            "tenant_fps": round(base / n, 2),
            "deviation_vs_alone_pct": round(100 * (1 - 1 / n), 1),
        })
    return rows


def main() -> None:
    rows = run()
    path = write_csv("isolation", rows)
    print("\n# Fig 5: performance isolation (deviation of fixed tenant)")
    for r in rows:
        if r["bench"] == "isolation_sdm":
            print(
                f"{r['cnn']:10s} fixed={r['fixed_pct']:3d}% "
                f"({r['fixed_cores']:2d} cores) mixes={r['mixes']:2d} "
                f"deviation={r['deviation_pct']:.3f}%  "
                f"(paper GPU: {r['paper_gpu_deviation_pct']}%)"
            )
    for r in rows:
        if r["bench"] == "isolation_tdm":
            print(
                f"TDM 1x8192: {r['co_tenants']} co-tenants -> tenant fps "
                f"{r['tenant_fps']} (deviation {r['deviation_vs_alone_pct']}%)"
            )
    print(f"csv -> {path}")


if __name__ == "__main__":
    main()
