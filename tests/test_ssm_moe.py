"""SSM (Mamba-2 SSD) and MoE layer numerics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.kernels.ssd_scan.ref import ssd_naive
from repro.models import init_params
from repro.models.ssm import (
    causal_conv, causal_conv_step, ssd_chunked,
    ssm_decode, ssm_forward,
)

KEY = jax.random.PRNGKey(0)


class TestSSD:
    @pytest.mark.parametrize("chunk", [32, 64, 128])
    def test_chunked_equals_naive(self, chunk):
        ks = jax.random.split(KEY, 5)
        B, S, nh, hd, G, N = 2, 128, 4, 16, 2, 8
        x = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.1
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
        A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
        Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
        Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
        got = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
        want = ssd_naive(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_causal_conv_step_matches_full(self):
        kx, kw = jax.random.split(KEY)
        B, S, C, K = 2, 16, 8, 4
        x = jax.random.normal(kx, (B, S, C))
        w = jax.random.normal(kw, (K, C)) * 0.3
        full = causal_conv(x, w)
        state = jnp.zeros((B, K - 1, C))
        outs = []
        for t in range(S):
            y, state = causal_conv_step(x[:, t], state, w)
            outs.append(y)
        got = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=1e-5, atol=1e-5)

    def test_prefill_decode_continuity(self):
        """ssm_forward over S tokens then ssm_decode of token S+1 equals
        ssm_forward over S+1 tokens (last output)."""
        cfg = get_reduced("mamba2-370m")
        params = init_params(cfg, KEY)
        lp = jax.tree.map(lambda a: a[0], params["blocks"][0])["ssm"]
        B, S = 2, 33
        x = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32) * 0.1
        full, _ = ssm_forward(lp, x, cfg, return_state=True)
        y_pre, state = ssm_forward(lp, x[:, :-1], cfg, return_state=True)
        y_dec, _ = ssm_decode(lp, x[:, -1:], state, cfg)
        np.testing.assert_allclose(
            np.asarray(y_dec[:, 0], np.float32), np.asarray(full[:, -1], np.float32),
            rtol=5e-2, atol=5e-2,
        )


class TestMoE:
    def test_grouped_equals_dense_decode(self):
        """The capacity-bucketed (train) MoE path equals the dense decode
        path when capacity is unbounded — same experts, same weights."""
        from repro.models.moe import moe_dense_decode, moe_grouped

        cfg = get_reduced("mixtral-8x22b")
        params = init_params(cfg, KEY)
        lp = jax.tree.map(lambda a: a[0], params["blocks"][0])["moe"]
        x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.3
        S, k = 8, cfg.moe.top_k
        y_train, aux = moe_grouped(lp, x, cfg, capacity=S * k)   # no drops
        y_dec, _ = moe_dense_decode(lp, x, cfg)
        np.testing.assert_allclose(
            np.asarray(y_train, np.float32), np.asarray(y_dec, np.float32),
            rtol=5e-2, atol=5e-2,
        )
        assert float(aux) > 0.5

    def test_capacity_drops_are_bounded(self):
        """With capacity_factor-bounded buckets, outputs differ from the
        unbounded path only on the dropped fraction of tokens."""
        from repro.models.moe import moe_grouped

        cfg = get_reduced("mixtral-8x22b")
        params = init_params(cfg, KEY)
        lp = jax.tree.map(lambda a: a[0], params["blocks"][0])["moe"]
        x = jax.random.normal(KEY, (1, 32, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.3
        y_unbounded, _ = moe_grouped(lp, x, cfg, capacity=32 * cfg.moe.top_k)
        y_capped, _ = moe_grouped(lp, x, cfg, capacity=2)
        diff_tokens = (
            jnp.abs((y_unbounded - y_capped).astype(jnp.float32)).max(-1) > 1e-3
        ).sum()
        assert int(diff_tokens) < 32  # some tokens survive with exact output

    def test_shared_experts_always_active(self):
        """DeepSeek-style shared experts contribute even when the router
        sends everything elsewhere."""
        from repro.models.moe import moe_apply

        cfg = get_reduced("deepseek-moe-16b")
        assert cfg.moe.n_shared_experts > 0
        params = init_params(cfg, KEY)
        lp = jax.tree.map(lambda a: a[0], params["blocks"][0])["moe"]
        x = jax.random.normal(KEY, (1, 4, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.3
        y, _ = moe_apply(lp, x, cfg, decode=False)
        # zero the routed experts: output must still be nonzero (shared path)
        lp2 = dict(lp)
        lp2["wi"] = jnp.zeros_like(lp["wi"])
        lp2["wo"] = jnp.zeros_like(lp["wo"])
        y2, _ = moe_apply(lp2, x, cfg, decode=False)
        assert float(jnp.abs(y2.astype(jnp.float32)).max()) > 0

    def test_load_balance_loss_uniform_router(self):
        """A perfectly uniform router hits the theoretical minimum (≈1)."""
        from repro.models.moe import load_balance_loss

        E, T, K = 8, 1024, 2
        probs = jnp.full((T, E), 1.0 / E)
        # round-robin top-k assignment: perfectly balanced
        top_i = (jnp.arange(T * K) % E).reshape(T, K)
        loss = load_balance_loss(probs, top_i, E)
        assert float(loss) == pytest.approx(1.0, rel=1e-3)
