"""Fault-domain hypervisor: seeded injection, detection, displacement and
recovery — with zero cross-tenant blast radius.

Covers the chaos contract end to end at the core layer:

* :class:`FaultInjector` determinism (same seed ⇒ byte-identical schedule),
* event-queue tie-breaking (FAILURE drains before anything else at the
  same timestamp; RECOVERY lands before same-time ARRIVALs),
* :class:`ResourcePool` health bookkeeping (``mark_failed`` /
  ``check_health`` / ``n_healthy``),
* hypervisor displacement, backoff retry and the ``recovery_log``,
* ``CORE_SLOW`` visibility through the engine's straggler probes,
* preemption rollback when the pool shrinks mid-rollback (exact
  restoration where possible, loud invariant-clean abort otherwise).

Serving-side guards (NaN sentinel, watchdog, page-table audit) live in
``TestServingGuards`` at the bottom — they ride the real jax batcher.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import (
    EventKind, FaultInjector, FaultKind, FaultSpec, Hypervisor, ResourcePool,
    TenantSpec, VirtualEngine, fpga_small_core,
)
from repro.core.events import EventQueue
from repro.core.hrp import HRPError
from repro.models import init_params
from repro.serving.batcher import ContinuousBatcher, Request

KEY = jax.random.PRNGKey(0)


class ChaosExecutor:
    """RecordingExecutor variant for fault tests: pool-backed, records
    exec_fault/exec_recover deliveries, and can kill a core *inside*
    ``exec_evict`` — modelling hardware that dies mid-context-switch (the
    shrank-mid-rollback scenario)."""

    def __init__(self, pool, fail_on_evict=None):
        self.pool = pool
        self.fail_on_evict = fail_on_evict      # core id to kill, one-shot
        self.calls = []
        self.faults = []

    def advance(self, t):
        pass

    def exec_admit(self, spec, n_cores, at):
        self.calls.append(("admit", spec.name, n_cores))
        self.pool.alloc(spec.name, n_cores)

    def exec_resize(self, name, n_cores, at, mode):
        self.calls.append(("resize", name, n_cores))
        self.pool.resize(name, n_cores)

    def exec_remove(self, name, at):
        self.calls.append(("remove", name))
        self.pool.release(name)

    def exec_evict(self, name, at):
        self.calls.append(("evict", name))
        self.pool.release(name)
        if self.fail_on_evict is not None:
            self.pool.mark_failed(self.fail_on_evict)
            self.fail_on_evict = None

    def exec_kv_resize(self, name, pages, at):
        self.calls.append(("kv", name, pages))

    def exec_fault(self, fault, at):
        self.faults.append(("fault", fault.kind, fault.core, at))

    def exec_recover(self, fault, at):
        self.faults.append(("recover", fault.kind, fault.core, at))


# ---------------------------------------------------------------------------
# injector determinism
# ---------------------------------------------------------------------------

class TestFaultInjector:
    def _inj(self, seed=7):
        return FaultInjector(16, seed=seed, death_rate=0.5, slow_rate=0.3,
                             corrupt_rate=0.2, n_kv_pages=64)

    def test_same_seed_identical_schedule(self):
        a, b = self._inj().schedule(50.0), self._inj().schedule(50.0)
        assert a == b                     # FaultSpec is frozen -> field eq
        assert len(a) > 0

    def test_schedule_is_pure(self):
        inj = self._inj()
        assert inj.schedule(50.0) == inj.schedule(50.0)

    def test_different_seed_differs(self):
        assert self._inj(seed=7).schedule(50.0) != \
            self._inj(seed=8).schedule(50.0)

    def test_time_order_and_fids(self):
        sched = self._inj().schedule(50.0)
        times = [f.time for f in sched]
        assert times == sorted(times)
        assert [f.fid for f in sched] == list(range(len(sched)))
        for f in sched:
            if f.kind is FaultKind.KV_CORRUPT:
                assert f.core is None and 0 <= f.page < 64
            else:
                assert f.page is None and 0 <= f.core < 16

    def test_validation(self):
        with pytest.raises(ValueError):
            FaultInjector(0, seed=1)
        with pytest.raises(ValueError):
            FaultInjector(4, death_rate=-0.1)
        with pytest.raises(ValueError):
            FaultInjector(4, corrupt_rate=0.5)     # no n_kv_pages

    def test_inject_schedules_failures_and_recoveries(self):
        inj = FaultInjector(8, seed=3, death_rate=0.4, slow_rate=0.4,
                            repair_after=1.0)
        q = EventQueue()
        sched = inj.inject(q, 20.0)
        events = []
        while q:
            events.append(q.pop())
        fails = [e for e in events if e.kind is EventKind.FAILURE]
        recs = [e for e in events if e.kind is EventKind.RECOVERY]
        assert [e.payload["fault"] for e in fails] == sched
        expected_recs = sum(1 for f in sched
                            if f.duration is not None
                            and f.time + f.duration <= 20.0)
        assert len(recs) == expected_recs
        times = [e.time for e in events]
        assert times == sorted(times)


# ---------------------------------------------------------------------------
# event-queue tie-breaking
# ---------------------------------------------------------------------------

class TestFailureEventOrdering:
    def test_same_timestamp_kind_rank(self):
        """At one timestamp the queue drains in the documented order:
        FAILURE first (capacity shrinks before anyone plans over it),
        RECOVERY after RECONFIG but before ARRIVAL (repaired cores are
        placeable for same-instant arrivals)."""
        q = EventQueue()
        kinds = [EventKind.ARRIVAL, EventKind.PROBE, EventKind.RECOVERY,
                 EventKind.REQUEST, EventKind.FAILURE, EventKind.COMPLETION,
                 EventKind.RECONFIG, EventKind.DEPARTURE]
        for k in kinds:                    # deliberately shuffled insert
            q.schedule(k, 1.0)
        got = [q.pop().kind for _ in range(len(kinds))]
        assert got == [EventKind.FAILURE, EventKind.DEPARTURE,
                       EventKind.COMPLETION, EventKind.RECONFIG,
                       EventKind.RECOVERY, EventKind.ARRIVAL,
                       EventKind.REQUEST, EventKind.PROBE]

    def test_failure_beats_simultaneous_arrival_in_run(self):
        """A FAILURE and an ARRIVAL at the same instant: the arrival must
        be planned over the already-shrunk pool (it cannot land on the
        dying core)."""
        pool = ResourcePool(4)
        hv = Hypervisor(pool, executor=ChaosExecutor(pool))
        hv.schedule_arrival(TenantSpec("a", 4, min_cores=4), at=1.0)
        hv.schedule_fault(FaultSpec(time=1.0, kind=FaultKind.CORE_DEATH,
                                    fid=0, core=0), recovery=False)
        hv.run(2.0)
        assert hv.allocation() == {}           # 3 healthy < min_cores=4
        assert hv.waiting_tenants() == ["a"]
        pool.check_health()


# ---------------------------------------------------------------------------
# pool health bookkeeping
# ---------------------------------------------------------------------------

class TestPoolHealth:
    def test_mark_failed_excludes_from_placement(self):
        pool = ResourcePool(4)
        assert pool.mark_failed(0) is None        # free core: no owner
        assert pool.n_healthy == 3
        assert pool.failed_cores() == [0]
        with pytest.raises(HRPError):
            pool.alloc("b", 4)                    # only 3 placeable
        pool.alloc("b", 3)
        assert 0 not in pool.lease_of("b").cores
        pool.release("b")
        pool.mark_recovered(0)
        assert pool.n_healthy == 4
        pool.alloc("c", 4)                        # back to full capacity

    def test_mark_failed_returns_owner(self):
        pool = ResourcePool(4)
        pool.alloc("a", 2)
        core = pool.lease_of("a").cores[0]
        assert pool.mark_failed(core) == "a"
        with pytest.raises(HRPError, match="a"):
            pool.check_health()                   # lease on dead hardware
        pool.mark_recovered(core)
        pool.check_health()

    def test_out_of_range_core_raises(self):
        pool = ResourcePool(4)
        with pytest.raises(HRPError):
            pool.mark_failed(4)
        with pytest.raises(HRPError):
            pool.mark_recovered(-1)

    def test_fault_domains_follow_ddr_groups(self):
        pool = ResourcePool(16)
        g = pool.cores_per_ddr
        for c in range(16):
            assert pool.fault_domain(c) == c // g
        assert pool.domain_cores(1) == list(range(g, 2 * g))


# ---------------------------------------------------------------------------
# hypervisor displacement + recovery
# ---------------------------------------------------------------------------

class TestHypervisorFaults:
    def test_core_death_displaces_owner_only(self):
        """Blast radius: the failed core's owner is re-placed on healthy
        cores inside the FAILURE event; the neighbour keeps its lease."""
        pool = ResourcePool(8)
        hv = Hypervisor(pool, executor=ChaosExecutor(pool))
        hv.admit(TenantSpec("a", 4), at=0.0)
        hv.admit(TenantSpec("b", 4, arrived_at=0.1), at=0.1)
        dead = pool.lease_of("a").cores[0]
        hv.fail_core(dead, at=1.0)
        assert "a" in hv.specs                     # re-placed immediately
        assert dead not in pool.lease_of("a").cores
        assert "b" in hv.specs
        assert hv.recovery_log[-1]["tenant"] == "a"
        assert hv.recovery_log[-1]["recovery_latency"] == 0.0
        assert hv.fault_log[-1].core == dead
        pool.check_health()

    def test_free_core_death_touches_nobody(self):
        pool = ResourcePool(8)
        hv = Hypervisor(pool, executor=ChaosExecutor(pool))
        hv.admit(TenantSpec("a", 4), at=0.0)
        before = hv.allocation()
        free = pool.free_cores()[0]
        hv.fail_core(free, at=1.0)
        assert hv.allocation() == before
        assert hv.recovery_log == []

    def test_displaced_tenant_parks_with_backoff(self):
        pool = ResourcePool(4)
        hv = Hypervisor(pool, executor=ChaosExecutor(pool))
        hv.admit(TenantSpec("a", 4, min_cores=4), at=0.0)
        dead = pool.lease_of("a").cores[0]
        hv.fail_core(dead, at=1.0)
        assert hv.allocation() == {}               # 3 healthy < floor 4
        assert hv.waiting_tenants() == ["a"]       # head of the queue
        assert hv._retry_backoff["a"] == pytest.approx(
            2 * hv.fault_retry_backoff)            # doubled at schedule
        hv.run(1.5)                                # retries fire, keep failing
        assert hv._retry_backoff["a"] > 2 * hv.fault_retry_backoff
        hv.recover_core(dead, at=2.0)
        assert hv.allocation() == {"a": 4}
        rec = hv.recovery_log[-1]
        assert rec["failed_at"] == 1.0 and rec["recovered_at"] == 2.0
        assert rec["recovery_latency"] == pytest.approx(1.0)

    def test_timed_fault_auto_recovers(self):
        pool = ResourcePool(4)
        hv = Hypervisor(pool, executor=ChaosExecutor(pool))
        hv.admit(TenantSpec("a", 4, min_cores=4), at=0.0)
        dead = pool.lease_of("a").cores[0]
        hv.fail_core(dead, at=1.0, duration=0.5)
        hv.run(3.0)                                # RECOVERY event at 1.5
        assert hv.allocation() == {"a": 4}
        assert hv.recovery_log[-1]["recovery_latency"] == pytest.approx(0.5)

    def test_kv_corrupt_delivered_to_executor(self):
        pool = ResourcePool(4, n_kv_pages=32)
        ex = ChaosExecutor(pool)
        hv = Hypervisor(pool, executor=ex)
        hv.admit(TenantSpec("a", 2), at=0.0)
        before = hv.allocation()
        hv.schedule_fault(FaultSpec(time=1.0, kind=FaultKind.KV_CORRUPT,
                                    fid=0, page=3), recovery=False)
        hv.run(2.0)
        assert ("fault", FaultKind.KV_CORRUPT, None, 1.0) in ex.faults
        assert hv.allocation() == before           # no placement change

    def test_injected_run_is_deterministic(self):
        def run_once():
            pool = ResourcePool(8)
            ex = ChaosExecutor(pool)
            hv = Hypervisor(pool, executor=ex)
            hv.admit(TenantSpec("a", 8, min_cores=1), at=0.0)
            inj = FaultInjector(8, seed=5, death_rate=0.6, slow_rate=0.4,
                                repair_after=0.8)
            inj.inject(hv.queue, 6.0)
            hv.run(6.0)
            return (
                [(f.fid, f.kind, f.time, f.core) for f in hv.fault_log],
                [tuple(sorted(r.items())) for r in hv.recovery_log],
                hv.allocation(),
                ex.faults,
            )

        assert run_once() == run_once()
        assert len(run_once()[0]) > 0              # faults actually fired


# ---------------------------------------------------------------------------
# CORE_SLOW -> straggler probes
# ---------------------------------------------------------------------------

class TestSlowCoreFaults:
    def test_exec_fault_sets_and_clears_slowdown(self):
        eng = VirtualEngine(ResourcePool(8), fpga_small_core())
        f = FaultSpec(time=1.0, kind=FaultKind.CORE_SLOW, fid=0, core=3,
                      factor=3.0, duration=2.0)
        eng.exec_fault(f, 1.0)
        assert eng.core_slowdown[3] == 3.0
        eng.exec_fault(dataclasses.replace(f, factor=2.0), 1.5)
        assert eng.core_slowdown[3] == 3.0         # escalation keeps the max
        eng.exec_recover(f, 3.0)
        assert 3 not in eng.core_slowdown

    def test_injected_slowdown_trips_straggler_probe(self, resnet_artifact):
        """The detection path for CORE_SLOW is the paper's straggler probe:
        the injected fault shows up in core_slowdown, the next probe
        rebalances the tenant's tiles, and the repair clears the state."""
        pool = ResourcePool(16)
        eng = VirtualEngine(pool, fpga_small_core(), straggler_threshold=1.3)
        hv = Hypervisor(pool, policy="no_realloc", executor=eng,
                        probe_interval=0.05)
        hv.schedule_arrival(TenantSpec("t", 8, artifact=resnet_artifact),
                            at=0.0)
        hv.schedule_fault(FaultSpec(time=0.1, kind=FaultKind.CORE_SLOW,
                                    fid=0, core=0, factor=3.0, duration=0.3))
        metrics = hv.run(0.6)
        assert metrics["t"].rebalances >= 1
        assert eng.core_slowdown == {}             # RECOVERY cleared it


# ---------------------------------------------------------------------------
# preemption rollback under a shrinking pool (satellite: kv-lease rollback)
# ---------------------------------------------------------------------------

class TestRollbackUnderShrink:
    def test_rollback_aborts_loudly_when_pool_shrank(self):
        """A core dies during the eviction context-switch, so the evicted
        victim's exact lease no longer fits.  The rollback must abort
        LOUDLY (chained HRPError) while leaving every invariant clean: the
        victim parks at the wait-queue head, nothing holds a partial core
        or kv lease."""
        pool = ResourcePool(n_cores=4, n_kv_pages=100)
        ex = ChaosExecutor(pool, fail_on_evict=0)
        hv = Hypervisor(pool, executor=ex, preemptive=True)
        assert hv.admit(TenantSpec("low", 4, min_cores=4, priority=1.0,
                                   requested_kv_pages=40, min_kv_pages=40),
                        at=0.0)
        assert pool.kv_lease_of("low") == 40
        with pytest.raises(HRPError, match="rollback could not restore"):
            hv.admit(TenantSpec("hi", 4, min_cores=4, priority=2.0,
                                arrived_at=1.0), at=1.0)
        # loud, but clean: victim parked, zero partial state
        assert hv.waiting_tenants()[0] == "low"
        assert "low" in hv._displaced_at           # recovery clock running
        assert hv.allocation() == {}
        assert pool.kv_leases == {}
        pool.check_isolation()
        pool.check_kv_quota()
        pool.check_health()

    def test_rollback_restores_exactly_on_healthy_remainder(self):
        """If the shrunk pool still fits the victim's exact pre-eviction
        lease (cores AND kv pages), the rollback restores it precisely —
        the victim pays the context switch but keeps its resources."""
        pool = ResourcePool(n_cores=4, n_kv_pages=100)
        ex = ChaosExecutor(pool, fail_on_evict=3)  # kill a FREE core
        hv = Hypervisor(pool, executor=ex, preemptive=True)
        assert hv.admit(TenantSpec("low", 2, min_cores=2, priority=1.0,
                                   requested_kv_pages=30, min_kv_pages=30),
                        at=0.0)
        kv_before = pool.kv_lease_of("low")
        assert kv_before == 30
        assert not hv.admit(TenantSpec("hi", 4, min_cores=4, priority=2.0,
                                       arrived_at=1.0), at=1.0)
        assert hv.allocation() == {"low": 2}       # exact core restoration
        assert pool.kv_lease_of("low") == kv_before
        assert 3 not in pool.lease_of("low").cores
        assert hv.waiting_tenants() == ["hi"]
        pool.check_isolation()
        pool.check_kv_quota()
        pool.check_health()

    def test_recovered_core_readmits_rollback_casualty(self):
        """After a loud rollback abort, repairing the core lets the parked
        victim re-place through the normal recovery path, stamping the
        recovery_log."""
        pool = ResourcePool(n_cores=4, n_kv_pages=100)
        ex = ChaosExecutor(pool, fail_on_evict=0)
        hv = Hypervisor(pool, executor=ex, preemptive=True)
        hv.admit(TenantSpec("low", 4, min_cores=4, priority=1.0), at=0.0)
        with pytest.raises(HRPError):
            hv.admit(TenantSpec("hi", 4, min_cores=4, priority=2.0,
                                arrived_at=1.0), at=1.0)
        hv.recover_core(0, at=2.0)
        assert hv.allocation() == {"low": 4}
        assert hv.recovery_log[-1]["tenant"] == "low"
        assert hv.recovery_log[-1]["recovery_latency"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# serving-side guards: NaN sentinel, watchdog, page-table audit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    return cfg, init_params(cfg, KEY)


def _prompts(cfg, n, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, size=1 + i % 6).astype(np.int32)
            for i in range(n)]


def _batcher(params, cfg, **kw):
    kw.setdefault("slots", 4)
    kw.setdefault("prompt_len", 8)
    kw.setdefault("max_len", 64)
    kw.setdefault("chunk", 4)
    return ContinuousBatcher(params, cfg, **kw)


def _submit(b, cfg, n, max_new=8, seed=3):
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(_prompts(cfg, n, seed=seed))]
    for r in reqs:
        b.submit(r)
    return reqs


def _poison_caches(b):
    """Flip every float cache value to NaN — the bit-flip fault model.  The
    sentinel must catch the poisoned logits before any token is emitted."""
    b.caches = jax.tree_util.tree_map(
        lambda x: jnp.full_like(x, jnp.nan)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, b.caches)


def _quarantine_partition(b):
    """Mapped + free + cache-shared + quarantined partitions the pool, and
    a quarantined page is neither free nor mapped."""
    tab = np.asarray(b.pages.table)
    free = set(np.asarray(b.pages.free)[: int(b.pages.free_top)].tolist())
    mapped = set(tab[tab >= 0].tolist())
    shared = b.kv_pool.shared_ids()
    quarantined = b._quarantined
    assert not (quarantined & free), "quarantined page back on free stack"
    assert not (quarantined & mapped), "quarantined page still mapped"
    assert sorted(mapped | free | shared | quarantined) == \
        list(range(b.n_pages)), "pool partition violated"


class TestServingGuards:
    def test_nan_sentinel_requeues_dense(self, qwen):
        cfg, params = qwen
        b = _batcher(params, cfg)
        reqs = _submit(b, cfg, 4)
        for _ in range(2):
            b.step()
        _poison_caches(b)                      # every active slot goes bad
        b.run(max_steps=4000)
        assert b.stats.poisoned_slots >= 1
        assert all(len(r.out) > 0 for r in reqs)   # self-healed to completion
        assert not any(b.slot_req)

    def test_nan_sentinel_requeues_paged(self, qwen):
        cfg, params = qwen
        b = _batcher(params, cfg, paged=True, page_size=8)
        reqs = _submit(b, cfg, 4)
        for _ in range(2):
            b.step()
        _poison_caches(b)
        b.run(max_steps=4000)
        assert b.stats.poisoned_slots >= 1
        assert all(len(r.out) > 0 for r in reqs)
        # poisoned slots were recycled on-device: no page leaked
        assert int(b.pages.free_top) == b.n_pages
        b.kv_pool.check()

    def test_watchdog_trips_on_stalled_chunk(self, qwen):
        cfg, params = qwen
        b = _batcher(params, cfg, clock=lambda: 0.0, watchdog_s=0.5)
        reqs = _submit(b, cfg, 2)
        b.step()                               # healthy step: no trip
        assert b.stats.watchdog_trips == 0
        b.inject_stall(0, 1.0)                 # next dispatch wedges 1s
        b.step()
        assert b.stats.watchdog_trips == 1
        assert b.slot_req[0] is None           # stuck slot deactivated
        b.run(max_steps=4000)                  # requeued work still finishes
        assert all(len(r.out) > 0 for r in reqs)

    def test_audit_quarantines_out_of_range_pid(self, qwen):
        cfg, params = qwen
        b = _batcher(params, cfg, paged=True, page_size=8, audit=True)
        reqs = _submit(b, cfg, 4, max_new=32)  # long enough to outlive inject
        for _ in range(2):
            b.step()
        assert any(b.slot_req)                 # corruption hits a live slot
        b.inject_kv_corruption(0)              # out-of-range pid in slot 0
        b.step()                               # audit rides the next sync
        assert b.stats.audit_repairs >= 1
        b.run(max_steps=4000)
        assert all(len(r.out) > 0 for r in reqs)
        _quarantine_partition(b)

    def test_audit_quarantines_double_mapped_page(self, qwen):
        cfg, params = qwen
        b = _batcher(params, cfg, paged=True, page_size=8, audit=True)
        reqs = _submit(b, cfg, 4, max_new=32)
        for _ in range(2):
            b.step()
        assert b.slot_req[0] is not None and b.slot_req[1] is not None
        row1 = np.asarray(b.pages.table)[1]
        stolen = int(row1[row1 >= 0][0])       # a page slot 1 really owns
        b.inject_kv_corruption(0, pid=stolen)  # slot 0 claims it too
        b.step()
        assert b.stats.audit_repairs >= 2      # both mappings cleared
        assert stolen in b._quarantined
        assert b.stats.quarantined_pages >= 1
        b.run(max_steps=4000)
        assert all(len(r.out) > 0 for r in reqs)
        _quarantine_partition(b)

    def test_audit_exempts_shared_prefix_pages(self, qwen):
        """Cache-owned prefix pages are legitimately multi-mapped; the
        audit must not mistake them for corruption."""
        cfg, params = qwen
        b = _batcher(params, cfg, prompt_len=32, paged=True, page_size=8,
                     prefix_cache=True, audit=True)
        rng = np.random.default_rng(0)
        head = rng.integers(1, cfg.vocab, size=28).astype(np.int32)
        reqs = [Request(rid=i, prompt=np.concatenate(
                    [head, rng.integers(1, cfg.vocab, size=4)
                     .astype(np.int32)]), max_new=6)
                for i in range(6)]
        for r in reqs:
            b.submit(r)
        b.run(max_steps=4000)
        assert all(len(r.out) > 0 for r in reqs)
        assert b.stats.audit_repairs == 0      # shared pages left alone
        assert b.stats.quarantined_pages == 0
