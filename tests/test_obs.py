"""Telemetry plane: metrics registry, log-bucketed histograms, Chrome-trace
tracer, registry-backed BatcherStats views, device counters riding the
per-chunk sync, per-tenant SLO quantiles, and injectable clocks.

Layered like the module: pure-python registry/tracer first (no JAX), then
the serving integration (device counters, ≤1-dispatch/≤1-sync contract
with telemetry enabled, trace export from a real run).
"""

import json
import math

import numpy as np
import pytest

from repro.obs import (
    Histogram, MetricsRegistry, NULL_TRACER, Telemetry, Tracer, percentile,
)
from repro.serving.batcher import BatcherStats, _STATS_FIELDS


# ---------------------------------------------------------------------------
# percentile + histogram (pure python)
# ---------------------------------------------------------------------------

class TestPercentile:
    def test_empty_is_nan(self):
        assert math.isnan(percentile([], 0.5))

    def test_matches_sorted_index(self):
        vals = [5.0, 1.0, 3.0, 2.0, 4.0]
        assert percentile(vals, 0.0) == 1.0
        assert percentile(vals, 0.5) == 3.0
        assert percentile(vals, 0.99) == 5.0
        assert percentile(vals, 1.0) == 5.0      # clamped to last element

    def test_single_value(self):
        assert percentile([7.0], 0.5) == 7.0
        assert percentile([7.0], 0.99) == 7.0


class TestHistogram:
    def test_counts_and_extremes_exact(self):
        h = Histogram()
        for v in (0.5, 2.0, 8.0, 0.25):
            h.record(v)
        assert h.count == 4
        assert h.total == pytest.approx(10.75)
        assert h.min == 0.25 and h.max == 8.0
        assert h.mean == pytest.approx(10.75 / 4)

    def test_quantile_relative_error_bounded(self):
        """Log-bucketed quantiles are within one bucket (base 1.08 → ~8%
        relative error) of the exact percentile on a lognormal sample."""
        rng = np.random.default_rng(0)
        vals = np.exp(rng.normal(0.0, 1.5, size=5000)).tolist()
        h = Histogram()
        for v in vals:
            h.record(v)
        for q in (0.5, 0.95, 0.99):
            exact = percentile(vals, q)
            assert abs(h.quantile(q) - exact) / exact < 0.09, q

    def test_quantile_clamped_to_observed_range(self):
        h = Histogram()
        h.record(3.0)
        assert h.quantile(0.0) == 3.0
        assert h.quantile(0.99) == 3.0

    def test_nonpositive_values_bucket(self):
        h = Histogram()
        h.record(0.0)
        h.record(-1.0)
        h.record(2.0)
        assert h.count == 3
        assert h.min == -1.0
        assert h.quantile(0.0) == -1.0           # zero-bucket rank 0

    def test_empty_quantile_nan(self):
        assert math.isnan(Histogram().quantile(0.5))

    def test_quantiles_keys(self):
        h = Histogram()
        h.record(1.0)
        assert set(h.quantiles()) == {"p50", "p95", "p99"}


class TestRegistry:
    def test_counter_gauge_histogram_roundtrip(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("req", "a").inc(3)
        reg.counter("req", "b").inc()
        reg.gauge("occ").set(0.5)
        reg.histogram("lat", "a").record(0.2)
        assert reg.counter("req", "a").value == 3   # get-or-create, same obj
        assert sorted(reg.labels("req")) == ["a", "b"]
        snap = reg.snapshot()
        path = reg.export(str(tmp_path / "m.json"))
        assert json.load(open(path)) == snap
        assert snap["counters"]["req{a}"] == 3
        assert snap["gauges"]["occ"] == 0.5
        assert snap["histograms"]["lat{a}"]["count"] == 1


# ---------------------------------------------------------------------------
# tracer (pure python, injectable clock)
# ---------------------------------------------------------------------------

class _FakeClock:
    def __init__(self):
        self.t = 0.0
        self.calls = 0

    def __call__(self):
        self.calls += 1
        self.t += 1.0
        return self.t


class TestTracer:
    def test_span_and_instant_timing(self):
        clk = _FakeClock()
        tr = Tracer(clock=clk)
        with tr.span("work", "tenantA", args={"k": 1}):
            tr.instant("mark", "tenantA")
        assert [e["ph"] for e in tr.events] == ["i", "X"]
        span = tr.events[1]
        assert span["name"] == "work" and span["dur"] == 2.0
        assert tr.tracks() == ["tenantA"]

    def test_instant_ts_override_for_sim_time(self):
        clk = _FakeClock()
        tr = Tracer(clock=clk)
        tr.instant("ev", "hyp", ts=42.5)
        assert tr.events[0]["ts"] == 42.5
        assert clk.calls == 0                    # sim time, clock untouched

    def test_chrome_export_schema(self, tmp_path):
        tr = Tracer(clock=_FakeClock())
        with tr.span("round", "a"):
            pass
        tr.instant("fault", "b")
        path = tr.export(str(tmp_path / "t.json"))
        doc = json.load(open(path))
        evs = doc["traceEvents"]
        metas = [e for e in evs if e["ph"] == "M"]
        assert {m["args"]["name"] for m in metas} == {"a", "b"}
        xs = [e for e in evs if e["ph"] == "X"]
        ins = [e for e in evs if e["ph"] == "i"]
        assert len(xs) == 1 and len(ins) == 1
        assert ins[0]["s"] == "t"
        # min-ts normalized to 0 and seconds scaled to integer-ish µs
        assert min(e["ts"] for e in xs + ins) == 0
        assert xs[0]["dur"] == pytest.approx(1e6)
        # tracks get distinct tids under one pid
        assert len({e["tid"] for e in xs + ins}) == 2

    def test_disabled_is_zero_cost(self):
        clk = _FakeClock()
        tr = Tracer(clock=clk, enabled=False)
        with tr.span("x", "a"):
            tr.instant("y", "a")
        assert tr.events == [] and clk.calls == 0
        # the shared singleton behaves the same
        with NULL_TRACER.span("x", "a"):
            NULL_TRACER.instant("y", "a")
        assert NULL_TRACER.events == []

    def test_max_events_drops_counted(self):
        tr = Tracer(clock=_FakeClock(), max_events=2)
        for _ in range(5):
            tr.instant("e", "a")
        assert len(tr.events) == 2 and tr.dropped == 3


# ---------------------------------------------------------------------------
# BatcherStats as a registry view (no JAX)
# ---------------------------------------------------------------------------

class TestBatcherStatsView:
    def test_fresh_stats_ratio_properties_defined(self):
        """Every derived ratio is finite/defined on a fresh (all-zero)
        stats object — no ZeroDivisionError on an idle batcher."""
        st = BatcherStats()
        assert st.tokens == 0
        assert st.acceptance_rate == 0.0
        assert st.occupancy == 0.0
        assert st.prefix_tokens_saved == 0.0
        assert st.dispatches_per_token == 0.0
        assert st.syncs_per_token == 0.0
        assert st.decode_dispatches_per_token == 0.0

    def test_kwargs_seed_and_unknown_field_rejected(self):
        st = BatcherStats(cache_bytes=123)
        assert st.cache_bytes == 123
        with pytest.raises(TypeError):
            BatcherStats(not_a_field=1)
        with pytest.raises(AttributeError):
            BatcherStats().no_such_counter

    @pytest.mark.parametrize("seed", range(3))
    def test_registry_view_equals_legacy_fields(self, seed):
        """Property-style: after random counter churn the attribute view,
        ``as_dict()``, and the raw registry all agree."""
        rng = np.random.default_rng(seed)
        reg = MetricsRegistry()
        st = BatcherStats(registry=reg, tenant="t0")
        shadow = {f: 0 for f in _STATS_FIELDS}
        for _ in range(200):
            f = _STATS_FIELDS[rng.integers(len(_STATS_FIELDS))]
            k = int(rng.integers(1, 5))
            setattr(st, f, getattr(st, f) + k)
            shadow[f] += k
        assert st.as_dict() == shadow
        for f in _STATS_FIELDS:
            assert getattr(st, f) == shadow[f]
            assert reg.counter(f"serving.{f}", "t0").value == shadow[f]

    def test_two_tenants_share_registry_without_collision(self):
        reg = MetricsRegistry()
        a = BatcherStats(registry=reg, tenant="a")
        b = BatcherStats(registry=reg, tenant="b")
        a.chunks += 3
        b.chunks += 5
        assert a.chunks == 3 and b.chunks == 5
        assert sorted(reg.labels("serving.chunks")) == ["a", "b"]


# ---------------------------------------------------------------------------
# serving integration: device counters, contract, trace from a real run
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")

from repro.configs import get_reduced                         # noqa: E402
from repro.models import init_params                          # noqa: E402
from repro.serving import ServingConfig                       # noqa: E402
from repro.serving.batcher import ContinuousBatcher, Request  # noqa: E402


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _run(params, cfg, sc, n=8, *, telemetry=None, max_new=10, seed=3):
    rng = np.random.default_rng(seed)
    b = ContinuousBatcher(params, cfg, sc, telemetry=telemetry)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        size=1 + i % 6).astype(np.int32),
                    max_new=max_new + i % 4)
            for i in range(n)]
    for r in reqs:
        b.submit(r)
    stats = b.run(max_steps=4000)
    return b, reqs, stats


class TestServingTelemetry:
    def test_contract_and_trace_with_telemetry_enabled(self, qwen):
        """Tracing must not add dispatches or syncs: a clean paged run keeps
        dispatches == syncs == chunks + prefills, and the exported trace
        carries the round/dispatch/host_sync spans on the tenant track."""
        cfg, params = qwen
        tel = Telemetry(tracer=Tracer(), tenant="tenantA")
        sc = ServingConfig(slots=4, prompt_len=8, max_len=64, chunk=8,
                           attn_impl="xla", paged=True, page_size=8,
                           n_pages=64)
        _, reqs, st = _run(params, cfg, sc, telemetry=tel)
        assert all(r.done for r in reqs)
        assert st.dispatches == st.chunks + st.prefills
        assert st.host_syncs == st.chunks + st.prefills
        names = {e["name"] for e in tel.tracer.events}
        assert {"round", "dispatch", "host_sync", "chunk",
                "admission"} <= names
        assert tel.tracer.tracks() == ["tenantA"]
        # stats landed in the shared registry under the tenant label
        assert tel.registry.counter("serving.chunks", "tenantA").value \
            == st.chunks

    def test_device_counters_page_conservation(self, qwen):
        """In-scan pops ride back and cover every decode page fault: a
        clean run pops at least one page per boundary crossing and pushes
        back the in-scan frees."""
        cfg, params = qwen
        sc = ServingConfig(slots=4, prompt_len=8, max_len=64, chunk=8,
                           attn_impl="xla", paged=True, page_size=4,
                           n_pages=96)
        b, reqs, st = _run(params, cfg, sc, max_new=14)
        assert all(r.done for r in reqs)
        assert st.device_pages_popped > 0
        assert st.device_pages_pushed > 0
        assert st.fault_denied_slots == 0        # pool never dry
        # pops never exceed the pool and the ledger reconciled at each sync
        assert st.device_pages_popped <= st.chunks * sc.chunk * sc.slots

    def test_fault_denied_counted_on_device(self, qwen):
        """Over-subscribe the quota: in-scan page denials are observed on
        device and ride back.  (No exact ordering vs ``oom_requeues`` — a
        requeue can also originate at re-admission, outside the scan.)"""
        cfg, params = qwen
        sc = ServingConfig(slots=4, prompt_len=8, max_len=64, chunk=8,
                           attn_impl="xla", paged=True, page_size=8,
                           n_pages=16, page_quota=5, reserve_pages=False)
        _, reqs, st = _run(params, cfg, sc, seed=17)
        assert all(r.done for r in reqs)
        assert st.oom_requeues > 0, "quota never exercised the denial path"
        assert st.fault_denied_slots > 0, \
            "device never observed the in-scan denials"

    def test_device_draft_accepted_matches_host(self, qwen):
        """The on-device accepted-token count agrees with the host-side
        commit accounting in a clean speculative paged run."""
        cfg, params = qwen
        sc = ServingConfig(slots=4, prompt_len=8, max_len=48, chunk=4,
                           attn_impl="xla", paged=True, page_size=8,
                           n_pages=96, speculative=True, draft_window=4)
        _, reqs, st = _run(params, cfg, sc, n=6)
        assert all(r.done for r in reqs)
        assert st.spec_windows > 0
        assert st.device_draft_accepted == st.accepted_tokens

    def test_telemetry_off_by_default_and_identical_tokens(self, qwen):
        """The default batcher gets NULL_TRACER and the token streams are
        identical with tracing on (observability never changes decode)."""
        cfg, params = qwen
        sc = ServingConfig(slots=4, prompt_len=8, max_len=64, chunk=8,
                           attn_impl="xla", paged=True, page_size=8,
                           n_pages=64)
        b, plain, _ = _run(params, cfg, sc)
        assert b._tracer is NULL_TRACER
        _, traced, _ = _run(params, cfg, sc,
                            telemetry=Telemetry(tracer=Tracer()))
        assert [r.out for r in plain] == [r.out for r in traced]


# ---------------------------------------------------------------------------
# executor SLO quantiles + injectable clock (bookkeeping only)
# ---------------------------------------------------------------------------

class TestExecutorObservability:
    @pytest.fixture()
    def vpool(self):
        from repro.serving.tenancy import VirtualAcceleratorPool

        return VirtualAcceleratorPool(devices=list(jax.devices()) * 8,
                                      devices_per_core=1)

    def test_slo_report_quantiles(self, vpool):
        from repro.serving.tenancy import ServingExecutor

        from repro.core.hypervisor import RequestRecord

        ex = ServingExecutor(vpool)
        lats = [0.1 * (i + 1) for i in range(20)]      # 0.1 .. 2.0
        for lt in lats:
            ex.record_latency("a", lt, slo=1.0)
        ex.note_drop(RequestRecord("b", 0, t_arrival=0.0))
        rep = ex.slo_report()
        assert rep["a"]["requests"] == 20
        assert rep["a"]["p50_latency"] == pytest.approx(
            percentile(lats, 0.5), rel=0.09)
        assert rep["a"]["p99_latency"] == pytest.approx(
            percentile(lats, 0.99), rel=0.09)
        assert rep["a"]["p50_latency"] <= rep["a"]["p95_latency"] \
            <= rep["a"]["p99_latency"]
        # a tenant that only dropped has no latency sample → None, not 0
        assert rep["b"]["dropped"] == 1
        assert rep["b"]["p99_latency"] is None

    def test_legacy_slo_counts_view(self, vpool):
        from repro.serving.tenancy import ServingExecutor

        from repro.core.hypervisor import RequestRecord

        ex = ServingExecutor(vpool)
        ex.record_latency("a", 0.2, slo=0.5)
        ex.record_latency("a", 0.9, slo=0.5)
        ex.note_drop(RequestRecord("a", 0, t_arrival=0.0))
        assert ex._slo_counts == {"a": {"n": 3, "met": 1, "dropped": 1}}

    def test_injectable_clock_times_remesh(self, vpool):
        """A fake clock makes the reconfigure timing deterministic — the
        logged t_remesh is exactly the clock delta across the callback."""
        from repro.serving.tenancy import ServingExecutor, SwitchMode

        clk = _FakeClock()
        ex = ServingExecutor(vpool, clock=clk)
        vpool.lease("a", 2)
        ex.register_remesh("a", lambda mesh: None)
        ex.exec_resize("a", 4, 0.0, SwitchMode.TASK_LEVEL)
        assert ex.reconfig_log[-1]["t_remesh"] == pytest.approx(1.0)

    def test_executor_telemetry_traces_reconfig(self, vpool):
        from repro.serving.tenancy import ServingExecutor, SwitchMode

        tel = Telemetry(tracer=Tracer(clock=_FakeClock()))
        ex = ServingExecutor(vpool, telemetry=tel, clock=_FakeClock())
        vpool.lease("a", 2)
        ex.register_remesh("a", lambda mesh: None)
        ex.exec_resize("a", 4, 0.0, SwitchMode.TASK_LEVEL)
        names = [e["name"] for e in tel.tracer.events]
        assert "remesh" in names


class TestHypervisorTelemetry:
    def test_events_land_on_tenant_tracks(self):
        from repro.core.hypervisor import (
            Hypervisor, ResourcePool, TenantSpec,
        )

        tel = Telemetry(tracer=Tracer(clock=_FakeClock()))
        hv = Hypervisor(ResourcePool(16), telemetry=tel)
        hv.admit(TenantSpec("a", 8))
        hv.admit(TenantSpec("b", 8))
        hv.run(1.0)
        kinds = {e["name"] for e in tel.tracer.events}
        assert "arrival" in kinds
        assert {"a", "b"} <= set(tel.tracer.tracks())
        assert tel.registry.counter("hypervisor.events.arrival").value >= 2
