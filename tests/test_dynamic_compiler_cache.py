"""DynamicCompiler schedule LRU: repeated Hypervisor reconfigs to a
previously seen core count reuse the plan at lookup cost.  (Separate from
test_ifp_compilers.py, which is skipped wholly when hypothesis is absent.)"""

import pytest

from repro.core import DynamicCompiler, fpga_small_core


class TestScheduleLRU:
    def test_reuses_previously_seen_core_counts(self, resnet_artifact):
        """Reconfiguring back to a core count seen before returns the
        memoized schedule (same plan, new physical cores) and reports the
        hit through context_switch_cost."""
        hw = fpga_small_core()
        dyn = DynamicCompiler(resnet_artifact)
        a = dyn.compile([0, 1, 2, 3])
        b = dyn.compile([2, 3])
        assert dyn.cache_hits == 0 and dyn.cache_misses == 2
        c = dyn.compile([4, 5, 6, 7])                 # same count, new cores
        assert dyn.cache_hits == 1
        assert c.from_cache and not a.from_cache
        assert c.core_ids == [4, 5, 6, 7]
        assert c.per_core_layers is a.per_core_layers  # plan reused, not rebuilt
        assert c.estimated_latency(hw) == pytest.approx(a.estimated_latency(hw))
        cost = dyn.context_switch_cost(c, hw)
        assert cost["cache_hit"] == 1.0 and cost["cache_hits"] == 1.0
        assert dyn.context_switch_cost(b, hw)["cache_hit"] == 0.0

    def test_core_speeds_participate_in_key(self, resnet_artifact):
        """A straggler probe (heterogeneous speeds) never reuses the
        homogeneous plan, and vice versa; repeated probes at the same
        rounded speeds do hit."""
        dyn = DynamicCompiler(resnet_artifact)
        dyn.compile([0, 1, 2, 3])
        d = dyn.compile([0, 1, 2, 3], core_speeds=[1.0, 1.0, 1.0, 0.5])
        assert not d.from_cache
        e = dyn.compile([0, 1, 2, 3], core_speeds=[1.0, 1.0, 1.0, 0.5])
        assert e.from_cache

    def test_lru_evicts_oldest(self, resnet_artifact):
        dyn = DynamicCompiler(resnet_artifact, cache_size=2)
        dyn.compile([0])
        dyn.compile([0, 1])
        dyn.compile([0, 1, 2])            # evicts the k=1 entry
        assert not dyn.compile([0]).from_cache
