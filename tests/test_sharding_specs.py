"""Sharding rules: spec pytrees match param pytrees structurally, and every
sharded dim divides its mesh axis (the invariant the 512-device dry-run
enforces for real)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS, get_config, get_reduced
from repro.distributed.sharding import (
    batch_shard, cache_specs, make_policy, param_specs, train_batch_specs,
)
from repro.models import init_caches, init_params


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    """Structure-only mesh: abstract device array is fine for spec checks."""
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


MESH = fake_mesh()


def _is_p(x):
    return isinstance(x, P)


@pytest.mark.parametrize("arch", ARCHS)
class TestParamSpecs:
    def test_structure_matches_params(self, arch):
        cfg = get_config(arch)
        params_abs = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
        specs = param_specs(cfg, MESH)
        # identical treedef
        t1 = jax.tree.structure(params_abs)
        t2 = jax.tree.structure(specs, is_leaf=_is_p)
        assert t1 == t2

    def test_sharded_dims_divide_axes(self, arch):
        cfg = get_config(arch)
        params_abs = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
        specs = param_specs(cfg, MESH)
        flat_p = jax.tree.leaves(params_abs)
        flat_s = jax.tree.leaves(specs, is_leaf=_is_p)
        sizes = dict(zip(MESH.axis_names, MESH.devices.shape))
        for leaf, spec in zip(flat_p, flat_s):
            assert len(spec) <= len(leaf.shape), (leaf.shape, spec)
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if ax is None:
                    continue
                axs = (ax,) if isinstance(ax, str) else ax
                n = int(np.prod([sizes[a] for a in axs]))
                assert dim % n == 0, f"{arch}: dim {dim} !% {axs} in {spec}"

    def test_cache_specs_structure(self, arch):
        cfg = get_config(arch)
        B = 32
        caches_abs = jax.eval_shape(lambda: init_caches(cfg, 4, 64))
        specs = cache_specs(cfg, MESH, batch=B)
        # same top-level key sets (period-aligned, full config both sides)
        assert set(specs.kv.keys()) == set(caches_abs.kv.keys())
        assert set(specs.ssm.keys()) == set(caches_abs.ssm.keys())


class TestBatchSharding:
    def test_batch_shard_divisibility(self):
        assert batch_shard(MESH, 256) == ("data",)
        assert batch_shard(MESH, 7) is None
        assert batch_shard(MESH, 16) == ("data",)

    def test_multipod_batch_axes(self):
        mesh3 = fake_mesh((2, 16, 16), ("pod", "data", "model"))
        assert batch_shard(mesh3, 256) == ("pod", "data")
        assert batch_shard(mesh3, 2) == ("pod",)

    def test_train_batch_specs_family_extras(self):
        cfg = get_config("qwen2-vl-72b")
        specs = train_batch_specs(cfg, MESH, batch=256)
        assert "extra_embeds" in specs and "positions" in specs
        # positions (3, B, S): batch on axis 1
        assert specs["positions"][0] is None


class TestPolicy:
    def test_policy_constrains_known_names_only(self):
        cfg = get_config("qwen3-32b")
        pol = make_policy(cfg, MESH, batch=256)
        x = jnp.zeros((4, 8, 16))
        assert pol(x, "unknown-name") is x     # passthrough

    def test_vocab_parallel_flag(self):
        cfg = get_config("qwen3-32b")        # vocab_padded % 16 == 0
        pol = make_policy(cfg, MESH, batch=256)
        assert pol.vocab_parallel

    def test_embed_fallback_without_vocab_parallel(self):
        cfg = get_reduced("qwen3-0.6b")
        pol = make_policy(cfg, MESH, batch=256)
        pol.vocab_parallel = False
        tbl = jnp.arange(20.0).reshape(10, 2)
        ids = jnp.array([[1, 3], [2, 0]])
        out = pol.embed(tbl, ids)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(tbl[ids]))
