"""Serving stack: generate loop, continuous batcher, two-stage compiler
cache + tenancy (the TPU-side instantiation of the paper's machinery)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.engine import ServeConfig, generate
from repro.serving.tenancy import TwoStageCompiler, VirtualAcceleratorPool

KEY = jax.random.PRNGKey(0)


class TestGenerate:
    def test_greedy_deterministic(self):
        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        prompt = (jnp.arange(8, dtype=jnp.int32)[None] * 5) % cfg.vocab
        a = generate(params, cfg, prompt, n_new=6)
        b = generate(params, cfg, prompt, n_new=6)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.shape == (1, 6)
        assert int(a.max()) < cfg.vocab      # padding never sampled

    def test_generate_matches_teacher_forced_forward(self):
        """Greedy decode token t+1 equals argmax of forward() at position t
        when fed its own outputs — the serve path is the train path."""
        from repro.models import forward, logits_fn

        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        prompt = (jnp.arange(6, dtype=jnp.int32)[None] * 3 + 1) % cfg.vocab
        out = generate(params, cfg, prompt, n_new=4)
        seq = jnp.concatenate([prompt, out[:, :3]], axis=1)
        h = forward(params, seq, cfg).hidden
        logits = logits_fn(params, h, cfg)[..., : cfg.vocab]
        ref_last = jnp.argmax(logits[:, -1], axis=-1)
        np.testing.assert_array_equal(np.asarray(ref_last), np.asarray(out[:, 3]))


class TestContinuousBatcher:
    def test_all_requests_complete(self):
        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        b = ContinuousBatcher(params, cfg, slots=4, prompt_len=8, max_len=32)
        reqs = [
            Request(rid=i, prompt=np.arange(1 + i % 7, dtype=np.int32) + 1,
                    max_new=5 + i % 3)
            for i in range(10)
        ]
        for r in reqs:
            b.submit(r)
        stats = b.run(max_steps=500)
        assert stats.completed == 10
        assert all(r.done for r in reqs)
        assert all(len(r.out) >= 1 for r in reqs)
        assert 0 < stats.occupancy <= 1

    def test_batched_requests_match_solo_run(self):
        """Isolation inside the batcher: a request's tokens are identical
        whether it shares slots with others or runs alone."""
        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        prompt = np.array([3, 1, 4, 1, 5], dtype=np.int32)

        solo = ContinuousBatcher(params, cfg, slots=4, prompt_len=8, max_len=32)
        r_solo = Request(rid=0, prompt=prompt, max_new=6)
        solo.submit(r_solo)
        solo.run(max_steps=100)

        busy = ContinuousBatcher(params, cfg, slots=4, prompt_len=8, max_len=32)
        r_busy = Request(rid=0, prompt=prompt, max_new=6)
        busy.submit(r_busy)
        for i in range(3):
            busy.submit(Request(rid=i + 1,
                                prompt=np.arange(2 + i, dtype=np.int32) + 2,
                                max_new=6))
        busy.run(max_steps=100)
        assert r_solo.out == r_busy.out


class TestTenancy:
    def test_pool_leases_disjoint_meshes(self):
        pool = VirtualAcceleratorPool(devices=jax.devices() * 8,
                                      devices_per_core=1, cores_per_group=4)
        la = pool.lease("a", 2)
        lb = pool.lease("b", 2)
        assert not set(la.cores) & set(lb.cores)
        ma = pool.mesh_for(la)
        assert ma.devices.shape == (2, 1)

    def test_hbm_admission_control(self):
        from repro.configs import get_config
        from repro.core.hrp import HRPError

        pool = VirtualAcceleratorPool(devices=jax.devices() * 4, devices_per_core=1)
        lease = pool.lease("t", 1)
        big = get_config("command-r-plus-104b")      # 104B params never fit 1 dev
        with pytest.raises(HRPError):
            pool.check_hbm(big, lease, batch=1, max_len=1024)
        small = get_reduced("qwen3-0.6b")
        pool.check_hbm(small, lease, batch=2, max_len=64)   # fits fine

    def test_two_stage_reconfigure_uses_cache(self):
        """Online reconfigure must never recompile: it resizes the lease and
        swaps in the statically-compiled executable (~ms)."""
        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        pool = VirtualAcceleratorPool(devices=jax.devices() * 4, devices_per_core=1)
        comp = TwoStageCompiler(pool)

        def program(x):
            return x * 2.0

        abstract = (jax.ShapeDtypeStruct((8,), jnp.float32),)
        import jax.sharding as jsh

        def mesh_builder(n):
            devs = np.array(jax.devices() * n, dtype=object)[:n].reshape(n, 1)
            return jsh.Mesh(devs, ("data", "model"))

        progs = comp.static_compile("toy", program, abstract,
                                    lease_sizes=[1, 2, 4], mesh_builder=mesh_builder)
        assert set(progs) == {1, 2, 4}
        static_cost = sum(p.compile_seconds + p.lowered_seconds for p in progs.values())

        pool.lease("t", 1)
        prog, _, timing = comp.reconfigure("t", "toy", 4)
        assert prog.n_cores == 4
        assert timing["t_context"] < max(0.05, static_cost / 10)

    def test_reconfigure_uncovered_size_raises(self):
        from repro.core.hrp import HRPError

        pool = VirtualAcceleratorPool(devices=jax.devices() * 4, devices_per_core=1)
        comp = TwoStageCompiler(pool)
        pool.lease("t", 1)
        with pytest.raises(HRPError):
            comp.reconfigure("t", "missing", 2)
