"""Serving stack: generate loop, continuous batcher, two-stage compiler
cache + tenancy (the TPU-side instantiation of the paper's machinery)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.engine import (
    ServeConfig,
    SlotState,
    generate,
    make_decode_chunk,
    make_prefill_step,
    make_serve_step,
)
from repro.serving.tenancy import TwoStageCompiler, VirtualAcceleratorPool

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    return cfg, init_params(cfg, KEY)


class TestGenerate:
    def test_greedy_deterministic(self):
        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        prompt = (jnp.arange(8, dtype=jnp.int32)[None] * 5) % cfg.vocab
        a = generate(params, cfg, prompt, n_new=6)
        b = generate(params, cfg, prompt, n_new=6)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.shape == (1, 6)
        assert int(a.max()) < cfg.vocab      # padding never sampled

    def test_generate_matches_teacher_forced_forward(self):
        """Greedy decode token t+1 equals argmax of forward() at position t
        when fed its own outputs — the serve path is the train path."""
        from repro.models import forward, logits_fn

        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        prompt = (jnp.arange(6, dtype=jnp.int32)[None] * 3 + 1) % cfg.vocab
        out = generate(params, cfg, prompt, n_new=4)
        seq = jnp.concatenate([prompt, out[:, :3]], axis=1)
        h = forward(params, seq, cfg).hidden
        logits = logits_fn(params, h, cfg)[..., : cfg.vocab]
        ref_last = jnp.argmax(logits[:, -1], axis=-1)
        np.testing.assert_array_equal(np.asarray(ref_last), np.asarray(out[:, 3]))


class TestContinuousBatcher:
    def test_all_requests_complete(self):
        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        b = ContinuousBatcher(params, cfg, slots=4, prompt_len=8, max_len=32)
        reqs = [
            Request(rid=i, prompt=np.arange(1 + i % 7, dtype=np.int32) + 1,
                    max_new=5 + i % 3)
            for i in range(10)
        ]
        for r in reqs:
            b.submit(r)
        stats = b.run(max_steps=500)
        assert stats.completed == 10
        assert all(r.done for r in reqs)
        assert all(len(r.out) >= 1 for r in reqs)
        assert 0 < stats.occupancy <= 1

    def test_batched_requests_match_solo_run(self):
        """Isolation inside the batcher: a request's tokens are identical
        whether it shares slots with others or runs alone."""
        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        prompt = np.array([3, 1, 4, 1, 5], dtype=np.int32)

        solo = ContinuousBatcher(params, cfg, slots=4, prompt_len=8, max_len=32)
        r_solo = Request(rid=0, prompt=prompt, max_new=6)
        solo.submit(r_solo)
        solo.run(max_steps=100)

        busy = ContinuousBatcher(params, cfg, slots=4, prompt_len=8, max_len=32)
        r_busy = Request(rid=0, prompt=prompt, max_new=6)
        busy.submit(r_busy)
        for i in range(3):
            busy.submit(Request(rid=i + 1,
                                prompt=np.arange(2 + i, dtype=np.int32) + 2,
                                max_new=6))
        busy.run(max_steps=100)
        assert r_solo.out == r_busy.out


class TestChunkedDecode:
    """The chunked hot path must be a pure performance change: token
    streams identical to the per-step reference, caches updated in place."""

    def _prefill(self, cfg, params, *, B=2, S=8, max_len=32):
        scfg = ServeConfig(max_len=max_len)
        pre = jax.jit(make_prefill_step(cfg, scfg))
        toks = (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) * 3 + 1) % cfg.vocab
        logits, caches = pre(params, {"tokens": toks})
        t0 = jnp.argmax(logits[..., : cfg.vocab], -1).astype(jnp.int32)
        return scfg, t0, caches, S

    def test_decode_chunk_matches_serve_step_loop(self, qwen):
        """One fused T-step scan == T per-step dispatches, token for token."""
        cfg, params = qwen
        scfg, t0, caches, S = self._prefill(cfg, params)
        B = t0.shape[0]
        T = 6

        step = jax.jit(make_serve_step(cfg, scfg))
        ref_caches = caches
        tok = t0
        ref = []
        for i in range(T):
            tok, _, ref_caches = step(
                params, tok, ref_caches, jnp.full((B,), S + i, jnp.int32),
                jax.random.PRNGKey(7),
            )
            ref.append(np.asarray(tok))

        chunk = jax.jit(make_decode_chunk(cfg, scfg, T))
        state = SlotState(
            tokens=t0,
            cur_pos=jnp.full((B,), S, jnp.int32),
            active=jnp.ones((B,), bool),
            remaining=jnp.full((B,), T + 1, jnp.int32),
            eos=jnp.full((B,), -1, jnp.int32),
        )
        _, state, toks, emitted, poisoned = chunk(
            params, caches, state, jax.random.PRNGKey(7))
        np.testing.assert_array_equal(np.asarray(toks), np.stack(ref))
        assert bool(np.asarray(emitted).all())
        assert not np.asarray(poisoned).any()

    def test_eos_mid_chunk_freezes_slot(self, qwen):
        """A slot hitting EOS inside the chunk stops emitting and freezes its
        position; the other slot keeps decoding the same tokens as without
        any EOS."""
        cfg, params = qwen
        scfg, t0, caches0, S = self._prefill(cfg, params)
        B = t0.shape[0]
        T = 6
        chunk = jax.jit(make_decode_chunk(cfg, scfg, T))

        def run(eos):
            state = SlotState(
                tokens=t0,
                cur_pos=jnp.full((B,), S, jnp.int32),
                active=jnp.ones((B,), bool),
                remaining=jnp.full((B,), T + 1, jnp.int32),
                eos=eos,
            )
            return chunk(params, caches0, state, jax.random.PRNGKey(7))

        _, _, free_toks, _, _ = run(jnp.full((B,), -1, jnp.int32))
        free = np.asarray(free_toks)                      # (T, B)
        # force slot 0 to hit EOS at step 2
        eos0 = int(free[2, 0])
        eos = jnp.array([eos0, -1], dtype=jnp.int32)
        _, state, toks, emitted, _ = run(eos)
        toks, emitted = np.asarray(toks), np.asarray(emitted)
        assert emitted[: 3, 0].all() and not emitted[3:, 0].any()
        assert emitted[:, 1].all()
        np.testing.assert_array_equal(toks[:3, 0], free[:3, 0])
        np.testing.assert_array_equal(toks[:, 1], free[:, 1])
        st = jax.device_get(state)
        assert not bool(st.active[0]) and bool(st.active[1])
        assert int(st.cur_pos[0]) == S + 3                # frozen at EOS
        assert int(st.cur_pos[1]) == S + T

    def test_chunked_batcher_matches_per_step_with_eos(self, qwen):
        """chunk=8 and chunk=1 batchers produce identical request outputs,
        including a request whose EOS lands mid-chunk."""
        cfg, params = qwen
        rng = np.random.default_rng(3)
        prompts = [rng.integers(1, cfg.vocab, size=1 + i % 6).astype(np.int32)
                   for i in range(8)]

        def run(chunk, eos_map):
            b = ContinuousBatcher(params, cfg, slots=4, prompt_len=8,
                                  max_len=64, chunk=chunk)
            reqs = [Request(rid=i, prompt=p, max_new=10 + i % 4,
                            eos=eos_map.get(i))
                    for i, p in enumerate(prompts)]
            for r in reqs:
                b.submit(r)
            b.run(max_steps=2000)
            return b, reqs

        # probe run to pick an EOS that fires mid-generation for request 0
        _, probe = run(1, {})
        eos_map = {0: probe[0].out[3]}
        b1, r1 = run(1, eos_map)
        b8, r8 = run(8, eos_map)
        for a, b in zip(r1, r8):
            assert a.done and b.done
            assert a.out == b.out, (a.rid, a.out, b.out)
        assert r8[0].out[-1] == eos_map[0] and len(r8[0].out) < 10
        # the chunked run must batch its dispatches
        assert b8.stats.dispatches < b1.stats.dispatches / 2
        assert b8.stats.host_syncs == b8.stats.dispatches

    def test_decode_cache_donated_not_copied(self, qwen):
        """donate_argnums really takes effect: the input cache buffers are
        consumed (deleted) by the chunked step — i.e. the KV ring buffer is
        updated in place, not copied per token."""
        cfg, params = qwen
        scfg, t0, caches, S = self._prefill(cfg, params)
        B = t0.shape[0]
        chunk = jax.jit(make_decode_chunk(cfg, scfg, 4), donate_argnums=(1, 2))
        state = SlotState(
            tokens=t0,
            cur_pos=jnp.full((B,), S, jnp.int32),
            active=jnp.ones((B,), bool),
            remaining=jnp.full((B,), 8, jnp.int32),
            eos=jnp.full((B,), -1, jnp.int32),
        )
        kv0 = caches.kv["0"].k
        new_caches, state, _, _, _ = chunk(params, caches, state, KEY)
        jax.block_until_ready(new_caches.kv["0"].k)
        assert kv0.is_deleted(), "input KV buffer survived: cache was copied"
        assert not new_caches.kv["0"].k.is_deleted()

    def test_scatter_admission_equals_where_merge(self, qwen):
        """Per-slot scatter admission == the old full-tree jnp.where merge
        on a 4-slot batcher."""
        cfg, params = qwen
        B, S, max_len = 4, 8, 32
        scfg = ServeConfig(max_len=max_len)
        pre = jax.jit(make_prefill_step(cfg, scfg))
        old_toks = (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) * 5 + 2) % cfg.vocab
        _, resident = pre(params, {"tokens": old_toks})
        new_toks = (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) * 7 + 3) % cfg.vocab
        _, fresh = pre(params, {"tokens": new_toks})

        join_slots = [1, 3]
        sel = np.zeros((B,), dtype=bool)
        sel[join_slots] = True
        selj = jnp.asarray(sel)

        def where_merge(old, new):
            cond = selj.reshape((1, -1) + (1,) * (old.ndim - 2))
            return jnp.where(cond, new, old)

        def scatter_merge(old, new):
            slots = jnp.asarray(join_slots, dtype=jnp.int32)
            return old.at[:, slots].set(new[:, slots].astype(old.dtype))

        ref = jax.tree.map(where_merge, resident, fresh)
        got = jax.tree.map(scatter_merge, resident, fresh)
        for r, g in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(r), np.asarray(g))

    def test_resize_between_chunks_migrates_live_state(self, qwen):
        """A TwoStageCompiler.reconfigure landing between chunks migrates the
        batcher's donated caches (pull-model register_state + adopt_state)
        and decode resumes token-identically."""
        from repro.core import TenantSpec
        from repro.serving.tenancy import (
            VirtualAcceleratorPool, make_serving_hypervisor,
        )

        cfg, params = qwen
        rng = np.random.default_rng(5)
        prompts = [rng.integers(1, cfg.vocab, size=4 + i).astype(np.int32)
                   for i in range(3)]

        def reqs():
            return [Request(rid=i, prompt=p, max_new=9)
                    for i, p in enumerate(prompts)]

        # uninterrupted reference
        ref = ContinuousBatcher(params, cfg, slots=4, prompt_len=8,
                                max_len=64, chunk=4)
        ref_reqs = reqs()
        for r in ref_reqs:
            ref.submit(r)
        ref.run(max_steps=2000)

        # interrupted run: resize lands between chunks
        pool = VirtualAcceleratorPool(devices=jax.devices() * 4,
                                      devices_per_core=1)
        hv, ex = make_serving_hypervisor(pool, policy="no_realloc")
        comp = ex.compiler

        def mesh_builder(n):
            import jax.sharding as jsh
            devs = np.array(jax.devices() * n, dtype=object)[:n].reshape(n, 1)
            return jsh.Mesh(devs, ("data", "model"))

        comp.static_compile("decode", lambda x: x, (jax.ShapeDtypeStruct((4,), jnp.float32),),
                            lease_sizes=[1, 2], mesh_builder=mesh_builder)
        assert hv.admit(TenantSpec("t", 1, artifact="decode"))

        b = ContinuousBatcher(params, cfg, slots=4, prompt_len=8,
                              max_len=64, chunk=4)
        ex.register_state("t", b.live_state, on_migrate=b.adopt_state)
        got_reqs = reqs()
        for r in got_reqs:
            b.submit(r)
        b.step()                                   # some tokens in flight
        hv.resize_request("t", 2)                  # migration between chunks
        assert ex.reconfig_log and "t_migrate" in ex.reconfig_log[-1]
        b.run(max_steps=2000)
        for a, g in zip(ref_reqs, got_reqs):
            assert a.out == g.out


class TestTenancy:
    def test_pool_leases_disjoint_meshes(self):
        pool = VirtualAcceleratorPool(devices=jax.devices() * 8,
                                      devices_per_core=1, cores_per_group=4)
        la = pool.lease("a", 2)
        lb = pool.lease("b", 2)
        assert not set(la.cores) & set(lb.cores)
        ma = pool.mesh_for(la)
        assert ma.devices.shape == (2, 1)

    def test_hbm_admission_control(self):
        from repro.configs import get_config
        from repro.core.hrp import HRPError

        pool = VirtualAcceleratorPool(devices=jax.devices() * 4, devices_per_core=1)
        lease = pool.lease("t", 1)
        big = get_config("command-r-plus-104b")      # 104B params never fit 1 dev
        with pytest.raises(HRPError):
            pool.check_hbm(big, lease, batch=1, max_len=1024)
        small = get_reduced("qwen3-0.6b")
        pool.check_hbm(small, lease, batch=2, max_len=64)   # fits fine

    def test_two_stage_reconfigure_uses_cache(self):
        """Online reconfigure must never recompile: it resizes the lease and
        swaps in the statically-compiled executable (~ms)."""
        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        pool = VirtualAcceleratorPool(devices=jax.devices() * 4, devices_per_core=1)
        comp = TwoStageCompiler(pool)

        def program(x):
            return x * 2.0

        abstract = (jax.ShapeDtypeStruct((8,), jnp.float32),)
        import jax.sharding as jsh

        def mesh_builder(n):
            devs = np.array(jax.devices() * n, dtype=object)[:n].reshape(n, 1)
            return jsh.Mesh(devs, ("data", "model"))

        progs = comp.static_compile("toy", program, abstract,
                                    lease_sizes=[1, 2, 4], mesh_builder=mesh_builder)
        assert set(progs) == {1, 2, 4}
        static_cost = sum(p.compile_seconds + p.lowered_seconds for p in progs.values())

        pool.lease("t", 1)
        prog, _, timing = comp.reconfigure("t", "toy", 4)
        assert prog.n_cores == 4
        assert timing["t_context"] < max(0.05, static_cost / 10)

    def test_reconfigure_uncovered_size_raises(self):
        from repro.core.hrp import HRPError

        pool = VirtualAcceleratorPool(devices=jax.devices() * 4, devices_per_core=1)
        comp = TwoStageCompiler(pool)
        pool.lease("t", 1)
        with pytest.raises(HRPError):
            comp.reconfigure("t", "missing", 2)
