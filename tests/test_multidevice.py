"""Multi-device semantics tests, run in a subprocess so the 8-device
XLA_FLAGS never leaks into this (single-device) test session."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_reduced
    from repro.distributed.sharding import cache_specs, make_policy
    from repro.models import init_params
    from repro.serving.engine import ServeConfig, make_prefill_step, make_serve_step

    from repro.launch.mesh import make_mesh_compat

    cfg = get_reduced("qwen3-0.6b")              # kv heads = 2 < model axis 4
    mesh = make_mesh_compat((2, 4), ("data", "model"))
    B, S = 4, 32
    policy = make_policy(cfg, mesh, batch=B)
    assert policy.kv_len_sharded, "cache length must be model-sharded here"
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = (jnp.arange(B * 8, dtype=jnp.int32).reshape(B, 8) * 3 + 1) % cfg.vocab

    scfg = ServeConfig(max_len=S)
    pre = jax.jit(make_prefill_step(cfg, scfg))
    step_ref = jax.jit(make_serve_step(cfg, scfg))
    logits0, caches = pre(params, {"tokens": toks})
    t0 = jnp.argmax(logits0[..., : cfg.vocab], -1).astype(jnp.int32)
    cur = jnp.full((B,), 8, jnp.int32)
    ref_next, ref_logits, ref_caches = step_ref(params, t0, caches, cur,
                                                jax.random.PRNGKey(1))

    with mesh:
        c_specs = cache_specs(cfg, mesh, batch=B)
        c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                            is_leaf=lambda x: isinstance(x, P))
        caches_sh = jax.device_put(caches, c_sh)
        step_sh = jax.jit(make_serve_step(cfg, scfg, policy=policy))
        got_next, got_logits, caches2 = step_sh(params, t0, caches_sh, cur,
                                                jax.random.PRNGKey(1))
        # second step exercises the shard-local ring-buffer write
        got2, gl2, _ = step_sh(params, got_next, caches2, cur + 1,
                               jax.random.PRNGKey(2))
    ref2, rl2, _ = step_ref(params, ref_next, ref_caches, cur + 1,
                            jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(got_next), np.asarray(ref_next))
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(ref2))
    np.testing.assert_allclose(np.asarray(gl2, np.float32),
                               np.asarray(rl2, np.float32), rtol=2e-2, atol=2e-2)
    # dtype stability across the sharded path too
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(caches2.kv)
               if l.dtype != jnp.int32)
    print("MULTIDEVICE-OK")
""")


def test_shard_map_gate_matches_ci_expectation():
    """A version-gated test that silently skips forever is a dead test.

    Each CI matrix leg sets ``EXPECT_SHARD_MAP`` (0 on the pinned-old-jax
    leg, 1 on the latest leg); this asserts the installed jax agrees, so
    the gated multidevice test below is *guaranteed* to run somewhere — if
    pip ever resolves an old jax on the latest leg (or the gate's condition
    rots), the suite fails loudly instead of skip-passing.  Unset locally:
    this check then skips, and the gate below speaks for itself."""
    expect = os.environ.get("EXPECT_SHARD_MAP")
    if expect is None:
        pytest.skip("EXPECT_SHARD_MAP unset (local run); the CI matrix "
                    "legs own this assertion")
    have = hasattr(jax, "shard_map")
    assert have == bool(int(expect)), (
        f"CI leg expected shard_map={expect} but jax {jax.__version__} "
        f"has shard_map={have} — the version gate on "
        f"test_sharded_kv_decode_matches_reference is now "
        f"{'never' if not have else 'always'} exercised on this leg"
    )


@pytest.mark.slow
@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-manual shard_map (length-sharded KV slot write) emits a "
           "PartitionId op that the SPMD partitioner of jax<0.6 cannot "
           "handle; needs jax >= 0.6.0 (where shard_map graduated from "
           "jax.experimental to the top-level jax.shard_map API) — this "
           f"container has jax {jax.__version__}",
)
def test_sharded_kv_decode_matches_reference():
    """The partial-manual shard_map slot update (length-sharded KV cache)
    produces the same tokens/logits as the single-device reference over two
    decode steps, on a forced 2×4 host mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.join(os.path.dirname(__file__), ".."),
                       timeout=900)
    assert p.returncode == 0, p.stderr[-3000:]
    assert "MULTIDEVICE-OK" in p.stdout
