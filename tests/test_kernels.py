"""Pallas TPU kernels, validated in interpret mode against pure-jnp oracles.

Each kernel sweeps shapes/dtypes; assert_allclose vs ref.py.  interpret=True
executes the kernel body on CPU with TPU grid semantics (sequential innermost
axis, VMEM scratch carried across grid steps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    # f32: block-K accumulation order differs from the fused reference
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=5e-4, atol=5e-4)


class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 384),
                                       (512, 256, 128), (64, 1024, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, k, n, dtype):
        from repro.kernels.matmul import ops, ref

        ka, kb = jax.random.split(KEY)
        a = jax.random.normal(ka, (m, k), dtype)
        b = jax.random.normal(kb, (k, n), dtype)
        got = ops.matmul(a, b, block_m=128, block_n=128, block_k=128, interpret=True)
        want = ref.matmul_ref(a, b)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype)
        )


class TestFlashAttention:
    @pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 128)])
    @pytest.mark.parametrize("seq,heads,kv_heads", [(512, 4, 2), (1024, 8, 8), (384, 4, 1)])
    def test_matches_ref(self, causal, window, seq, heads, kv_heads):
        from repro.kernels.flash_attention import ops, ref

        kq, kk, kv = jax.random.split(KEY, 3)
        B, dh = 2, 64
        q = jax.random.normal(kq, (B, seq, heads, dh), jnp.float32)
        k = jax.random.normal(kk, (B, seq, kv_heads, dh), jnp.float32)
        v = jax.random.normal(kv, (B, seq, kv_heads, dh), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=causal, window=window,
                                  block_q=128, block_k=128, interpret=True)
        want = jnp.swapaxes(
            ref.flash_attention_ref(
                jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
                causal=causal, window=window,
            ), 1, 2,
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        from repro.kernels.flash_attention import ops, ref

        kq, kk, kv = jax.random.split(KEY, 3)
        q = jax.random.normal(kq, (1, 256, 2, 64), jnp.bfloat16)
        k = jax.random.normal(kk, (1, 256, 2, 64), jnp.bfloat16)
        v = jax.random.normal(kv, (1, 256, 2, 64), jnp.bfloat16)
        got = ops.flash_attention(q, k, v, causal=True, interpret=True)
        want = jnp.swapaxes(
            ref.flash_attention_ref(
                jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
            ), 1, 2,
        )
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(4, 128, 256), (2, 64, 1024), (1, 8, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype):
        from repro.kernels.rmsnorm import ops, ref

        kx, ks = jax.random.split(KEY)
        x = jax.random.normal(kx, shape, dtype)
        s = jax.random.normal(ks, (shape[-1],), dtype)
        got = ops.rmsnorm(x, s, interpret=True)
        want = ref.rmsnorm_ref(x, s)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **tol(dtype))


class TestDecodeAttention:
    @pytest.mark.parametrize("C,H,Hkv", [(128, 4, 2), (1024, 8, 1), (384, 8, 8)])
    @pytest.mark.parametrize("window", [None, 64])
    def test_matches_ref(self, C, H, Hkv, window):
        from repro.kernels.decode_attention import ops, ref

        kq, kk, kv = jax.random.split(KEY, 3)
        B, dh = 2, 64
        q = jax.random.normal(kq, (B, H, dh), jnp.float32)
        k = jax.random.normal(kk, (B, C, Hkv, dh), jnp.float32)
        v = jax.random.normal(kv, (B, C, Hkv, dh), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (B, C))
        cur = jnp.full((B,), C // 2, jnp.int32)
        got = ops.decode_attention(q, k, v, pos, cur, window=window,
                                   block_c=128, interpret=True)
        want = ref.decode_attention_ref(q, k, v, pos, cur, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


class TestSSDScan:
    @pytest.mark.parametrize("S,chunk", [(256, 64), (512, 128), (384, 128)])
    def test_matches_naive(self, S, chunk):
        from repro.kernels.ssd_scan import ops, ref

        ks = jax.random.split(KEY, 5)
        B, nh, hd, G, N = 2, 4, 32, 1, 16
        x = jax.random.normal(ks[0], (B, S, nh, hd), jnp.float32) * 0.1
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
        A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
        Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
        Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
        got = ops.ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
        want = ref.ssd_naive(x, dt, A, Bm, Cm)
        want = want[0] if isinstance(want, tuple) else want
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_final_state_matches_chunked_oracle(self):
        from repro.kernels.ssd_scan import ops
        from repro.models.ssm import ssd_chunked

        ks = jax.random.split(KEY, 5)
        B, S, nh, hd, G, N = 1, 256, 2, 16, 1, 8
        x = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.1
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
        A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
        Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
        Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
        got_y, got_st = ops.ssd(x, dt, A, Bm, Cm, chunk=64, return_state=True,
                                interpret=True)
        ref_y, ref_st = ssd_chunked(x, dt, A, Bm, Cm, chunk=64, return_state=True)
        np.testing.assert_allclose(np.asarray(got_st), np.asarray(ref_st),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(got_y), np.asarray(ref_y),
                                   rtol=2e-3, atol=2e-3)


class TestPagedDecodeAttention:
    """In-kernel page-table walk vs the materialized-gather oracle.

    The trash page is poisoned with large finite values (1e4) so any
    unmapped-page or beyond-cur_pos leak shows up as a loud mismatch
    instead of averaging away (NaN would poison the oracle too)."""

    def _pools(self, key, P, ps, Hkv, dh):
        kk, kv = jax.random.split(key)
        kp = jax.random.normal(kk, (P + 1, ps, Hkv, dh), jnp.float32)
        vp = jax.random.normal(kv, (P + 1, ps, Hkv, dh), jnp.float32)
        # poisoned trash page: leaks are loud, not averaged away
        return kp.at[P].set(1e4), vp.at[P].set(1e4)

    @pytest.mark.parametrize("H,Hkv", [(4, 2), (8, 1), (8, 8)])
    def test_matches_ref(self, H, Hkv):
        from repro.kernels.paged_attention import ops, ref

        B, dh, P, ps, maxp = 3, 32, 10, 8, 4
        kq, kp_key = jax.random.split(KEY)
        q = jax.random.normal(kq, (B, H, dh), jnp.float32)
        kp, vp = self._pools(kp_key, P, ps, Hkv, dh)
        # rows: unmapped holes mid-table; cur_pos mid-page (partial last
        # page), at a page boundary - 1, and at full capacity
        table = jnp.asarray([[0, 3, -1, -1], [5, -1, 7, -1], [2, 4, 6, 8]],
                            jnp.int32)
        cur = jnp.asarray([9, 23, 31], jnp.int32)
        got = ops.paged_decode_attention(q, kp, vp, table, cur, interpret=True)
        want = ref.paged_decode_attention_ref(q, kp, vp, table, cur)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_page_boundary_sweep(self):
        """cur_pos crossing every position of a 2-page window: the fused
        `pos <= cur_pos` mask must flip exactly one key per step."""
        from repro.kernels.paged_attention import ops, ref

        B, H, Hkv, dh, P, ps = 1, 4, 2, 32, 4, 8
        kq, kp_key = jax.random.split(KEY)
        kp, vp = self._pools(kp_key, P, ps, Hkv, dh)
        table = jnp.asarray([[1, 2]], jnp.int32)
        for cur in range(2 * ps):
            q = jax.random.normal(jax.random.fold_in(kq, cur), (B, H, dh),
                                  jnp.float32)
            c = jnp.asarray([cur], jnp.int32)
            got = ops.paged_decode_attention(q, kp, vp, table, c,
                                             interpret=True)
            want = ref.paged_decode_attention_ref(q, kp, vp, table, c)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-4, atol=2e-4, err_msg=f"cur={cur}")

    def test_fully_unmapped_slot_is_finite(self):
        """An inactive slot (all pages -1) must not produce NaN/inf — the
        batcher keeps dead slots decoding with frozen positions."""
        from repro.kernels.paged_attention import ops

        B, H, Hkv, dh, P, ps = 2, 4, 2, 32, 4, 8
        q = jax.random.normal(KEY, (B, H, dh), jnp.float32)
        kp, vp = self._pools(jax.random.fold_in(KEY, 1), P, ps, Hkv, dh)
        table = jnp.full((B, 2), -1, jnp.int32)
        cur = jnp.zeros((B,), jnp.int32)
        got = ops.paged_decode_attention(q, kp, vp, table, cur, interpret=True)
        assert np.isfinite(np.asarray(got)).all()


class TestPrefixAttention:
    """Two-phase (cached prefix, fresh suffix) kernel vs the concat oracle."""

    @pytest.mark.parametrize("H,Hkv", [(4, 2), (8, 1), (8, 8)])
    @pytest.mark.parametrize("Lp,Sq,qo", [(28, 4, 0), (10, 7, 3), (33, 9, 0)])
    def test_matches_ref(self, H, Hkv, Lp, Sq, qo):
        from repro.kernels.prefix_attention import ops, ref

        B, dh, Sk = 2, 32, Sq + qo
        kq, kp, kv, kk2, kv2 = jax.random.split(KEY, 5)
        q = jax.random.normal(kq, (B, Sq, H, dh), jnp.float32)
        pk = jax.random.normal(kp, (B, Lp, Hkv, dh), jnp.float32)
        pv = jax.random.normal(kv, (B, Lp, Hkv, dh), jnp.float32)
        k = jax.random.normal(kk2, (B, Sk, Hkv, dh), jnp.float32)
        v = jax.random.normal(kv2, (B, Sk, Hkv, dh), jnp.float32)
        got = ops.prefix_flash_attention(q, pk, pv, k, v, q_offset=qo,
                                         block_q=8, block_k=16, interpret=True)
        want = ref.prefix_flash_attention_ref(q, pk, pv, k, v, q_offset=qo)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_multi_block_both_phases(self):
        """Prefix and suffix each span several k blocks; q spans several
        q blocks — exercises the clamped index maps on both operands."""
        from repro.kernels.prefix_attention import ops, ref

        B, H, Hkv, dh, Lp, Sq = 1, 4, 2, 32, 21, 18
        kq, kp, kv, kk2, kv2 = jax.random.split(KEY, 5)
        q = jax.random.normal(kq, (B, Sq, H, dh), jnp.float32)
        pk = jax.random.normal(kp, (B, Lp, Hkv, dh), jnp.float32)
        pv = jax.random.normal(kv, (B, Lp, Hkv, dh), jnp.float32)
        k = jax.random.normal(kk2, (B, Sq, Hkv, dh), jnp.float32)
        v = jax.random.normal(kv2, (B, Sq, Hkv, dh), jnp.float32)
        got = ops.prefix_flash_attention(q, pk, pv, k, v, block_q=4,
                                         block_k=4, interpret=True)
        want = ref.prefix_flash_attention_ref(q, pk, pv, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_matches_self_attention_xla_path(self):
        """Kernel == the model's concat XLA path on bf16-cast prefix pages
        (the dtype round-trip cached admission actually performs)."""
        from repro.kernels.prefix_attention import ops
        from repro.models.attention import chunked_flash_attention

        B, H, Hkv, dh, Lp, Sq = 2, 4, 2, 32, 16, 8
        kq, kp, kv, kk2, kv2 = jax.random.split(KEY, 5)
        q = jax.random.normal(kq, (B, Sq, H, dh), jnp.float32)
        pk = jax.random.normal(kp, (B, Lp, Hkv, dh), jnp.float32)
        pv = jax.random.normal(kv, (B, Lp, Hkv, dh), jnp.float32)
        k = jax.random.normal(kk2, (B, Sq, Hkv, dh), jnp.float32)
        v = jax.random.normal(kv2, (B, Sq, Hkv, dh), jnp.float32)
        got = ops.prefix_flash_attention(q, pk, pv, k, v, interpret=True)
        want = chunked_flash_attention(
            q, jnp.concatenate([pk, k], axis=1),
            jnp.concatenate([pv, v], axis=1), causal=True, q_offset=Lp)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
