"""Pallas TPU kernels, validated in interpret mode against pure-jnp oracles.

Each kernel sweeps shapes/dtypes; assert_allclose vs ref.py.  interpret=True
executes the kernel body on CPU with TPU grid semantics (sequential innermost
axis, VMEM scratch carried across grid steps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    # f32: block-K accumulation order differs from the fused reference
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=5e-4, atol=5e-4)


class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 384),
                                       (512, 256, 128), (64, 1024, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, k, n, dtype):
        from repro.kernels.matmul import ops, ref

        ka, kb = jax.random.split(KEY)
        a = jax.random.normal(ka, (m, k), dtype)
        b = jax.random.normal(kb, (k, n), dtype)
        got = ops.matmul(a, b, block_m=128, block_n=128, block_k=128, interpret=True)
        want = ref.matmul_ref(a, b)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype)
        )


class TestFlashAttention:
    @pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 128)])
    @pytest.mark.parametrize("seq,heads,kv_heads", [(512, 4, 2), (1024, 8, 8), (384, 4, 1)])
    def test_matches_ref(self, causal, window, seq, heads, kv_heads):
        from repro.kernels.flash_attention import ops, ref

        kq, kk, kv = jax.random.split(KEY, 3)
        B, dh = 2, 64
        q = jax.random.normal(kq, (B, seq, heads, dh), jnp.float32)
        k = jax.random.normal(kk, (B, seq, kv_heads, dh), jnp.float32)
        v = jax.random.normal(kv, (B, seq, kv_heads, dh), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=causal, window=window,
                                  block_q=128, block_k=128, interpret=True)
        want = jnp.swapaxes(
            ref.flash_attention_ref(
                jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
                causal=causal, window=window,
            ), 1, 2,
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        from repro.kernels.flash_attention import ops, ref

        kq, kk, kv = jax.random.split(KEY, 3)
        q = jax.random.normal(kq, (1, 256, 2, 64), jnp.bfloat16)
        k = jax.random.normal(kk, (1, 256, 2, 64), jnp.bfloat16)
        v = jax.random.normal(kv, (1, 256, 2, 64), jnp.bfloat16)
        got = ops.flash_attention(q, k, v, causal=True, interpret=True)
        want = jnp.swapaxes(
            ref.flash_attention_ref(
                jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
            ), 1, 2,
        )
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(4, 128, 256), (2, 64, 1024), (1, 8, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype):
        from repro.kernels.rmsnorm import ops, ref

        kx, ks = jax.random.split(KEY)
        x = jax.random.normal(kx, shape, dtype)
        s = jax.random.normal(ks, (shape[-1],), dtype)
        got = ops.rmsnorm(x, s, interpret=True)
        want = ref.rmsnorm_ref(x, s)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **tol(dtype))


class TestDecodeAttention:
    @pytest.mark.parametrize("C,H,Hkv", [(128, 4, 2), (1024, 8, 1), (384, 8, 8)])
    @pytest.mark.parametrize("window", [None, 64])
    def test_matches_ref(self, C, H, Hkv, window):
        from repro.kernels.decode_attention import ops, ref

        kq, kk, kv = jax.random.split(KEY, 3)
        B, dh = 2, 64
        q = jax.random.normal(kq, (B, H, dh), jnp.float32)
        k = jax.random.normal(kk, (B, C, Hkv, dh), jnp.float32)
        v = jax.random.normal(kv, (B, C, Hkv, dh), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (B, C))
        cur = jnp.full((B,), C // 2, jnp.int32)
        got = ops.decode_attention(q, k, v, pos, cur, window=window,
                                   block_c=128, interpret=True)
        want = ref.decode_attention_ref(q, k, v, pos, cur, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


class TestSSDScan:
    @pytest.mark.parametrize("S,chunk", [(256, 64), (512, 128), (384, 128)])
    def test_matches_naive(self, S, chunk):
        from repro.kernels.ssd_scan import ops, ref

        ks = jax.random.split(KEY, 5)
        B, nh, hd, G, N = 2, 4, 32, 1, 16
        x = jax.random.normal(ks[0], (B, S, nh, hd), jnp.float32) * 0.1
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
        A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
        Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
        Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
        got = ops.ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
        want = ref.ssd_naive(x, dt, A, Bm, Cm)
        want = want[0] if isinstance(want, tuple) else want
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_final_state_matches_chunked_oracle(self):
        from repro.kernels.ssd_scan import ops
        from repro.models.ssm import ssd_chunked

        ks = jax.random.split(KEY, 5)
        B, S, nh, hd, G, N = 1, 256, 2, 16, 1, 8
        x = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.1
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
        A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
        Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
        Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
        got_y, got_st = ops.ssd(x, dt, A, Bm, Cm, chunk=64, return_state=True,
                                interpret=True)
        ref_y, ref_st = ssd_chunked(x, dt, A, Bm, Cm, chunk=64, return_state=True)
        np.testing.assert_allclose(np.asarray(got_st), np.asarray(ref_st),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(got_y), np.asarray(ref_y),
                                   rtol=2e-3, atol=2e-3)
