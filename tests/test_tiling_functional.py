"""Functional correctness of the paper's two tiling strategies, in JAX:
a conv/matmul layer tiled along WIDTH or OUTPUT-CHANNEL and re-assembled is
allclose to the untiled computation.  This is the semantic guarantee behind
the IFP machinery — the instruction-level model assumes tiles are
independent and exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ifp import _split


def conv2d(x, w, stride=1):
    """x: (H, W, Cin); w: (kh, kw, Cin, Cout) — SAME padding."""
    return jax.lax.conv_general_dilated(
        x[None], w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]


@pytest.fixture(scope="module")
def conv_case():
    k = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(k)
    x = jax.random.normal(kx, (14, 14, 32), jnp.float32)
    w = jax.random.normal(kw, (3, 3, 32, 64), jnp.float32) * 0.1
    return x, w


class TestConvTiling:
    @pytest.mark.parametrize("n_tiles", [2, 3, 7, 14])
    def test_width_tiling_exact(self, conv_case, n_tiles):
        """Width tiles need a halo of kw//2 input columns; stitched outputs
        equal the untiled conv."""
        x, w = conv_case
        ref = conv2d(x, w)
        H, W, _ = x.shape
        halo = w.shape[1] // 2
        chunks = _split(W, n_tiles)
        outs = []
        col = 0
        for wc in chunks:
            lo, hi = max(col - halo, 0), min(col + wc + halo, W)
            xin = x[:, lo:hi, :]
            # explicit zero padding where SAME padding would have applied
            pad_l = halo - (col - lo)
            pad_r = halo - (hi - (col + wc))
            xin = jnp.pad(xin, ((0, 0), (pad_l, pad_r), (0, 0)))
            out = jax.lax.conv_general_dilated(
                xin[None], w, (1, 1), "VALID",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )[0]
            # VALID on padded halo yields exactly wc columns... plus edge rows
            out = jnp.pad(out, ((w.shape[0] // 2, w.shape[0] // 2), (0, 0), (0, 0)))
            outs.append(out[: H, :wc, :] if out.shape[0] >= H else out)
            col += wc
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got)[1:-1], np.asarray(ref)[1:-1],
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("n_tiles", [2, 4, 16, 64])
    def test_oc_tiling_exact(self, conv_case, n_tiles):
        x, w = conv_case
        ref = conv2d(x, w)
        chunks = _split(w.shape[-1], n_tiles)
        outs, c = [], 0
        for co in chunks:
            outs.append(conv2d(x, w[..., c:c + co]))
            c += co
        got = jnp.concatenate(outs, axis=-1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


class TestMatmulTiling:
    """The LM analogue: width == tokens (DP shard), OC == features (TP shard)."""

    @pytest.fixture(scope="class")
    def case(self):
        k = jax.random.PRNGKey(1)
        ka, kb = jax.random.split(k)
        x = jax.random.normal(ka, (64, 128), jnp.float32)
        w = jax.random.normal(kb, (128, 256), jnp.float32) * 0.05
        return x, w

    @pytest.mark.parametrize("n", [2, 3, 16])
    def test_token_tiling(self, case, n):
        x, w = case
        ref = x @ w
        got = jnp.concatenate([c @ w for c in jnp.array_split(x, n, axis=0)], 0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("n", [2, 3, 16])
    def test_oc_tiling(self, case, n):
        x, w = case
        ref = x @ w
        got = jnp.concatenate([x @ c for c in jnp.array_split(w, n, axis=1)], 1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)

    def test_mixed_per_layer_choice(self, case):
        """A 2-layer net with W-tiling on layer 1 and OC-tiling on layer 2 —
        the dynamic compiler's per-layer strategy mix is functionally free."""
        x, w = case
        w2 = w.T * 0.1
        ref = jax.nn.relu(x @ w) @ w2
        h = jnp.concatenate([c @ w for c in jnp.array_split(x, 4, 0)], 0)
        h = jax.nn.relu(h)
        got = jnp.concatenate([h @ c for c in jnp.array_split(w2, 8, 1)], 1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)
