"""Attention numerics: chunked online-softmax == naive reference across
causal / sliding-window / GQA / offset variants, and the decode path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    _decode_attn_xla, chunked_flash_attention, naive_attention,
)


def rand_qkv(key, B=2, Sq=48, Sk=48, H=4, Hkv=2, dh=16):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Sq, H, dh), jnp.float32)
    k = jax.random.normal(kk, (B, Sk, Hkv, dh), jnp.float32)
    v = jax.random.normal(kv, (B, Sk, Hkv, dh), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("block_k", [8, 17, 48, 64])
def test_chunked_matches_naive(causal, window, block_k):
    q, k, v = rand_qkv(jax.random.PRNGKey(0))
    ref = naive_attention(q, k, v, causal=causal, window=window)
    got = chunked_flash_attention(q, k, v, causal=causal, window=window,
                                  block_k=block_k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_q_offset_decode_prefix():
    """Cross-attention of the LAST 8 queries against the full K/V with
    q_offset equals the tail of full self-attention."""
    q, k, v = rand_qkv(jax.random.PRNGKey(1), Sq=32, Sk=32)
    full = chunked_flash_attention(q, k, v, causal=True)
    tail = chunked_flash_attention(q[:, -8:], k, v, causal=True, q_offset=24)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, -8:]),
                               rtol=2e-5, atol=2e-5)


def test_gqa_equals_repeated_kv_mha():
    """GQA with Hkv=2 equals MHA with each kv head repeated group times."""
    q, k, v = rand_qkv(jax.random.PRNGKey(2), H=8, Hkv=2)
    got = chunked_flash_attention(q, k, v, causal=True)
    k_full = jnp.repeat(k, 4, axis=2)
    v_full = jnp.repeat(v, 4, axis=2)
    ref = chunked_flash_attention(q, k_full, v_full, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


class TestDecodeAttention:
    def test_decode_equals_full_attention_row(self):
        """One decode step against a seeded cache == the last row of
        full-sequence attention."""
        B, S, H, Hkv, dh = 2, 24, 4, 2, 16
        q, k, v = rand_qkv(jax.random.PRNGKey(3), B=B, Sq=S, Sk=S, H=H, Hkv=Hkv, dh=dh)
        full = naive_attention(q, k, v, causal=True)

        class Cfg:
            sliding_window = None

        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        out = _decode_attn_xla(
            q[:, -1:, :, :], k, v, pos, jnp.full((B,), S - 1), Cfg
        )
        np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]),
                                   rtol=2e-5, atol=2e-5)

    def test_empty_slots_masked(self):
        """Slots with pos = -1 (never written) contribute nothing."""
        B, C, H, Hkv, dh = 1, 16, 2, 1, 8
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(4), 3)
        q = jax.random.normal(kq, (B, 1, H, dh))
        k = jax.random.normal(kk, (B, C, Hkv, dh))
        v = jax.random.normal(kv, (B, C, Hkv, dh))

        class Cfg:
            sliding_window = None

        pos_half = jnp.where(jnp.arange(C) < 8, jnp.arange(C), -1)[None]
        out_half = _decode_attn_xla(q, k, v, pos_half, jnp.array([7]), Cfg)
        out_ref = _decode_attn_xla(
            q, k[:, :8], v[:, :8],
            jnp.arange(8)[None], jnp.array([7]), Cfg,
        )
        np.testing.assert_allclose(np.asarray(out_half), np.asarray(out_ref),
                                   rtol=2e-5, atol=2e-5)


class TestFlashVJP:
    """Custom-VJP flash attention: identical gradients to the reference,
    with O(S·block) residuals instead of per-block score tensors."""

    @pytest.mark.parametrize("causal,window,q_offset", [
        (True, None, 0), (False, None, 0), (True, 16, 0), (True, None, 32),
    ])
    def test_grads_match_naive(self, causal, window, q_offset):
        from repro.models.attention import flash_attention_train

        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        B, Sq, Sk, H, Hkv, dh = 2, 24, 24 + q_offset, 4, 2, 16
        q = jax.random.normal(ks[0], (B, Sq, H, dh), jnp.float32)
        k = jax.random.normal(ks[1], (B, Sk, Hkv, dh), jnp.float32)
        v = jax.random.normal(ks[2], (B, Sk, Hkv, dh), jnp.float32)

        def f(fn):
            return lambda q, k, v: (fn(q, k, v) ** 2).sum()

        flash = f(lambda q, k, v: flash_attention_train(
            q, k, v, causal=causal, window=window, q_offset=q_offset, block_k=8))
        ref = f(lambda q, k, v: naive_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset))
        g1 = jax.grad(flash, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)

    def test_train_step_with_flash_impl(self):
        """End-to-end: a train step with attn_impl='flash' matches 'xla'."""
        from repro.configs import get_reduced
        from repro.models import init_params
        from repro.optim import adamw_init
        from repro.training.steps import TrainerConfig, make_train_step

        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        batch = {"tokens": jnp.ones((2, 16), jnp.int32),
                 "labels": jnp.ones((2, 16), jnp.int32)}
        pa, _, ma = jax.jit(make_train_step(cfg, TrainerConfig(loss_chunk=8, attn_impl="xla")))(params, opt, batch)
        pb, _, mb = jax.jit(make_train_step(cfg, TrainerConfig(loss_chunk=8, attn_impl="flash")))(params, opt, batch)
        assert float(ma["loss"]) == pytest.approx(float(mb["loss"]), rel=1e-4)
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-3, atol=1e-4)


class TestCacheDtypeStability:
    def test_decode_never_promotes_cache(self):
        """Regression: RoPE's f32 K/V must not promote the bf16 cache via
        .at[].set — that round-trips the whole stacked cache through f32
        converts every layer (EXPERIMENTS.md §Perf D3)."""
        from repro.configs import get_reduced
        from repro.models import init_params
        from repro.models.attention import decode_attention, init_kv_cache

        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], params["blocks"][0])["attn"]
        cache = init_kv_cache(cfg, batch=2, max_len=8)
        assert cache.k.dtype == jnp.bfloat16
        x = jnp.ones((2, 1, cfg.d_model), jnp.bfloat16)
        _, new_cache = decode_attention(lp, x, cache, jnp.zeros((2,), jnp.int32), cfg)
        assert new_cache.k.dtype == jnp.bfloat16
        assert new_cache.v.dtype == jnp.bfloat16
