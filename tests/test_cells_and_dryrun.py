"""Cell construction + dry-run plumbing at reduced scale (1 CPU device),
plus validation of the committed 512-device dry-run artifacts."""

import glob
import json
import os

import pytest

from repro.configs import cell_status, cells


class TestCellStatus:
    def test_forty_cells(self):
        cs = cells()
        assert len(cs) == 40
        skips = [c for c in cs if not c["runs"]]
        assert {(c["arch"], c["shape"]) for c in skips} == {
            (a, "long_500k")
            for a in ("command-r-plus-104b", "qwen3-0.6b", "starcoder2-7b",
                      "qwen3-32b", "deepseek-moe-16b", "qwen2-vl-72b",
                      "whisper-base")
        }

    def test_subquadratic_archs_run_long(self):
        for arch in ("mixtral-8x22b", "mamba2-370m", "jamba-1.5-large-398b"):
            runs, _ = cell_status(arch, "long_500k")
            assert runs


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mixtral-8x22b", "mamba2-370m",
                                  "jamba-1.5-large-398b", "whisper-base",
                                  "qwen2-vl-72b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_reduced_cell_lowers_and_runs(arch, shape):
    """build_cell(reduced=True) on the host mesh must lower, compile, AND
    execute with real (tiny) inputs — the strongest smoke we can run on CPU."""
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(shape=(1, 1))
    cell = build_cell(arch, shape, mesh, reduced=True)
    with mesh:
        compiled = cell.lower().compile()
    assert compiled is not None
    assert cell.model_flops > 0


class TestDryrunArtifacts:
    """The 512-device artifacts are produced by `python -m repro.launch.dryrun
    --all --both`; these tests validate whatever has been committed."""

    DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

    def _records(self, tag):
        paths = glob.glob(os.path.join(self.DIR, f"*__{tag}.json"))
        return [json.load(open(p)) for p in paths]

    @pytest.mark.parametrize("tag", ["pod16x16", "pod2x16x16"])
    def test_all_cells_ok(self, tag):
        recs = self._records(tag)
        if not recs:
            pytest.skip("dry-run artifacts not generated yet")
        assert len(recs) == 40
        bad = [(r["arch"], r["shape"], r.get("error", "")[:80])
               for r in recs if not r.get("ok")]
        assert not bad, bad

    def test_roofline_terms_sane(self):
        recs = [r for r in self._records("pod16x16") if r.get("ok") and not r.get("skipped")]
        if not recs:
            pytest.skip("dry-run artifacts not generated yet")
        for r in recs:
            roof = r["roofline"]
            assert roof["t_compute"] >= 0
            assert roof["bound"] in ("compute", "memory", "collective")
            # useful-FLOPs ratio: HLO must contain at least the model math
            # (<=1.25 tolerates analyzer undercount of non-dot ops)
            assert 0 < roof["useful_flops_ratio"] < 1.25, (r["arch"], r["shape"], roof["useful_flops_ratio"])

    def test_memory_fits_hbm(self):
        """Every compiled cell fits 16 GiB/device — the memory_analysis
        'proves it fits' requirement of the brief."""
        for tag in ("pod16x16", "pod2x16x16"):
            for r in self._records(tag):
                if not r.get("ok") or r.get("skipped"):
                    continue
                per_dev = r.get("per_device_bytes")
                assert per_dev is not None
                assert per_dev < 16 * 2**30, (r["arch"], r["shape"], tag, per_dev / 2**30)

    def test_multipod_shards_pod_axis(self):
        """The 2-pod mesh halves (or keeps equal) per-device argument bytes
        for train cells vs 1-pod — proof the pod axis actually shards."""
        one = {(r["arch"], r["shape"]): r for r in self._records("pod16x16")}
        two = {(r["arch"], r["shape"]): r for r in self._records("pod2x16x16")}
        if not one or not two:
            pytest.skip("dry-run artifacts not generated yet")
        checked = 0
        for key, r1 in one.items():
            r2 = two.get(key)
            if not (r1.get("ok") and r2 and r2.get("ok")) or r1.get("skipped"):
                continue
            if key[1] != "train_4k":
                continue
            a1 = r1["memory"].get("argument_size_in_bytes")
            a2 = r2["memory"].get("argument_size_in_bytes")
            if a1 and a2:
                # params replicated across pods, batch split: args/device
                # must not grow moving to 2 pods
                assert a2 <= a1 * 1.05, (key, a1, a2)
                checked += 1
        assert checked >= 5
