"""Instruction IR + latency simulator: dependency/overlap semantics."""

import pytest

from repro.core import (
    Chain, HardwareModel, Op, Program, concat, simulate,
    simulate_layer_barrier,
)
from repro.core.isa import SYNC_PROGRAM


def flat_hw(**kw):
    """1 B/s, 1 FLOP/s hardware: durations == raw flops/bytes (no quant)."""
    args = dict(name="flat", flops_per_sec=1.0, mem_bw=1.0, bw_eff=1.0,
                sync_latency=0.0, instr_overhead=0.0)
    args.update(kw)
    return HardwareModel(**args)


class TestProgram:
    def test_emit_and_validate(self):
        p = Program()
        a = p.load(10.0)
        b = p.emit(Op.CONV, flops=5.0, deps=[a])
        p.save(3.0, deps=[b])
        p.validate()
        assert len(p) == 3
        assert p.total_flops == 5.0
        assert p.total_bytes == 13.0

    def test_forward_dep_rejected(self):
        p = Program()
        p.emit(Op.CONV, flops=1.0, deps=[5])
        with pytest.raises(ValueError):
            p.validate()

    def test_concat_relabels(self):
        p1 = Program(); a = p1.load(1.0); p1.emit(Op.CONV, flops=1.0, deps=[a])
        p2 = Program(); b = p2.load(2.0); p2.emit(Op.CONV, flops=2.0, deps=[b])
        c = concat([p1, p2])
        c.validate()
        assert len(c) == 4
        assert c.instrs[3].deps == [2]


class TestSimulator:
    def test_units_overlap(self):
        """LOAD and CONV are different units: independent instrs overlap."""
        p = Program()
        p.load(10.0)                       # 10 s on LOAD
        p.emit(Op.CONV, flops=10.0)        # 10 s on CONV, no dep -> parallel
        assert simulate(p, flat_hw()) == pytest.approx(10.0)

    def test_dependency_serializes(self):
        p = Program()
        a = p.load(10.0)
        p.emit(Op.CONV, flops=10.0, deps=[a])
        assert simulate(p, flat_hw()) == pytest.approx(20.0)

    def test_same_unit_serializes(self):
        p = Program()
        p.load(10.0)
        p.load(5.0)
        assert simulate(p, flat_hw()) == pytest.approx(15.0)

    def test_load_compute_pipeline(self):
        """Grouped loads overlap with compute of the previous group — the
        reason the ISA carries dependency fields (paper §5.1)."""
        p = Program()
        for _ in range(4):
            ld = p.load(10.0)
            p.emit(Op.CONV, flops=10.0, deps=[ld])
        # pipeline: 10 (first load) + 4*10 (compute, loads hidden) = 50
        assert simulate(p, flat_hw()) == pytest.approx(50.0)

    def test_chain_equals_concat(self):
        p1 = Program(); a = p1.load(4.0); p1.emit(Op.CONV, flops=3.0, deps=[a])
        p2 = Program(); b = p2.load(2.0); p2.emit(Op.CONV, flops=7.0, deps=[b])
        hw = flat_hw()
        assert simulate(Chain([p1, p2]), hw) == pytest.approx(
            simulate(concat([p1, p2]), hw)
        )

    def test_compute_tile_quantization(self):
        """Eq. 2 ceil-quantization: a 1-channel conv on an (1,1,8)-tile core
        wastes 7/8 of the array."""
        hw = flat_hw(flops_per_sec=8.0, compute_tile=(1, 1, 8))
        p = Program()
        p.emit(Op.CONV, flops=8.0, shape=(1, 1, 1))
        assert simulate(p, hw) == pytest.approx(8.0)   # util 1/8 -> 8x slower
        p2 = Program()
        p2.emit(Op.CONV, flops=8.0, shape=(1, 1, 8))
        assert simulate(p2, hw) == pytest.approx(1.0)

    def test_layer_barrier_adds_sync(self):
        hw = flat_hw(sync_latency=0.5)
        def mk(f):
            return Chain([_conv_prog(f)])
        per_core = [[mk(4.0), mk(1.0)], [mk(2.0), mk(3.0)]]
        t = simulate_layer_barrier(per_core, hw)
        # layer0: max(4,2)=4; layer1: max(1,3)=3; +2 syncs
        assert t == pytest.approx(4 + 3 + 1.0)


def _conv_prog(flops):
    p = Program()
    p.emit(Op.CONV, flops=flops)
    return p


class TestSyncProgram:
    def test_shared_sync_is_single_sync_instr(self):
        assert len(SYNC_PROGRAM) == 1
        assert SYNC_PROGRAM.instrs[0].is_sync
