"""Gradient compression (int8 + error feedback) and the synthetic pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.distributed.compression import (
    compress_with_feedback, dequantize_int8, quantize_int8,
)


class TestInt8Quantization:
    @given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                    min_size=1, max_size=600))
    @settings(max_examples=100, deadline=None)
    def test_roundtrip_error_bounded(self, vals):
        """Property: |x - dq(q(x))| <= blockmax/127/2 + eps, elementwise."""
        x = jnp.asarray(vals, jnp.float32)
        q, s = quantize_int8(x)
        got = dequantize_int8(q, s, x.shape)
        bound = np.asarray(s).max() * 0.5 + 1e-6
        assert float(jnp.abs(got - x).max()) <= bound + 1e-5

    def test_zero_tensor(self):
        x = jnp.zeros((300,), jnp.float32)
        q, s = quantize_int8(x)
        np.testing.assert_array_equal(np.asarray(dequantize_int8(q, s, x.shape)), 0)

    @given(st.integers(1, 2000))
    @settings(max_examples=50, deadline=None)
    def test_shapes(self, n):
        x = jnp.ones((n,), jnp.float32)
        q, s = quantize_int8(x, block=256)
        nb = -(-n // 256)
        assert q.shape == (nb, 256)
        assert s.shape == (nb, 1)


class TestErrorFeedback:
    def test_residual_accumulates_truth(self):
        """Error feedback: summed dequantized updates converge to the summed
        true gradient (bias-free), unlike naive quantization."""
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=512) * 1e-3, jnp.float32)
        e = jnp.zeros_like(g)
        acc_fb = jnp.zeros_like(g)
        acc_naive = jnp.zeros_like(g)
        steps = 50
        for _ in range(steps):
            q, s, e = compress_with_feedback(g, e)
            acc_fb = acc_fb + dequantize_int8(q, s, g.shape)
            qn, sn = quantize_int8(g * 0 + g)   # naive, no feedback
            acc_naive = acc_naive + dequantize_int8(qn, sn, g.shape)
        true = g * steps
        err_fb = float(jnp.abs(acc_fb - true).max())
        # feedback keeps total error within one quantization step
        assert err_fb <= float(jnp.max(jnp.abs(g))) / 127 + 1e-6


class TestCompressedPsum:
    def test_matches_plain_psum(self):
        """int8 pod-psum ≈ exact mean within quantization tolerance; error
        feedback carries the residual."""
        n_dev = 4
        devs = jax.devices()
        if len(devs) < n_dev:
            # emulate with vmap'd shard_map over a 1-device mesh: skip
            pytest.skip("needs 4 devices; covered by dryrun-time usage")

    def test_compress_semantics_single_process(self):
        """Numerical check of the wire scheme without a mesh: quantize with
        the shared scale, sum, dequantize — error bounded by block max."""
        n = 4
        rng = np.random.default_rng(2)
        gs = [jnp.asarray(rng.normal(size=300), jnp.float32) for _ in range(n)]
        from repro.distributed.compression import _blockify

        xs = [g / n for g in gs]
        blocks = [_blockify(x, 256)[0] for x in xs]
        gmax = jnp.max(jnp.stack([jnp.max(jnp.abs(b), 1, keepdims=True) for b in blocks]), 0)
        scale = jnp.maximum(gmax / (127.0 / n), 1e-12)
        qs = [jnp.clip(jnp.round(b / scale), -127 / n, 127 / n).astype(jnp.int8) for b in blocks]
        qsum = sum(q.astype(jnp.int32) for q in qs)
        assert int(jnp.abs(qsum).max()) <= 127          # wire fits int8
        red = (qsum.astype(jnp.float32) * scale).reshape(-1)[:300]
        truth = sum(xs)
        tol = float(scale.max()) * n * 0.5 + 1e-6
        assert float(jnp.abs(red - truth).max()) <= tol


class TestSyntheticData:
    def test_deterministic_replay(self):
        from repro.configs import get_reduced
        from repro.data.synthetic import make_batch

        cfg = get_reduced("qwen3-0.6b")
        a = make_batch(cfg, seq_len=32, batch=4, step=7, seed=3)
        b = make_batch(cfg, seq_len=32, batch=4, step=7, seed=3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_hosts_disjoint_streams(self):
        from repro.configs import get_reduced
        from repro.data.synthetic import make_batch

        cfg = get_reduced("qwen3-0.6b")
        a = make_batch(cfg, seq_len=64, batch=8, step=1, host=0, n_hosts=2)
        b = make_batch(cfg, seq_len=64, batch=8, step=1, host=1, n_hosts=2)
        assert a["tokens"].shape == (4, 64)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_tokens(self):
        from repro.configs import get_reduced
        from repro.data.synthetic import make_batch

        cfg = get_reduced("qwen3-0.6b")
        d = make_batch(cfg, seq_len=32, batch=2, step=0)
        np.testing.assert_array_equal(d["labels"][:, :-1], d["tokens"][:, 1:])
        assert (d["labels"][:, -1] == -1).all()

    def test_tokens_within_vocab(self):
        from repro.configs import get_reduced
        from repro.data.synthetic import make_batch

        cfg = get_reduced("deepseek-moe-16b")
        d = make_batch(cfg, seq_len=128, batch=4, step=2)
        assert d["tokens"].min() >= 0
        assert d["tokens"].max() < cfg.vocab

    def test_family_extras(self):
        from repro.configs import get_reduced
        from repro.data.synthetic import make_batch

        vlm = get_reduced("qwen2-vl-72b")
        d = make_batch(vlm, seq_len=64, batch=2, step=0, reduced=True)
        assert "extra_embeds" in d and "positions" in d
        assert d["positions"].shape[0] == 3

        audio = get_reduced("whisper-base")
        d = make_batch(audio, seq_len=64, batch=2, step=0, reduced=True)
        assert "frames" in d
        assert d["frames"].shape[1] == audio.enc_seq
