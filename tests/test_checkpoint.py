"""Checkpointing: roundtrip, GC, async writer, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (
    AsyncCheckpointer, latest_step, read_metadata, restore, save,
)


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"w": jnp.ones((5,), jnp.bfloat16), "n": jnp.int32(7)},
    }


class TestSaveRestore:
    def test_roundtrip_bitexact(self, tmp_path):
        t = tree()
        save(str(tmp_path), 10, t)
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(jnp.shape(a), a.dtype), t)
        got = restore(str(tmp_path), like)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_and_gc(self, tmp_path):
        for s in (1, 2, 3, 4, 5):
            save(str(tmp_path), s, tree(), keep=3)
        assert latest_step(str(tmp_path)) == 5
        kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert len(kept) == 3

    def test_missing_leaf_raises(self, tmp_path):
        save(str(tmp_path), 1, {"a": jnp.zeros(3)})
        with pytest.raises(KeyError):
            restore(str(tmp_path), {"a": jnp.zeros(3), "extra": jnp.zeros(2)})

    def test_metadata(self, tmp_path):
        save(str(tmp_path), 3, tree(), metadata={"mesh": [4, 4], "arch": "x"})
        md = read_metadata(str(tmp_path))
        assert md["metadata"]["mesh"] == [4, 4]

    def test_dtype_cast_on_restore(self, tmp_path):
        save(str(tmp_path), 1, {"a": jnp.ones((4,), jnp.float32)})
        got = restore(str(tmp_path), {"a": jax.ShapeDtypeStruct((4,), jnp.bfloat16)})
        assert got["a"].dtype == jnp.bfloat16


class TestAsync:
    def test_async_write_then_wait(self, tmp_path):
        ck = AsyncCheckpointer(str(tmp_path))
        ck.save_async(7, tree())
        ck.wait()
        assert latest_step(str(tmp_path)) == 7
        assert ck.last_saved == 7

    def test_snapshot_semantics(self, tmp_path):
        """Mutation after save_async must not leak into the checkpoint."""
        ck = AsyncCheckpointer(str(tmp_path))
        t = {"a": np.zeros(4, np.float32)}
        ck.save_async(1, t)
        t["a"][:] = 99.0
        ck.wait()
        got = restore(str(tmp_path), {"a": jnp.zeros(4)})
        np.testing.assert_array_equal(np.asarray(got["a"]), np.zeros(4))


class TestElasticRestore:
    def test_restore_training_state_continues(self, tmp_path):
        """Kill/restore: training resumed from a checkpoint produces the
        identical next step as the uninterrupted run (bit-continuity)."""
        from repro.configs import get_reduced
        from repro.models import init_params
        from repro.optim import adamw_init
        from repro.training.steps import TrainerConfig, make_train_step

        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg, TrainerConfig(loss_chunk=8)))
        batch = {"tokens": jnp.ones((2, 16), jnp.int32),
                 "labels": jnp.ones((2, 16), jnp.int32)}

        p1, o1, _ = step(params, opt, batch)
        save(str(tmp_path), 1, {"params": p1, "opt": o1})
        p2_direct, o2_direct, _ = step(p1, o1, batch)

        like = {
            "params": jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), p1),
            "opt": jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), o1),
        }
        got = restore(str(tmp_path), like)
        p2_resume, o2_resume, _ = step(got["params"], got["opt"], batch)
        for a, b in zip(jax.tree.leaves(p2_direct), jax.tree.leaves(p2_resume)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
