"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the real
single CPU device; only launch/dryrun.py forces 512 host devices."""

import pytest


@pytest.fixture(scope="session")
def fpga_hw():
    from repro.core import fpga_small_core

    return fpga_small_core()


@pytest.fixture(scope="session")
def resnet_artifact(fpga_hw):
    from repro.core import CNN_WORKLOADS, StaticCompiler

    return StaticCompiler(fpga_hw, n_tiles=16).compile(CNN_WORKLOADS["resnet50"]())


@pytest.fixture(scope="session")
def mobilenet_artifact(fpga_hw):
    from repro.core import CNN_WORKLOADS, StaticCompiler

    return StaticCompiler(fpga_hw, n_tiles=16).compile(CNN_WORKLOADS["mobilenet"]())
