"""Training loop: loss decreases, grad-accum equivalence, quantized AdamW,
schedules, clipping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.synthetic import make_batch
from repro.models import init_params
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedules import SCHEDULES
from repro.training.steps import TrainerConfig, make_train_step

KEY = jax.random.PRNGKey(0)


class TestTrainingLoop:
    def test_loss_decreases_on_fixed_batch(self):
        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg, TrainerConfig(lr=3e-3, loss_chunk=16)))
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, seq_len=32, batch=4, step=0).items()}
        first = None
        for i in range(25):
            params, opt, m = step(params, opt, batch)
            if first is None:
                first = float(m["loss"])
        last = float(m["loss"])
        assert last < first * 0.7, (first, last)

    def test_grad_accum_equivalence(self):
        """grad_accum=2 on batch 4 == grad_accum=1 (same grads up to f32
        accumulation noise) — the metrics and updated params must agree."""
        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        opt = adamw_init(params)
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, seq_len=16, batch=4, step=0).items()}
        s1 = jax.jit(make_train_step(cfg, TrainerConfig(loss_chunk=8, grad_accum=1)))
        s2 = jax.jit(make_train_step(cfg, TrainerConfig(loss_chunk=8, grad_accum=2)))
        p1, _, m1 = s1(params, opt, batch)
        p2, _, m2 = s2(params, opt, batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=2e-2, atol=2e-3,
            )

    def test_remat_full_matches_none(self):
        """Activation checkpointing changes memory, not math."""
        cfg = get_reduced("qwen3-0.6b")
        params = init_params(cfg, KEY)
        opt = adamw_init(params)
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, seq_len=16, batch=2, step=0).items()}
        pa, _, ma = jax.jit(make_train_step(cfg, TrainerConfig(loss_chunk=8, remat="none")))(params, opt, batch)
        pb, _, mb = jax.jit(make_train_step(cfg, TrainerConfig(loss_chunk=8, remat="full")))(params, opt, batch)
        assert float(ma["loss"]) == pytest.approx(float(mb["loss"]), rel=1e-4)
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), rtol=1e-3, atol=1e-4)


class TestAdamW:
    def test_quantized_close_to_f32(self):
        """8-bit AdamW tracks full-precision AdamW within quantization noise
        over a few steps."""
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.normal(size=(64, 64)) * 0.1, jnp.float32)}
        g = {"w": jnp.asarray(rng.normal(size=(64, 64)) * 0.01, jnp.float32)}
        p_f, s_f = dict(params), adamw_init(params)
        p_q, s_q = dict(params), adamw_init(params, quantize=True)
        for _ in range(5):
            p_f, s_f = adamw_update(g, s_f, p_f, lr=1e-2)
            p_q, s_q = adamw_update(g, s_q, p_q, lr=1e-2, quantized=True)
        diff = np.abs(np.asarray(p_q["w"]) - np.asarray(p_f["w"]))
        # int8 sqrt-space moments: per-element drift bounded, mean tiny
        assert float(diff.mean()) < 2e-3
        assert float(diff.max()) < 5e-2
        corr = np.corrcoef(np.asarray(p_q["w"]).ravel(), np.asarray(p_f["w"]).ravel())[0, 1]
        assert corr > 0.999

    def test_quantized_state_memory(self):
        """8-bit moments cost ~2 B/param vs 8 B for f32."""
        params = {"w": jnp.zeros((1024, 256), jnp.float32)}
        s = adamw_init(params, quantize=True)
        q_bytes = (s.m["w"].q.size * 1 + s.m["w"].scale.size * 4) * 2
        f_bytes = 2 * params["w"].size * 4
        assert q_bytes < f_bytes / 3

    def test_weight_decay_shrinks_params(self):
        params = {"w": jnp.ones((8,), jnp.float32)}
        g = {"w": jnp.zeros((8,), jnp.float32)}
        s = adamw_init(params)
        p2, _ = adamw_update(g, s, params, lr=1e-1, weight_decay=0.5)
        assert float(p2["w"][0]) < 1.0

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(np.sqrt(800), rel=1e-5)
        from repro.optim import global_norm

        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


class TestSchedules:
    def test_warmup_cosine(self):
        fn = SCHEDULES["warmup_cosine"](1e-3, 10, 100)
        assert float(fn(0)) < float(fn(9))
        assert float(fn(10)) == pytest.approx(1e-3, rel=1e-3)
        assert float(fn(99)) < 1e-3 * 0.2

    def test_constant(self):
        fn = SCHEDULES["constant"](5e-4)
        assert float(fn(0)) == float(fn(1000)) == pytest.approx(5e-4)
