"""Paged KV pool: token identity vs the dense path, device page-table /
free-list invariants, quota enforcement under over-subscription, and
mid-run migration of the paged state."""

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.kv_cache import PagedKVPool, PageQuotaError, pages_for

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    return cfg, init_params(cfg, KEY)


def _prompts(cfg, n, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, size=1 + i % 6).astype(np.int32)
            for i in range(n)]


def _run(params, cfg, prompts, *, eos_map=None, max_new=10, chunk=8, **kw):
    b = ContinuousBatcher(params, cfg, slots=4, prompt_len=8, max_len=64,
                          chunk=chunk, **kw)
    reqs = [Request(rid=i, prompt=p, max_new=max_new + i % 4,
                    eos=(eos_map or {}).get(i))
            for i, p in enumerate(prompts)]
    for r in reqs:
        b.submit(r)
    b.run(max_steps=4000)
    return b, reqs


def _assert_pool_invariants(b):
    """No page mapped twice, and mapped + free partitions the pool."""
    tab = np.asarray(b.pages.table)
    free = np.asarray(b.pages.free)
    top = int(b.pages.free_top)
    mapped = tab[tab >= 0].tolist()
    assert len(mapped) == len(set(mapped)), "page mapped to two slots"
    assert sorted(set(mapped) | set(free[:top].tolist())) == \
        list(range(b.n_pages)), "free-list conservation violated"
    # host ledger never exceeds the lease cap
    assert b.kv_pool.used <= b._page_limit
    b.kv_pool.check()


class TestPagedIdentity:
    """Paging must be a pure memory-layout change: request token streams
    identical to the dense ring-buffer path (which test_serving pins to the
    per-step reference, so identity is transitive)."""

    def test_paged_matches_dense(self, qwen):
        cfg, params = qwen
        prompts = _prompts(cfg, 8)
        _, dense = _run(params, cfg, prompts)
        bp, paged = _run(params, cfg, prompts, paged=True, page_size=8)
        for a, g in zip(dense, paged):
            assert a.done and g.done
            assert a.out == g.out, (a.rid, a.out, g.out)
        _assert_pool_invariants(bp)

    def test_page_boundary_crossing(self, qwen):
        """page_size=4 forces several boundary crossings (prompt bucket is 8
        = 2 pages, decode crosses into pages 2..5); streams stay identical
        and slots really span multiple pages."""
        cfg, params = qwen
        prompts = _prompts(cfg, 6, seed=5)
        _, dense = _run(params, cfg, prompts, max_new=14)
        bp, paged = _run(params, cfg, prompts, max_new=14, paged=True,
                         page_size=4)
        for a, g in zip(dense, paged):
            assert a.out == g.out, (a.rid, a.out, g.out)
        assert bp.stats.peak_pages_in_use > pages_for(8, 4), \
            "decode never faulted past the prompt pages"
        _assert_pool_invariants(bp)

    def test_eos_mid_chunk(self, qwen):
        """A request whose EOS lands mid-chunk finishes at the same token
        under paging, and its pages return to the free list."""
        cfg, params = qwen
        prompts = _prompts(cfg, 6, seed=7)
        _, probe = _run(params, cfg, prompts)
        eos_map = {0: probe[0].out[3]}
        _, dense = _run(params, cfg, prompts, eos_map=eos_map)
        bp, paged = _run(params, cfg, prompts, eos_map=eos_map, paged=True,
                         page_size=8)
        for a, g in zip(dense, paged):
            assert a.done and g.done
            assert a.out == g.out, (a.rid, a.out, g.out)
        assert paged[0].out[-1] == eos_map[0]
        assert len(paged[0].out) < 10
        # everything completed: every page is back on the free stack
        assert int(bp.pages.free_top) == bp.n_pages
        _assert_pool_invariants(bp)

    def test_chunk_one_matches_chunk_eight(self, qwen):
        """chunk==per-step identity *under paging*: the fused paged scan
        emits the same streams as single-step paged chunks."""
        cfg, params = qwen
        prompts = _prompts(cfg, 6, seed=11)
        _, one = _run(params, cfg, prompts, chunk=1, paged=True, page_size=8)
        _, eight = _run(params, cfg, prompts, chunk=8, paged=True,
                        page_size=8)
        for a, g in zip(one, eight):
            assert a.out == g.out, (a.rid, a.out, g.out)


class TestPoolInvariants:
    def test_conservation_across_churn(self, qwen):
        """Admit/complete cycles over an over-subscribed pool (with
        reservations) keep the table/free-list partition exact."""
        cfg, params = qwen
        prompts = _prompts(cfg, 10, seed=13)
        b, reqs = _run(params, cfg, prompts, paged=True, page_size=8,
                       n_pages=6)
        assert all(r.done for r in reqs)
        assert b.stats.peak_pages_in_use <= 6
        _assert_pool_invariants(b)

    def test_quota_enforced_on_oversubscription(self, qwen):
        """A kv_pages lease below the pool caps device allocation; denied
        faults requeue (oom_requeues) and everything still completes."""
        cfg, params = qwen
        prompts = _prompts(cfg, 8, seed=17)
        b, reqs = _run(params, cfg, prompts, paged=True, page_size=8,
                       n_pages=16, page_quota=5, reserve_pages=False)
        assert all(r.done for r in reqs)
        assert b.stats.peak_pages_in_use <= 5, \
            "device allocation exceeded the kv_pages quota"
        assert b.stats.oom_requeues > 0, \
            "over-subscription never exercised the denial path"
        _assert_pool_invariants(b)

    def test_page_limit_resize_cycle(self, qwen):
        """Shrinking the page lease mid-run throttles allocation (drain, no
        revocation); growing it back restores throughput.  Conservation
        holds at every sync."""
        cfg, params = qwen
        prompts = _prompts(cfg, 8, seed=19)
        b = ContinuousBatcher(params, cfg, slots=4, prompt_len=8, max_len=64,
                              chunk=4, paged=True, page_size=8, n_pages=16,
                              reserve_pages=False)
        reqs = [Request(rid=i, prompt=p, max_new=10) for i, p in
                enumerate(prompts)]
        for r in reqs:
            b.submit(r)
        b.step()
        b.set_page_limit(4)                      # hypervisor shrank the lease
        for _ in range(4):
            b.step()
            _assert_pool_invariants(b)
        assert int(b.pages.quota) == 4
        b.set_page_limit(16)                     # lease grew back
        b.run(max_steps=4000)
        assert all(r.done for r in reqs)
        _assert_pool_invariants(b)

    def test_admit_only_rounds_do_not_starve_admission(self, qwen):
        """Requests that finish at admission (max_new=1) pop no device
        pages; the host's since-sync estimate must not leak and starve an
        entirely free pool (regression: over-subscribed admission counter
        only reset after a decode chunk)."""
        cfg, params = qwen
        b = ContinuousBatcher(params, cfg, slots=4, prompt_len=8, max_len=64,
                              chunk=4, paged=True, page_size=8, n_pages=4,
                              reserve_pages=False)
        rng = np.random.default_rng(31)
        reqs = [Request(rid=i,
                        prompt=rng.integers(1, cfg.vocab,
                                            size=1 + i % 6).astype(np.int32),
                        max_new=1)
                for i in range(12)]
        for r in reqs:
            b.submit(r)
        b.run(max_steps=2000)
        assert all(r.done for r in reqs), [r.done for r in reqs]
        assert b._admitted_pages_since_sync == 0
        _assert_pool_invariants(b)

    def test_submit_rejects_impossible_footprint(self, qwen):
        cfg, params = qwen
        b = ContinuousBatcher(params, cfg, slots=2, prompt_len=8, max_len=64,
                              chunk=4, paged=True, page_size=8, n_pages=2)
        with pytest.raises(AssertionError):
            b.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                             max_new=40))


class TestLedger:
    """Host-side PagedKVPool: counts, quotas, conservation errors."""

    def test_alloc_free_quota(self):
        pool = PagedKVPool(10, 16)
        pool.set_quota("a", 6)
        assert pool.alloc("a", 4) == 4
        assert pool.alloc("b", 5) == 5
        assert pool.available == 1
        with pytest.raises(PageQuotaError):
            pool.alloc("a", 3)                   # quota (4+3 > 6)
        with pytest.raises(PageQuotaError):
            pool.alloc("b", 2)                   # pool (9+2 > 10)
        assert pool.free("a", 2) == 2
        assert pool.held_by("a") == 2
        assert pool.free("b") == 5               # free-all
        assert pool.available == 8
        pool.check()

    def test_oversubscribed_quotas_are_legal(self):
        """Quota sum may exceed the pool (that IS over-subscription); only
        actual reservations are bounded."""
        pool = PagedKVPool(10, 16)
        pool.set_quota("a", 8)
        pool.set_quota("b", 8)
        pool.alloc("a", 6)
        with pytest.raises(PageQuotaError):
            pool.alloc("b", 5)
        pool.alloc("b", 4)
        pool.check()


class TestMigration:
    def test_resize_between_chunks_migrates_paged_state(self, qwen):
        """A hypervisor resize between chunks migrates caches AND page
        tables/free list; paged decode resumes token-identically."""
        from repro.core import TenantSpec
        from repro.serving.tenancy import (
            VirtualAcceleratorPool, make_serving_hypervisor,
        )
        import jax.numpy as jnp

        cfg, params = qwen
        prompts = _prompts(cfg, 3, seed=23)

        def reqs():
            return [Request(rid=i, prompt=p, max_new=9)
                    for i, p in enumerate(prompts)]

        def batcher():
            return ContinuousBatcher(params, cfg, slots=4, prompt_len=8,
                                     max_len=64, chunk=4, paged=True,
                                     page_size=8)

        ref = batcher()
        ref_reqs = reqs()
        for r in ref_reqs:
            ref.submit(r)
        ref.run(max_steps=2000)

        pool = VirtualAcceleratorPool(devices=jax.devices() * 4,
                                      devices_per_core=1)
        hv, ex = make_serving_hypervisor(pool, policy="no_realloc")

        def mesh_builder(n):
            import jax.sharding as jsh
            devs = np.array(jax.devices() * n, dtype=object)[:n].reshape(n, 1)
            return jsh.Mesh(devs, ("data", "model"))

        ex.compiler.static_compile(
            "decode", lambda x: x, (jax.ShapeDtypeStruct((4,), jnp.float32),),
            lease_sizes=[1, 2], mesh_builder=mesh_builder)
        assert hv.admit(TenantSpec("t", 1, artifact="decode"))

        b = batcher()
        ex.register_state("t", b.live_state, on_migrate=b.adopt_state)
        got_reqs = reqs()
        for r in got_reqs:
            b.submit(r)
        b.step()
        hv.resize_request("t", 2)
        assert ex.reconfig_log and "t_migrate" in ex.reconfig_log[-1]
        b.run(max_steps=2000)
        for a, g in zip(ref_reqs, got_reqs):
            assert a.out == g.out
        _assert_pool_invariants(b)

    def test_kv_lease_drives_batcher_page_limit(self, qwen):
        """Full loop: hypervisor kv_pages grant -> ServingExecutor
        exec_kv_resize -> ContinuousBatcher.set_page_limit; shrink lands on
        the device quota and a second tenant's admission re-splits pages."""
        from repro.core import TenantSpec
        from repro.serving.tenancy import (
            VirtualAcceleratorPool, make_serving_hypervisor,
        )

        cfg, params = qwen
        pool = VirtualAcceleratorPool(devices=jax.devices() * 4,
                                      devices_per_core=1, kv_pages=16)
        hv, ex = make_serving_hypervisor(pool, policy="even_split")
        b = ContinuousBatcher(params, cfg, slots=4, prompt_len=8, max_len=64,
                              chunk=4, paged=True, page_size=8, n_pages=16)
        assert hv.admit(TenantSpec("t", 2, requested_kv_pages=16,
                                   min_kv_pages=2))
        ex.register_kv_limit("t", b.set_page_limit)
        assert hv.kv_allocation() == {"t": 16}
        # second tenant arrives: the even split halves t's page lease and the
        # executor pushes the new cap into the live batcher
        assert hv.admit(TenantSpec("u", 2, requested_kv_pages=16,
                                   min_kv_pages=2))
        assert sum(hv.kv_allocation().values()) <= 16
        assert b._page_limit == hv.kv_allocation()["t"]
        assert int(b.pages.quota) == b._page_limit
        prompts = _prompts(cfg, 6, seed=29)
        reqs = [Request(rid=i, prompt=p, max_new=8)
                for i, p in enumerate(prompts)]
        for r in reqs:
            b.submit(r)
        b.run(max_steps=2000)
        assert all(r.done for r in reqs)
        assert b.stats.peak_pages_in_use <= hv.kv_allocation()["t"]
        _assert_pool_invariants(b)


@pytest.fixture(scope="module")
def qwen_f32():
    """f32 variant: Pallas-vs-XLA token identity needs both paths to see
    numerically equal inputs (bf16 would make argmax ties dtype-lottery)."""
    import dataclasses
    cfg = dataclasses.replace(get_reduced("qwen3-0.6b"), dtype="float32",
                              name="qwen3-0.6b-f32")
    return cfg, init_params(cfg, KEY)


class TestPallasPagedServing:
    """attn_impl="pallas" on the paged path: the in-kernel page-table walk
    (repro.kernels.paged_attention) must emit the same token streams as the
    materialized-gather XLA oracle, end to end through the batcher."""

    def test_pallas_matches_xla_tokens(self, qwen_f32):
        cfg, params = qwen_f32
        prompts = _prompts(cfg, 8)
        _, xla = _run(params, cfg, prompts, paged=True, page_size=8)
        bp, pal = _run(params, cfg, prompts, paged=True, page_size=8,
                       attn_impl="pallas")
        for a, g in zip(xla, pal):
            assert a.done and g.done
            assert a.out == g.out, (a.rid, a.out, g.out)
        _assert_pool_invariants(bp)

    def test_pallas_page_boundary_crossing(self, qwen_f32):
        """page_size=4 forces in-kernel walks over several boundary
        crossings and unmapped tail pages; streams stay identical."""
        cfg, params = qwen_f32
        prompts = _prompts(cfg, 6, seed=5)
        _, xla = _run(params, cfg, prompts, max_new=14, paged=True,
                      page_size=4)
        _, pal = _run(params, cfg, prompts, max_new=14, paged=True,
                      page_size=4, attn_impl="pallas")
        for a, g in zip(xla, pal):
            assert a.out == g.out, (a.rid, a.out, g.out)


class TestAttnCapabilities:
    """Bad impl × mode combinations fail at construction time with a
    ValueError from the shared capability table — not three layers deep
    inside a jit trace."""

    def test_paged_rejects_naive_at_construction(self, qwen):
        cfg, params = qwen
        with pytest.raises(ValueError, match="paged"):
            ContinuousBatcher(params, cfg, slots=2, prompt_len=8, max_len=32,
                              paged=True, page_size=8, attn_impl="naive")

    def test_paged_rejects_flash_at_construction(self, qwen):
        # "flash" (the train-only custom-VJP path) already fails the dense
        # check inside ServeConfig, before the batcher's paged check
        cfg, params = qwen
        with pytest.raises(ValueError, match="not supported"):
            ContinuousBatcher(params, cfg, slots=2, prompt_len=8, max_len=32,
                              paged=True, page_size=8, attn_impl="flash")

    def test_serve_config_rejects_unknown_impl(self):
        from repro.serving.engine import ServeConfig
        with pytest.raises(ValueError, match="attn_impl"):
            ServeConfig(max_len=32, attn_impl="cuda")

    def test_table_covers_every_mode(self):
        from repro.models.attention import ATTN_CAPABILITIES, check_attn_impl
        for mode, impls in ATTN_CAPABILITIES.items():
            for impl in impls:
                assert check_attn_impl(impl, mode) == impl
        with pytest.raises(ValueError, match="unknown attention mode"):
            check_attn_impl("xla", "teleport")
