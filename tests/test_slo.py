"""SLO-aware scheduling: seeded open-loop traffic, request lifecycles,
latency_slo policy demands, preemptive eviction accounting, and backfill
admission (PR 3)."""

import pytest

from repro.core import (
    EventKind, Hypervisor, PoissonTraffic, PolicyContext, RequestRecord,
    ResourcePool, TenantSpec, TraceTraffic, VirtualEngine, emit_requests,
    fpga_small_core, queueing_latency, slo_demand,
)
from repro.core.events import EventQueue
from repro.core.hypervisor import latency_slo


def make_engine(pool=None):
    return VirtualEngine(pool or ResourcePool(16), fpga_small_core())


# ---------------------------------------------------------------------------
# seeded traffic determinism
# ---------------------------------------------------------------------------

class TestTrafficDeterminism:
    def test_same_seed_same_times(self):
        a = PoissonTraffic(5.0, seed=7).times(20.0)
        b = PoissonTraffic(5.0, seed=7).times(20.0)
        assert a == b
        assert len(a) > 10                       # ~100 expected arrivals

    def test_times_reproducible_across_calls(self):
        t = PoissonTraffic(5.0, seed=7)
        assert t.times(20.0) == t.times(20.0)    # re-seeded per call

    def test_different_seeds_differ(self):
        assert PoissonTraffic(5.0, seed=1).times(20.0) != \
            PoissonTraffic(5.0, seed=2).times(20.0)

    def test_same_seed_identical_event_stream(self):
        """Satellite acceptance: same seed -> identical REQUEST event
        stream (times, tenants, rids, SLOs)."""
        streams = []
        for _ in range(2):
            q = EventQueue()
            emit_requests(q, "t", PoissonTraffic(8.0, seed=3), 10.0, slo=0.5)
            evs = [q.pop() for _ in range(len(q))]
            streams.append([
                (e.time, e.kind, e.tenant, e.payload["record"].rid,
                 e.payload["record"].slo)
                for e in evs
            ])
        assert streams[0] == streams[1]

    def test_trace_traffic_sorts_and_clips(self):
        t = TraceTraffic([3.0, 1.0, 2.0, 9.0])
        assert t.times(5.0) == [1.0, 2.0, 3.0]

    def test_full_run_deterministic(self, resnet_artifact):
        def run_once():
            pool = ResourcePool(16)
            eng = make_engine(pool)
            hv = Hypervisor(pool, policy="even_split", executor=eng)
            hv.schedule_arrival(TenantSpec("t", 8, artifact=resnet_artifact),
                                at=0.0)
            recs = hv.open_traffic("t", PoissonTraffic(6.0, seed=5), 2.0,
                                   slo=0.5)
            hv.run(2.0)
            return [(r.t_arrival, r.t_start, r.t_complete) for r in recs]

        assert run_once() == run_once()


# ---------------------------------------------------------------------------
# open-loop request lifecycle
# ---------------------------------------------------------------------------

class TestOpenLoop:
    def test_requests_stamped_and_completed(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="even_split", executor=eng)
        hv.schedule_arrival(TenantSpec("t", 8, artifact=resnet_artifact), at=0.0)
        recs = hv.open_traffic("t", TraceTraffic([0.1, 0.5]), 1.0, slo=1.0)
        hv.run(2.0)
        assert all(r.t_complete is not None for r in recs)
        assert all(r.t_start >= r.t_arrival for r in recs)
        assert all(r.slo_met for r in recs)

    def test_idle_tenant_does_not_reissue(self, resnet_artifact):
        """Open loop: two offered requests -> exactly two completions, even
        over a horizon long enough for dozens of closed-loop re-issues."""
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="even_split", executor=eng)
        hv.schedule_arrival(TenantSpec("t", 8, artifact=resnet_artifact), at=0.0)
        hv.open_traffic("t", TraceTraffic([0.0, 1.0]), 2.0)
        metrics = hv.run(2.0)
        assert len(metrics["t"].completions) == 2
        assert metrics["t"].arrivals == 2
        # the second request started at its arrival, not back-to-back
        assert metrics["t"].requests[1].t_start == 1.0

    def test_unqueued_latency_equals_single_inference(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="even_split", executor=eng)
        hv.schedule_arrival(
            TenantSpec("t", 8, artifact=resnet_artifact, open_loop=True),
            at=0.0)
        recs = hv.open_traffic("t", TraceTraffic([0.5]), 1.0)
        hv.run(2.0)
        # declared open-loop: idle until 0.5, then exactly one inference
        assert recs[0].t_start == 0.5
        assert recs[0].latency == pytest.approx(
            eng.single_inference_latency("t"), rel=1e-9)

    def test_completion_events_on_timeline(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="even_split", executor=eng)
        hv.schedule_arrival(TenantSpec("t", 8, artifact=resnet_artifact), at=0.0)
        recs = hv.open_traffic("t", TraceTraffic([0.1, 0.2, 0.3]), 1.0)
        hv.run(2.0)
        assert len(hv.completion_log) == 3
        completions = [e for e in hv.trace if e.kind is EventKind.COMPLETION]
        assert [e.payload["record"] for e in completions] == recs

    def test_backlog_delivered_on_late_admission(self, resnet_artifact):
        """Requests offered before their tenant is admitted are held and
        delivered on admission — offered load is never dropped, and the
        wait shows up as latency."""
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="even_split", executor=eng)
        hv.schedule_arrival(TenantSpec("t", 8, artifact=resnet_artifact), at=0.5)
        recs = hv.open_traffic("t", TraceTraffic([0.1]), 1.0)
        hv.run(2.0)
        assert recs[0].t_start >= 0.5
        assert recs[0].latency >= 0.4

    def test_never_admitted_requests_stay_unserved(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="no_realloc", executor=eng)
        hv.schedule_arrival(TenantSpec("a", 16, artifact=resnet_artifact), at=0.0)
        hv.schedule_arrival(TenantSpec("b", 8, artifact=resnet_artifact), at=0.1)
        recs = hv.open_traffic("b", TraceTraffic([0.2, 0.4]), 1.0, slo=0.5)
        hv.run(1.0)
        assert hv.waiting_tenants() == ["b"]
        assert all(r.t_complete is None for r in recs)
        assert not any(r.slo_met for r in recs)


# ---------------------------------------------------------------------------
# latency_slo policy
# ---------------------------------------------------------------------------

def _ctx(specs, current=None, latency=None, n=16):
    return PolicyContext(n_cores=n, tenants=list(specs),
                         current=current or {}, time=0.0, latency=latency)


def _inv_latency(spec, k):
    return 1.0 / k      # 1 second on one core, perfectly divisible


class TestLatencySloPolicy:
    def test_queueing_latency_model(self):
        assert queueing_latency(1.0, 0.0) == 1.0
        assert queueing_latency(0.1, 2.0) == pytest.approx(
            0.1 * (1 + 0.2 / (2 * 0.8)))
        assert queueing_latency(1.0, 2.0) == float("inf")   # unstable

    def test_demand_is_fewest_cores_meeting_slo(self):
        spec = TenantSpec("t", 16, latency_slo=0.3)
        d = slo_demand(_ctx([spec], latency=_inv_latency), spec)
        assert d == 4            # 1/4 = 0.25 <= 0.9 * 0.3; 1/3 = 0.33 too slow

    def test_demand_grows_with_offered_load(self):
        lo = TenantSpec("t", 16, latency_slo=0.3, arrival_rate=0.1)
        hi = TenantSpec("t", 16, latency_slo=0.3, arrival_rate=3.0)
        ctx = _ctx([lo], latency=_inv_latency)
        assert slo_demand(ctx, hi) > slo_demand(ctx, lo)

    def test_demand_floor_without_slo_or_model(self):
        spec = TenantSpec("t", 16, min_cores=2)
        assert slo_demand(_ctx([spec], latency=_inv_latency), spec) == 2
        slod = TenantSpec("t", 16, min_cores=2, latency_slo=0.1)
        assert slo_demand(_ctx([slod], latency=None), slod) == 2

    def test_demand_caps_at_request_when_unmeetable(self):
        spec = TenantSpec("t", 4, latency_slo=0.01)
        assert slo_demand(_ctx([spec], latency=_inv_latency), spec) == 4

    def test_residents_get_demand_newcomer_all_or_nothing(self):
        a = TenantSpec("a", 16, latency_slo=0.2, arrived_at=0.0)   # demand 6
        b = TenantSpec("b", 16, latency_slo=0.1, arrived_at=1.0)   # demand 12
        out = latency_slo(_ctx([a, b], current={"a": 6},
                               latency=_inv_latency))
        assert out["b"] == 0                  # 12 > 16 - 6: parks
        assert out["a"] >= 6

    def test_higher_priority_arrival_shrinks_resident_to_floor(self):
        lo = TenantSpec("lo", 16, latency_slo=0.1, priority=1.0,
                        arrived_at=0.0)                            # demand 12
        hi = TenantSpec("hi", 16, latency_slo=0.1, priority=5.0,
                        arrived_at=1.0)                            # demand 12
        out = latency_slo(_ctx([lo, hi], current={"lo": 12},
                               latency=_inv_latency))
        assert out["hi"] == 12
        assert out["lo"] >= 1                 # degraded, not evicted
        assert out["lo"] + out["hi"] <= 16

    def test_work_conserving_leftovers(self):
        a = TenantSpec("a", 16, latency_slo=0.5, arrived_at=0.0)
        out = latency_slo(_ctx([a], current={"a": 2}, latency=_inv_latency))
        assert out["a"] == 16                 # leftover flows to the request

    def test_end_to_end_demands_respected(self, resnet_artifact):
        """Tight-SLO tenant gets more cores than a loose-SLO one under
        contention, regardless of arrival order."""
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="latency_slo", executor=eng)
        base = eng.estimate_latency(
            TenantSpec("probe", 16, artifact=resnet_artifact), 8)
        loose = TenantSpec("loose", 16, artifact=resnet_artifact,
                           latency_slo=base * 8, priority=1.0)
        tight = TenantSpec("tight", 16, artifact=resnet_artifact,
                           latency_slo=base * 1.1, priority=1.0)
        hv.schedule_arrival(loose, at=0.0)
        hv.schedule_arrival(tight, at=0.1)
        hv.run(0.5)
        alloc = hv.allocation()
        assert alloc["tight"] > alloc["loose"]


# ---------------------------------------------------------------------------
# preemptive eviction
# ---------------------------------------------------------------------------

class TestPreemption:
    def _arrive(self, hv, name, cores, prio, artifact, at, min_cores=None):
        hv.schedule_arrival(
            TenantSpec(name, cores, priority=prio, artifact=artifact,
                       min_cores=min_cores or cores), at=at)

    def test_high_priority_arrival_evicts_lowest_priority(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="priority", executor=eng, preemptive=True)
        self._arrive(hv, "old-lo", 8, 1.0, resnet_artifact, 0.0)
        self._arrive(hv, "young-lo", 8, 1.0, resnet_artifact, 0.1)
        self._arrive(hv, "hi", 16, 5.0, resnet_artifact, 0.3)
        hv.run(0.6)
        assert hv.allocation() == {"hi": 16}
        assert hv.preemptions == ["young-lo", "old-lo"]
        # victims re-queued at the head, original arrival order
        assert hv.waiting_tenants() == ["old-lo", "young-lo"]

    def test_eviction_charges_context_switch_into_history(self, resnet_artifact):
        """Satellite acceptance: the evicted tenant's context-switch cost
        appears in its (surviving) history."""
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="priority", executor=eng, preemptive=True)
        self._arrive(hv, "victim", 16, 1.0, resnet_artifact, 0.0)
        self._arrive(hv, "hi", 16, 5.0, resnet_artifact, 0.3)
        hv.run(0.6)
        assert "victim" not in hv.allocation()
        hist = eng.history["victim"]
        assert hist.evictions == 1
        assert hist.ctx_switches >= 1
        assert hist.ctx_overhead > 0
        # a voluntary departure, by contrast, pays nothing (same scenario,
        # departure instead of preemption)
        pool2 = ResourcePool(16)
        eng2 = make_engine(pool2)
        hv2 = Hypervisor(pool2, policy="priority", executor=eng2)
        self._arrive(hv2, "leaver", 16, 1.0, resnet_artifact, 0.0)
        hv2.schedule_departure("leaver", at=0.3)
        hv2.run(0.6)
        assert eng2.history["leaver"].ctx_overhead == 0

    def test_no_preemption_without_flag(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="priority", executor=eng)
        self._arrive(hv, "lo", 16, 1.0, resnet_artifact, 0.0)
        self._arrive(hv, "hi", 16, 5.0, resnet_artifact, 0.3)
        hv.run(0.6)
        assert hv.allocation() == {"lo": 16}
        assert hv.waiting_tenants() == ["hi"]
        assert hv.preemptions == []

    def test_equal_priority_never_preempts(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="priority", executor=eng, preemptive=True)
        self._arrive(hv, "a", 16, 2.0, resnet_artifact, 0.0)
        self._arrive(hv, "b", 16, 2.0, resnet_artifact, 0.3)
        hv.run(0.6)
        assert hv.allocation() == {"a": 16}
        assert hv.preemptions == []

    def test_priority_queue_jump_prefers_free_capacity(self, resnet_artifact):
        """Under fifo+preemptive, a high-priority arrival facing a non-empty
        wait queue is seated from *free* cores when they suffice — it must
        not evict a resident that isn't in the way."""
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="no_realloc", executor=eng,
                        preemptive=True)
        self._arrive(hv, "lo", 4, 1.0, resnet_artifact, 0.0)
        self._arrive(hv, "blocked", 16, 1.0, resnet_artifact, 0.1)  # waits
        self._arrive(hv, "hi", 4, 5.0, resnet_artifact, 0.2)        # 12 free
        hv.run(0.5)
        assert hv.allocation() == {"lo": 4, "hi": 4}
        assert hv.preemptions == []
        assert hv.waiting_tenants() == ["blocked"]
        assert eng.tenants["lo"].metrics.ctx_switches == 0

    def test_infeasible_arrival_never_evicts(self, resnet_artifact):
        """An arrival whose floor exceeds the whole pool must not charge
        residents for a doomed preemption attempt."""
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="priority", executor=eng, preemptive=True)
        self._arrive(hv, "lo", 16, 1.0, resnet_artifact, 0.0)
        self._arrive(hv, "huge", 32, 9.0, resnet_artifact, 0.3)
        hv.run(0.6)
        assert hv.allocation() == {"lo": 16}
        assert hv.preemptions == []
        assert eng.tenants["lo"].metrics.ctx_switches == 0

    def test_rollback_restores_victims_when_preemption_fails(self, resnet_artifact):
        """Eviction of every lower-priority resident still can't seat the
        arrival (a same-priority resident holds the rest): victims are
        re-admitted and the arrival parks."""
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="priority", executor=eng, preemptive=True)
        self._arrive(hv, "peer", 8, 5.0, resnet_artifact, 0.0, min_cores=8)
        self._arrive(hv, "lo", 8, 1.0, resnet_artifact, 0.1, min_cores=8)
        self._arrive(hv, "hi", 16, 5.0, resnet_artifact, 0.3, min_cores=16)
        hv.run(0.6)
        # hi outranks lo but not peer; evicting lo frees only 8 of 16
        assert hv.allocation() == {"peer": 8, "lo": 8}
        assert "hi" in hv.waiting_tenants()
        assert hv.preemptions == ["lo"]          # attempted, then rolled back
        assert eng.tenants["lo"].metrics.evictions == 1

    def test_evicted_tenant_readmitted_after_departure(self, resnet_artifact):
        """The victim re-enters from the wait-queue head when capacity
        frees; its parked open-loop requests follow it back in and its
        metrics resume (continuity across the eviction)."""
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="priority", executor=eng, preemptive=True)
        self._arrive(hv, "victim", 16, 1.0, resnet_artifact, 0.0)
        recs = hv.open_traffic("victim", TraceTraffic([0.1, 0.45]), 1.0)
        self._arrive(hv, "hi", 16, 5.0, resnet_artifact, 0.4)
        hv.schedule_departure("hi", at=0.7)
        metrics = hv.run(2.0)
        assert hv.allocation() == {"victim": 16}
        assert all(r.t_complete is not None for r in recs)
        assert recs[1].t_start >= 0.7            # served after re-admission
        assert metrics["victim"].evictions == 1
        assert metrics["victim"].arrivals == 2   # accounting resumed


# ---------------------------------------------------------------------------
# backfill admission
# ---------------------------------------------------------------------------

class TestBackfill:
    def test_small_tenant_admitted_past_blocked_head(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="no_realloc", executor=eng,
                        admission="backfill")
        hv.schedule_arrival(TenantSpec("big0", 12, artifact=resnet_artifact),
                            at=0.0)
        hv.schedule_arrival(TenantSpec("big1", 10, artifact=resnet_artifact),
                            at=0.1)                      # blocks: 10 > 4 free
        hv.schedule_arrival(TenantSpec("small", 2, artifact=resnet_artifact),
                            at=0.2)                      # fits past the head
        hv.run(0.5)
        assert hv.allocation() == {"big0": 12, "small": 2}
        assert hv.waiting_tenants() == ["big1"]

    def test_fifo_keeps_head_of_line_blocking(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="no_realloc", executor=eng)
        hv.schedule_arrival(TenantSpec("big0", 12, artifact=resnet_artifact),
                            at=0.0)
        hv.schedule_arrival(TenantSpec("big1", 10, artifact=resnet_artifact),
                            at=0.1)
        hv.schedule_arrival(TenantSpec("small", 2, artifact=resnet_artifact),
                            at=0.2)
        hv.run(0.5)
        assert hv.allocation() == {"big0": 12}
        assert hv.waiting_tenants() == ["big1", "small"]

    def test_backfill_drains_in_order_on_departure(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="no_realloc", executor=eng,
                        admission="backfill")
        hv.schedule_arrival(TenantSpec("a", 14, artifact=resnet_artifact), at=0.0)
        hv.schedule_arrival(TenantSpec("b", 10, artifact=resnet_artifact), at=0.1)
        hv.schedule_arrival(TenantSpec("c", 4, artifact=resnet_artifact), at=0.2)
        hv.schedule_departure("a", at=0.4)
        hv.run(0.6)
        # the head fits first after the departure; c backfills the rest
        assert hv.allocation() == {"b": 10, "c": 4}
        assert hv.waiting_tenants() == []

    def test_unknown_admission_order_rejected(self):
        with pytest.raises(ValueError):
            Hypervisor(ResourcePool(4), admission="lifo")


# ---------------------------------------------------------------------------
# serving executor SLO plumbing (no JAX dispatch: bookkeeping only)
# ---------------------------------------------------------------------------

class TestServingSlo:
    @pytest.fixture()
    def vpool(self):
        jax = pytest.importorskip("jax")
        from repro.serving.tenancy import VirtualAcceleratorPool

        return VirtualAcceleratorPool(devices=list(jax.devices()) * 8,
                                      devices_per_core=1)

    def test_registered_model_drives_demand(self, vpool):
        from repro.serving.tenancy import make_serving_hypervisor

        hv, ex = make_serving_hypervisor(vpool, policy="latency_slo")
        ex.register_latency_model("a", lambda k: 1.0 / k)
        spec = TenantSpec("a", 8, latency_slo=0.3)
        assert hv.admit(spec)
        assert hv.allocation()["a"] == 8          # demand 4 + leftovers
        assert ex.estimate_latency(spec, 4) == 0.25

    def test_ewma_fallback_scales_with_lease(self, vpool):
        from repro.serving.tenancy import ServingExecutor

        ex = ServingExecutor(vpool)
        spec = TenantSpec("a", 8)
        assert ex.estimate_latency(spec, 4) is None
        vpool.lease("a", 2)
        ex.record_latency("a", 0.4)
        ex.record_latency("a", 0.4)
        assert ex.estimate_latency(spec, 2) == pytest.approx(0.4)
        assert ex.estimate_latency(spec, 4) == pytest.approx(0.2)
        # after the lease is gone (eviction/departure) the estimate stays
        # anchored to the 2 cores the measurements came from — a leaseless
        # tenant must not be treated as if it measured on 1 core
        vpool.release("a")
        assert ex.estimate_latency(spec, 2) == pytest.approx(0.4)
        assert ex.estimate_latency(spec, 1) == pytest.approx(0.8)

    def test_note_completion_feeds_report_and_sink(self, vpool):
        from repro.serving.tenancy import ServingExecutor

        ex = ServingExecutor(vpool)
        seen = []
        ex.completion_sink = seen.append
        rec = RequestRecord("a", 0, t_arrival=0.0, slo=1.0,
                            t_start=0.0, t_complete=0.5)
        ex.note_completion(rec)
        miss = RequestRecord("a", 1, t_arrival=0.0, slo=0.1,
                             t_start=0.0, t_complete=0.5)
        ex.note_completion(miss)
        report = ex.slo_report()["a"]
        assert report["requests"] == 2 and report["slo_met"] == 1
        assert report["attainment"] == 0.5
        assert seen == [rec, miss]

    def test_eviction_keeps_state_for_readmission(self, vpool):
        from repro.serving.tenancy import make_serving_hypervisor

        hv, ex = make_serving_hypervisor(vpool, policy="priority",
                                         preemptive=True)
        ex.register_request_sink("lo", lambda rec: None)
        ex.register_latency_model("lo", lambda k: 0.1)
        assert hv.admit(TenantSpec("lo", 8, min_cores=8, priority=1.0))
        assert hv.admit(TenantSpec("hi", 8, min_cores=8, priority=5.0))
        assert hv.allocation() == {"hi": 8}
        assert hv.waiting_tenants() == ["lo"]
        assert "lo" in ex._latency_models         # kept across eviction
        hv.depart("hi")
        assert hv.allocation() == {"lo": 8}
