"""Hypervisor: global event loop, reallocation policies, wait-queue
admission, DDR-group-aware placement, and per-event isolation invariants."""

import pytest

from repro.core import (
    EventKind, Hypervisor, PolicyContext, ResourcePool, TenantSpec,
    VirtualEngine, fpga_small_core, resolve_policy,
)
from repro.core.events import EventQueue
from repro.core.hypervisor import POLICIES, even_split, no_realloc, priority, \
    weighted_by_workload


def make_engine(pool=None):
    return VirtualEngine(pool or ResourcePool(16), fpga_small_core())


def ctx(specs, current=None, n=16):
    return PolicyContext(n_cores=n, tenants=list(specs), current=current or {},
                         time=0.0)


# ---------------------------------------------------------------------------
# event queue
# ---------------------------------------------------------------------------

class TestEventQueue:
    def test_time_order(self):
        q = EventQueue()
        q.schedule(EventKind.ARRIVAL, 2.0, tenant="b")
        q.schedule(EventKind.ARRIVAL, 1.0, tenant="a")
        q.schedule(EventKind.ARRIVAL, 3.0, tenant="c")
        assert [q.pop().tenant for _ in range(3)] == ["a", "b", "c"]

    def test_departure_handled_before_simultaneous_arrival(self):
        q = EventQueue()
        q.schedule(EventKind.ARRIVAL, 1.0, tenant="new")
        q.schedule(EventKind.DEPARTURE, 1.0, tenant="old")
        assert q.pop().kind is EventKind.DEPARTURE
        assert q.pop().kind is EventKind.ARRIVAL

    def test_insertion_order_breaks_remaining_ties(self):
        q = EventQueue()
        for name in ("x", "y", "z"):
            q.schedule(EventKind.ARRIVAL, 0.0, tenant=name)
        assert [q.pop().tenant for _ in range(3)] == ["x", "y", "z"]


# ---------------------------------------------------------------------------
# policies (pure functions over PolicyContext)
# ---------------------------------------------------------------------------

class TestPolicies:
    def test_registry_and_resolution(self):
        assert set(POLICIES) == {
            "even_split", "weighted_by_workload", "priority", "latency_slo",
            "no_realloc",
        }
        assert resolve_policy("even_split") is even_split
        assert resolve_policy(even_split) is even_split
        with pytest.raises(ValueError):
            resolve_policy("round_robin")

    def test_even_split_balanced(self):
        specs = [TenantSpec(f"t{i}", 16) for i in range(3)]
        out = even_split(ctx(specs))
        assert sorted(out.values(), reverse=True) == [6, 5, 5]
        assert sum(out.values()) == 16

    def test_even_split_caps_at_request_and_redistributes(self):
        out = even_split(ctx([TenantSpec("small", 2), TenantSpec("big", 16)]))
        assert out == {"small": 2, "big": 14}

    def test_weighted_by_workload(self):
        out = weighted_by_workload(
            ctx([TenantSpec("heavy", 16, weight=3.0), TenantSpec("light", 16, weight=1.0)])
        )
        assert out["heavy"] > out["light"]
        assert sum(out.values()) == 16

    def test_priority_satisfies_high_priority_first(self):
        out = priority(
            ctx([TenantSpec("lo", 16, priority=1.0), TenantSpec("hi", 12, priority=5.0)])
        )
        assert out == {"hi": 12, "lo": 4}

    def test_no_realloc_keeps_residents(self):
        specs = [TenantSpec("a", 12), TenantSpec("b", 8)]
        out = no_realloc(ctx(specs, current={"a": 12}))
        assert out["a"] == 12          # resident untouched
        assert out["b"] == 0           # newcomer doesn't fit -> waits

    def test_no_realloc_honours_own_resize(self):
        out = no_realloc(ctx([TenantSpec("a", 4)], current={"a": 12}))
        assert out["a"] == 4


# ---------------------------------------------------------------------------
# DDR-group-aware placement (HRP satellite)
# ---------------------------------------------------------------------------

class TestDdrGroupPlacement:
    def test_alloc_prefers_whole_free_group(self):
        pool = ResourcePool(16, cores_per_ddr=4)
        pool.alloc("a", 2)                      # breaks group 0
        b = pool.alloc("b", 4)
        assert b.cores == (4, 5, 6, 7)          # whole group, not (2,3,4,5)

    def test_small_alloc_best_fits_into_partial_group(self):
        pool = ResourcePool(16, cores_per_ddr=4)
        pool.alloc("a", 2)                      # group 0 partially free
        c = pool.alloc("c", 2)
        assert c.cores == (2, 3)                # fills the broken group

    def test_multi_group_alloc_takes_whole_groups(self):
        pool = ResourcePool(16, cores_per_ddr=4)
        a = pool.alloc("a", 8)
        assert a.cores == (0, 1, 2, 3, 4, 5, 6, 7)

    def test_shrink_drops_partial_group_cores_first(self):
        pool = ResourcePool(16, cores_per_ddr=4)
        pool.alloc("a", 6)                      # group 0 whole + 2 of group 1
        smaller = pool.resize("a", 4)
        assert smaller.cores == (0, 1, 2, 3)    # retains the dedicated bank

    def test_grow_extends_own_partial_group_first(self):
        pool = ResourcePool(16, cores_per_ddr=4)
        pool.alloc("a", 2)                      # (0, 1)
        grown = pool.resize("a", 4)
        assert grown.cores == (0, 1, 2, 3)      # completes its own bank


# ---------------------------------------------------------------------------
# event-driven engine runs
# ---------------------------------------------------------------------------

HORIZON = 1.2


class TestEventLoop:
    def test_two_tenants_arrive_and_leave_mid_run(self, resnet_artifact):
        """Acceptance: tenants arrive/leave mid-run, the pool rebalances via
        the policy, and HRP isolation invariants hold after every event."""
        pool = ResourcePool(16)
        eng = make_engine(pool)
        checked = []

        def check(hv, ev):
            hv.pool.check_isolation()
            hv.pool.check_bandwidth()
            checked.append(ev)

        hv = Hypervisor(pool, policy="even_split", executor=eng, on_event=check)
        hv.schedule_arrival(TenantSpec("a", 16, artifact=resnet_artifact), at=0.0)
        hv.schedule_arrival(TenantSpec("b", 16, artifact=resnet_artifact), at=0.4)
        hv.schedule_departure("b", at=0.8)
        metrics = hv.run(HORIZON)

        assert hv.allocation() == {"a": 16}          # b gone, a regrown
        assert metrics["a"].ctx_switches >= 2        # shrink @0.4, grow @0.8
        assert metrics["a"].completions
        assert metrics["b"].completions              # departed metrics survive
        assert all(c >= 0.4 for c in metrics["b"].completions)
        assert len(checked) == 3                     # every event was verified

    def test_arrival_rebalances_and_speeds_reflect_allocation(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="even_split", executor=eng)
        hv.schedule_arrival(TenantSpec("a", 16, artifact=resnet_artifact), at=0.0)
        hv.schedule_arrival(TenantSpec("b", 16, artifact=resnet_artifact), at=0.6)
        metrics = hv.run(HORIZON)
        assert hv.allocation() == {"a": 8, "b": 8}
        # a's completion rate on 16 cores (before b) beats its rate on 8
        early = sum(1 for c in metrics["a"].completions if c <= 0.6) / 0.6
        late = sum(1 for c in metrics["a"].completions if c > 0.6) / (HORIZON - 0.6)
        assert early > late

    def test_wait_queue_admission_on_departure(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="no_realloc", executor=eng)
        hv.schedule_arrival(TenantSpec("big", 12, artifact=resnet_artifact), at=0.0)
        hv.schedule_arrival(TenantSpec("late", 8, artifact=resnet_artifact), at=0.1)
        hv.schedule_departure("big", at=0.5)
        metrics = hv.run(HORIZON)
        assert hv.allocation() == {"late": 8}
        assert hv.waiting_tenants() == []
        assert metrics["late"].completions
        assert all(c >= 0.5 for c in metrics["late"].completions)

    def test_waiting_tenant_never_admitted_stays_queued(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="no_realloc", executor=eng)
        hv.schedule_arrival(TenantSpec("big", 16, artifact=resnet_artifact), at=0.0)
        hv.schedule_arrival(TenantSpec("late", 4, artifact=resnet_artifact), at=0.1)
        hv.run(0.5)
        assert hv.waiting_tenants() == ["late"]
        assert "late" not in hv.allocation()

    def test_departure_admits_waiter_in_one_decision(self, resnet_artifact):
        """A departure that unblocks a waiter re-applies the policy over the
        full new tenant set once — residents must not grow and then shrink
        again (double context switch) around the admission."""
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="even_split", executor=eng)
        hv.schedule_arrival(TenantSpec("a", 16, artifact=resnet_artifact), at=0.0)
        hv.schedule_arrival(TenantSpec("b", 16, artifact=resnet_artifact), at=0.0)
        hv.schedule_arrival(
            TenantSpec("c", 16, min_cores=8, artifact=resnet_artifact), at=0.1
        )                                       # floor 8 can't fit -> waits
        hv.schedule_departure("b", at=0.5)
        metrics = hv.run(1.0)
        assert hv.allocation() == {"a": 8, "c": 8}
        assert metrics["a"].ctx_switches == 1   # only the shrink at b's arrival

    def test_duplicate_arrival_updates_contract(self, resnet_artifact):
        """Re-submitting a resident tenant updates its request instead of
        crashing on a duplicate lease."""
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="even_split", executor=eng)
        hv.schedule_arrival(TenantSpec("a", 16, artifact=resnet_artifact), at=0.0)
        hv.schedule_arrival(TenantSpec("a", 4, artifact=resnet_artifact), at=0.2)
        hv.run(0.5)
        assert hv.allocation() == {"a": 4}

    def test_reconfig_signal_resizes_through_policy(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="no_realloc", executor=eng)
        hv.schedule_arrival(TenantSpec("t", 4, artifact=resnet_artifact), at=0.0)
        hv.schedule_reconfig("t", 12, at=0.3)
        metrics = hv.run(HORIZON)
        assert hv.allocation() == {"t": 12}
        assert metrics["t"].ctx_switches == 1
        assert 0 < metrics["t"].ctx_overhead < 0.05   # ~ms, not ~100 s

    def test_probe_event_rebalances_straggler(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = VirtualEngine(pool, fpga_small_core(), straggler_threshold=1.3)
        hv = Hypervisor(pool, policy="no_realloc", executor=eng,
                        probe_interval=0.05)
        hv.schedule_arrival(TenantSpec("t", 8, artifact=resnet_artifact), at=0.0)
        eng.core_slowdown[0] = 3.0
        metrics = hv.run(0.6)
        assert metrics["t"].rebalances == 1           # one probe fired a fix

    def test_invariants_checked_after_every_event(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        seen = []

        def check(hv, ev):
            hv.pool.check_isolation()
            hv.pool.check_bandwidth()
            total = sum(l.n_cores for l in hv.pool.leases.values())
            assert total + len(hv.pool.free_cores()) == hv.pool.n_cores
            seen.append(ev.kind)

        hv = Hypervisor(pool, policy="even_split", executor=eng,
                        probe_interval=0.25, on_event=check)
        hv.schedule_arrival(TenantSpec("a", 16, artifact=resnet_artifact), at=0.0)
        hv.schedule_arrival(TenantSpec("b", 16, artifact=resnet_artifact), at=0.2)
        hv.schedule_reconfig("a", 4, at=0.4)
        hv.schedule_departure("a", at=0.6)
        hv.run(1.0)
        assert EventKind.ARRIVAL in seen and EventKind.DEPARTURE in seen
        assert EventKind.RECONFIG in seen and EventKind.PROBE in seen
        assert len(hv.trace) == len(seen)

    def test_degenerate_run_matches_direct_engine(self, resnet_artifact):
        """VirtualEngine.run (a no_realloc hypervisor with an empty queue)
        reproduces the seed engine's independent per-tenant clocks."""
        eng1 = make_engine()
        eng1.admit("t", resnet_artifact, 8)
        direct = eng1.run(1.0)["t"]

        pool2 = ResourcePool(16)
        eng2 = make_engine(pool2)
        hv = Hypervisor(pool2, policy="even_split", executor=eng2)
        hv.schedule_arrival(TenantSpec("t", 8, artifact=resnet_artifact), at=0.0)
        evented = hv.run(1.0)["t"]
        assert evented.completions == direct.completions


class TestImmediateMode:
    def test_admit_depart_resize_without_queue(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="priority", executor=eng)
        assert hv.admit(TenantSpec("hi", 12, priority=2.0, artifact=resnet_artifact))
        assert hv.admit(TenantSpec("lo", 8, priority=1.0, artifact=resnet_artifact))
        assert hv.allocation() == {"hi": 12, "lo": 4}
        hv.depart("hi")
        hv.resize_request("lo", 8)
        assert hv.allocation() == {"lo": 8}

    def test_admit_failure_parks_in_wait_queue(self, resnet_artifact):
        pool = ResourcePool(16)
        eng = make_engine(pool)
        hv = Hypervisor(pool, policy="no_realloc", executor=eng)
        assert hv.admit(TenantSpec("a", 16, artifact=resnet_artifact))
        assert not hv.admit(TenantSpec("b", 2, artifact=resnet_artifact))
        assert hv.waiting_tenants() == ["b"]
        hv.depart("a")                       # frees the pool -> b admitted
        assert hv.allocation() == {"b": 2}
