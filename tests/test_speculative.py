"""Speculative decode inside the chunked scan + prefill/decode overlap +
the unified ServingConfig API.

The headline invariant: speculative greedy decode is **token-identical** to
non-speculative greedy decode, by construction — through the batcher, at
f32, dense and paged, including EOS landing inside a draft window and
accepted runs crossing page boundaries.  Everything else (acceptance
algebra, drafter, verify kernel, config validation, program registry,
draft-state migration) defends a piece of that construction.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.config import ServingConfig, config_from_legacy_kwargs
from repro.serving.engine import (
    PROGRAMS,
    DraftState,
    ProgramRegistry,
    SlotState,
    _advance_draft,
    _propose_drafts,
    _spec_accept,
    init_draft_state,
)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-0.6b")
    return cfg, init_params(cfg, KEY)


def _slot_state(B, *, remaining, eos=-1, tokens=0):
    return SlotState(
        tokens=jnp.full((B,), tokens, jnp.int32),
        cur_pos=jnp.zeros((B,), jnp.int32),
        active=jnp.ones((B,), bool),
        remaining=jnp.asarray(remaining, jnp.int32).reshape(B),
        eos=jnp.asarray(eos, jnp.int32).reshape(-1).repeat(B)[:B]
        if np.isscalar(eos) else jnp.asarray(eos, jnp.int32),
    )


class TestSpecAccept:
    """The acceptance algebra in isolation: c / nxt / done / emitted."""

    def test_full_accept_commits_window(self):
        # drafts q[1:] all equal the verified greedy tokens g[:-1]
        q = jnp.asarray([[7, 3, 4, 5]], jnp.int32)
        g = jnp.asarray([[3, 4, 5, 6]], jnp.int32)
        st = _slot_state(1, remaining=[10])
        c, nxt, done, emitted = _spec_accept(q, g, st, st.active)
        assert int(c[0]) == 4 and int(nxt[0]) == 6
        assert not bool(done[0])
        np.testing.assert_array_equal(np.asarray(emitted[0]),
                                      [True] * 4)

    def test_first_mismatch_cuts_commit(self):
        # draft at w=2 (token 9) != g[:,1] (4): accept prefix len e = 2
        q = jnp.asarray([[7, 3, 9, 5]], jnp.int32)
        g = jnp.asarray([[3, 4, 5, 6]], jnp.int32)
        st = _slot_state(1, remaining=[10])
        c, nxt, done, emitted = _spec_accept(q, g, st, st.active)
        assert int(c[0]) == 2 and int(nxt[0]) == 4
        np.testing.assert_array_equal(np.asarray(emitted[0]),
                                      [True, True, False, False])

    def test_full_reject_still_commits_bonus_token(self):
        # every draft wrong: exactly one token commits — the w=0 verify
        # output, which is what one plain greedy step would have produced
        q = jnp.asarray([[7, 9, 9, 9]], jnp.int32)
        g = jnp.asarray([[3, 4, 5, 6]], jnp.int32)
        st = _slot_state(1, remaining=[10])
        c, nxt, done, emitted = _spec_accept(q, g, st, st.active)
        assert int(c[0]) == 1 and int(nxt[0]) == 3
        np.testing.assert_array_equal(np.asarray(emitted[0]),
                                      [True, False, False, False])

    def test_eos_inside_accepted_prefix_cuts_and_finishes(self):
        # full agreement, but g[:,1] is the EOS: commit through it (c=2),
        # mark done, never emit the post-EOS positions
        q = jnp.asarray([[7, 3, 4, 5]], jnp.int32)
        g = jnp.asarray([[3, 2, 5, 6]], jnp.int32)
        st = _slot_state(1, remaining=[10], eos=2)
        c, nxt, done, emitted = _spec_accept(q, g, st, st.active)
        assert int(c[0]) == 2 and int(nxt[0]) == 2
        assert bool(done[0])
        np.testing.assert_array_equal(np.asarray(emitted[0]),
                                      [True, True, False, False])

    def test_eos_beyond_accepted_prefix_is_garbage_and_ignored(self):
        # mismatch at w=1 (draft 9 != g 4) makes positions w>=2 garbage;
        # a spurious EOS there must not finish the slot
        q = jnp.asarray([[7, 3, 9, 9]], jnp.int32)
        g = jnp.asarray([[3, 4, 2, 2]], jnp.int32)
        st = _slot_state(1, remaining=[10], eos=2)
        c, nxt, done, emitted = _spec_accept(q, g, st, st.active)
        assert int(c[0]) == 2 and int(nxt[0]) == 4
        assert not bool(done[0])

    def test_budget_clamps_commit_and_finishes(self):
        q = jnp.asarray([[7, 3, 4, 5]], jnp.int32)
        g = jnp.asarray([[3, 4, 5, 6]], jnp.int32)
        st = _slot_state(1, remaining=[2])
        c, nxt, done, emitted = _spec_accept(q, g, st, st.active)
        assert int(c[0]) == 2 and int(nxt[0]) == 4
        assert bool(done[0])

    def test_inactive_slot_commits_nothing(self):
        q = jnp.asarray([[7, 3, 4, 5]], jnp.int32)
        g = jnp.asarray([[3, 4, 5, 6]], jnp.int32)
        st = _slot_state(1, remaining=[10], tokens=7)
        c, nxt, done, emitted = _spec_accept(q, g, st,
                                             jnp.zeros((1,), bool))
        assert int(c[0]) == 0 and int(nxt[0]) == 7   # keeps st.tokens
        assert not bool(done[0]) and not bool(emitted.any())


class TestDrafter:
    """On-device n-gram self-speculation: propose + history advance."""

    def test_repeated_ngram_proposes_continuation(self):
        # history ... 5 6 9 5 6 : trailing bigram (5,6) recurs with
        # continuation 9 — the drafter must propose 9 first
        d = init_draft_state(1, 16)
        toks = jnp.asarray([[1, 2, 5, 6, 9, 5, 6]], jnp.int32)
        d = _advance_draft(DraftState(hist=d.hist, n=d.n), toks,
                           jnp.asarray([7], jnp.int32))
        prop = _propose_drafts(d, jnp.asarray([6], jnp.int32),
                               n_draft=3, ngram=2)
        assert int(prop[0, 0]) == 9

    def test_no_match_falls_back_to_last_token(self):
        d = init_draft_state(1, 16)
        toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        d = _advance_draft(d, toks, jnp.asarray([4], jnp.int32))
        prop = _propose_drafts(d, jnp.asarray([4], jnp.int32),
                               n_draft=3, ngram=2)
        np.testing.assert_array_equal(np.asarray(prop[0]), [4, 4, 4])

    def test_advance_is_a_shift_register(self):
        d = init_draft_state(1, 4)
        d = _advance_draft(d, jnp.asarray([[1, 2, 3, 0]], jnp.int32),
                           jnp.asarray([3], jnp.int32))
        np.testing.assert_array_equal(np.asarray(d.hist[0]), [-1, 1, 2, 3])
        assert int(d.n[0]) == 3
        d = _advance_draft(d, jnp.asarray([[7, 8, 0, 0]], jnp.int32),
                           jnp.asarray([2], jnp.int32))
        np.testing.assert_array_equal(np.asarray(d.hist[0]), [2, 3, 7, 8])
        assert int(d.n[0]) == 4          # saturates at capacity

    def test_advance_never_reads_uncommitted_window_tokens(self):
        # c=1 of a W=4 window: rejected drafts (positions 1..3) must not
        # enter the history
        d = init_draft_state(1, 4)
        d = _advance_draft(d, jnp.asarray([[5, 666, 666, 666]], jnp.int32),
                           jnp.asarray([1], jnp.int32))
        assert 666 not in np.asarray(d.hist[0])


def _mk_requests(cfg, n, *, seed=7, plen=6, max_new=24, eos=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab, size=plen).astype(np.int32),
                    max_new=max_new if np.isscalar(max_new) else max_new[i],
                    eos=eos)
            for i in range(n)]


def _run(params, cfg, sc, reqs, *, max_steps=4000):
    b = ContinuousBatcher(params, cfg, sc)
    for r in reqs:
        b.submit(r)
    stats = b.run(max_steps=max_steps)
    return b, stats


class TestSpecBatcherIdentity:
    """spec == greedy, token for token, through the full batcher at f32."""

    def _ref(self, qwen, **kw):
        cfg, params = qwen
        reqs = _mk_requests(cfg, 6, **kw)
        _run(params, cfg,
             ServingConfig(slots=4, prompt_len=8, max_len=48,
                           attn_impl="xla", chunk=4), reqs)
        return {r.rid: list(r.out) for r in reqs}

    def test_dense_spec_identical(self, qwen):
        cfg, params = qwen
        ref = self._ref(qwen)
        reqs = _mk_requests(cfg, 6)
        _, st = _run(params, cfg,
                     ServingConfig(slots=4, prompt_len=8, max_len=48,
                                   attn_impl="xla", chunk=4,
                                   speculative=True, draft_window=4), reqs)
        assert {r.rid: r.out for r in reqs} == ref
        assert st.spec_windows > 0
        assert st.drafted_tokens >= st.accepted_tokens >= 0
        assert 0.0 <= st.acceptance_rate <= 1.0

    def test_paged_spec_identical_small_pages(self, qwen):
        """page_size=4 < draft_window+1 forces accepted runs (and single
        verify windows) to cross page boundaries and multi-page-fault."""
        cfg, params = qwen
        ref = self._ref(qwen)
        reqs = _mk_requests(cfg, 6)
        _, st = _run(params, cfg,
                     ServingConfig(slots=4, prompt_len=8, max_len=48,
                                   attn_impl="xla", chunk=4, paged=True,
                                   page_size=4, n_pages=96,
                                   speculative=True, draft_window=6), reqs)
        assert {r.rid: r.out for r in reqs} == ref
        assert st.spec_windows > 0

    def test_eos_inside_draft_window(self, qwen):
        """Pick an EOS id straight out of the reference stream so it lands
        mid-generation; both runs must stop at the same token."""
        cfg, params = qwen
        ref_free = self._ref(qwen)
        # an id that occurs at least 2 tokens into some stream
        eos = None
        for out in ref_free.values():
            if len(out) > 3:
                eos = out[3]
                break
        assert eos is not None
        ref_reqs = _mk_requests(cfg, 6, eos=eos)
        _run(params, cfg,
             ServingConfig(slots=4, prompt_len=8, max_len=48,
                           attn_impl="xla", chunk=4), ref_reqs)
        ref = {r.rid: list(r.out) for r in ref_reqs}
        assert any(r.out and r.out[-1] == eos and len(r.out) < 24
                   for r in ref_reqs)          # EOS actually fired early
        reqs = _mk_requests(cfg, 6, eos=eos)
        _run(params, cfg,
             ServingConfig(slots=4, prompt_len=8, max_len=48,
                           attn_impl="xla", chunk=4,
                           speculative=True, draft_window=4), reqs)
        assert {r.rid: r.out for r in reqs} == ref

    def test_overlap_identical_with_stats(self, qwen):
        cfg, params = qwen
        ref = self._ref(qwen)
        reqs = _mk_requests(cfg, 6)
        _, st = _run(params, cfg,
                     ServingConfig(slots=4, prompt_len=8, max_len=48,
                                   attn_impl="xla", chunk=4, paged=True,
                                   page_size=8, n_pages=64,
                                   speculative=True, draft_window=4,
                                   overlap=True), reqs)
        assert {r.rid: r.out for r in reqs} == ref
        assert st.overlap_rounds > 0

    def test_dense_overlap_identical_without_spec(self, qwen):
        cfg, params = qwen
        ref = self._ref(qwen)
        reqs = _mk_requests(cfg, 6)
        _, st = _run(params, cfg,
                     ServingConfig(slots=4, prompt_len=8, max_len=48,
                                   attn_impl="xla", chunk=4, overlap=True),
                     reqs)
        assert {r.rid: r.out for r in reqs} == ref
        assert st.spec_windows == 0


class TestDraftStateSurvival:
    """Draft history must ride along with every state-movement path."""

    def test_live_state_carries_draft(self, qwen):
        cfg, params = qwen
        sc = ServingConfig(slots=2, prompt_len=8, max_len=32,
                           attn_impl="xla", speculative=True)
        b = ContinuousBatcher(params, cfg, sc)
        state = b.live_state()
        assert "draft" in state
        b.adopt_state(jax.tree.map(jnp.copy, state))
        assert isinstance(b.draft, DraftState)

    def test_set_page_limit_shrink_preserves_identity(self, qwen):
        """Shrinking the page pool mid-run evicts/requeues slots; resumed
        requests re-seed the drafter from their kept output and the final
        streams still match unconstrained greedy."""
        cfg, params = qwen
        ref_reqs = _mk_requests(cfg, 4, plen=6, max_new=20)
        _run(params, cfg,
             ServingConfig(slots=4, prompt_len=8, max_len=48,
                           attn_impl="xla", chunk=2), ref_reqs)
        ref = {r.rid: list(r.out) for r in ref_reqs}

        sc = ServingConfig(slots=4, prompt_len=8, max_len=48,
                           attn_impl="xla", chunk=2, paged=True,
                           page_size=4, n_pages=64,
                           speculative=True, draft_window=4)
        reqs = _mk_requests(cfg, 4, plen=6, max_new=20)
        b = ContinuousBatcher(params, cfg, sc)
        for r in reqs:
            b.submit(r)
        for _ in range(3):
            b.step()
        b.set_page_limit(28)                    # force evictions + resumes
        b.run(max_steps=4000)
        assert {r.rid: r.out for r in reqs} == ref

    def test_migration_between_chunks_preserves_identity(self, qwen):
        """TwoStageCompiler.reconfigure pulls live_state (incl. draft) and
        pushes it back through adopt_state; decode resumes identically."""
        from repro.core import TenantSpec
        from repro.serving.tenancy import (
            VirtualAcceleratorPool, make_serving_hypervisor,
        )

        cfg, params = qwen
        ref_reqs = _mk_requests(cfg, 3, plen=4, max_new=12)
        _run(params, cfg,
             ServingConfig(slots=4, prompt_len=8, max_len=64,
                           attn_impl="xla", chunk=4), ref_reqs)
        ref = {r.rid: list(r.out) for r in ref_reqs}

        pool = VirtualAcceleratorPool(devices=jax.devices() * 4,
                                      devices_per_core=1)
        hv, ex = make_serving_hypervisor(pool, policy="no_realloc")

        def mesh_builder(n):
            import jax.sharding as jsh
            devs = np.array(jax.devices() * n, dtype=object)[:n].reshape(n, 1)
            return jsh.Mesh(devs, ("data", "model"))

        ex.compiler.static_compile(
            "decode", lambda x: x,
            (jax.ShapeDtypeStruct((4,), jnp.float32),),
            lease_sizes=[1, 2], mesh_builder=mesh_builder)
        assert hv.admit(TenantSpec("t", 1, artifact="decode"))

        sc = ServingConfig(slots=4, prompt_len=8, max_len=64,
                           attn_impl="xla", chunk=4,
                           speculative=True, draft_window=4)
        b = ContinuousBatcher(params, cfg, sc)
        ex.register_state("t", b.live_state, on_migrate=b.adopt_state)
        reqs = _mk_requests(cfg, 3, plen=4, max_new=12)
        for r in reqs:
            b.submit(r)
        b.step()                                 # drafts + tokens in flight
        hv.resize_request("t", 2)                # migration between chunks
        assert ex.reconfig_log and "t_migrate" in ex.reconfig_log[-1]
        b.run(max_steps=2000)
        assert {r.rid: r.out for r in reqs} == ref


class TestPagedVerifyKernel:
    """Pallas multi-query verify vs the materialized-gather oracle."""

    def _pools(self, key, P, ps, Hkv, dh):
        kk, kv = jax.random.split(key)
        kp = jax.random.normal(kk, (P + 1, ps, Hkv, dh), jnp.float32)
        vp = jax.random.normal(kv, (P + 1, ps, Hkv, dh), jnp.float32)
        return kp.at[P].set(1e4), vp.at[P].set(1e4)   # poisoned trash page

    @pytest.mark.parametrize("H,Hkv", [(4, 2), (8, 1), (8, 8)])
    @pytest.mark.parametrize("W", [2, 4])
    def test_matches_ref(self, H, Hkv, W):
        from repro.kernels.paged_attention import ops, ref

        B, dh, P, ps, maxp = 3, 32, 10, 8, 4
        kq, kp_key = jax.random.split(KEY)
        q = jax.random.normal(kq, (B, W, H, dh), jnp.float32)
        kp, vp = self._pools(kp_key, P, ps, Hkv, dh)
        table = jnp.asarray([[0, 3, 9, -1], [5, 1, 7, -1], [2, 4, 6, 8]],
                            jnp.int32)
        # windows straddling page boundaries and the capacity edge
        cur = jnp.asarray([6, 14, 32 - W], jnp.int32)
        got = ops.paged_verify_attention(q, kp, vp, table, cur,
                                         interpret=True)
        want = ref.paged_verify_attention_ref(q, kp, vp, table, cur)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_window_rows_see_increasing_context(self):
        """Row w attends through cur+w only: verify outputs must equal W
        independent single-query decode calls at successive positions."""
        from repro.kernels.paged_attention import ops, ref

        B, W, H, Hkv, dh, P, ps = 1, 4, 4, 2, 32, 6, 8
        kq, kp_key = jax.random.split(KEY)
        q = jax.random.normal(kq, (B, W, H, dh), jnp.float32)
        kp, vp = self._pools(kp_key, P, ps, Hkv, dh)
        table = jnp.asarray([[1, 4]], jnp.int32)
        cur = jnp.asarray([5], jnp.int32)            # crosses into page 2
        got = ops.paged_verify_attention(q, kp, vp, table, cur,
                                         interpret=True)
        for w in range(W):
            want = ref.paged_decode_attention_ref(
                q[:, w], kp, vp, table, cur + w)
            np.testing.assert_allclose(np.asarray(got[:, w]),
                                       np.asarray(want),
                                       rtol=2e-4, atol=2e-4, err_msg=f"w={w}")


class TestServingConfigAPI:
    def test_config_construction_path(self, qwen):
        cfg, params = qwen
        sc = ServingConfig(slots=2, prompt_len=8, max_len=32)
        with warnings.catch_warnings():
            warnings.simplefilter("error")       # no deprecation on new path
            b = ContinuousBatcher(params, cfg, sc)
        assert b.config is sc

    def test_legacy_kwargs_warn_and_match(self, qwen):
        cfg, params = qwen
        with pytest.warns(DeprecationWarning):
            b = ContinuousBatcher(params, cfg, slots=2, prompt_len=8,
                                  max_len=32, chunk=4)
        assert b.config == ServingConfig(slots=2, prompt_len=8, max_len=32,
                                         chunk=4)

    def test_config_plus_kwargs_is_an_error(self, qwen):
        cfg, params = qwen
        sc = ServingConfig(slots=2, prompt_len=8, max_len=32)
        with pytest.raises(TypeError):
            ContinuousBatcher(params, cfg, sc, slots=4)

    def test_unknown_legacy_kwarg_raises(self):
        with pytest.raises(TypeError, match="slotz"):
            config_from_legacy_kwargs(slotz=4, prompt_len=8, max_len=32)

    def test_cross_field_validation(self):
        with pytest.raises(ValueError):        # prefix cache needs paging
            ServingConfig(slots=2, prompt_len=8, max_len=32,
                          prefix_cache=True)
        with pytest.raises(ValueError):        # no room to decode
            ServingConfig(slots=2, prompt_len=32, max_len=32)
        with pytest.raises(ValueError):        # window too small
            ServingConfig(slots=2, prompt_len=8, max_len=32,
                          speculative=True, draft_window=1)
        with pytest.raises(ValueError):        # capability-gated impl
            ServingConfig(slots=2, prompt_len=8, max_len=32,
                          attn_impl="naive", speculative=True)

    def test_speculative_rejects_ssm_arch(self, qwen):
        from repro.configs import get_reduced as gr
        cfg = gr("mamba2-370m")
        params = init_params(cfg, KEY)
        with pytest.raises(ValueError, match="rolled back"):
            ContinuousBatcher(params, cfg,
                              ServingConfig(slots=2, prompt_len=8,
                                            max_len=32, speculative=True))


class TestProgramRegistry:
    def test_same_key_hits_cache(self):
        reg = ProgramRegistry(maxsize=4)
        calls = []

        def build():
            calls.append(1)
            return object()

        a = reg.get("k", None, None, (3,), None, build)
        b = reg.get("k", None, None, (3,), None, build)
        assert a is b and len(calls) == 1
        c = reg.get("k", None, None, (4,), None, build)
        assert c is not a and len(calls) == 2

    def test_lru_eviction(self):
        reg = ProgramRegistry(maxsize=2)
        for i in range(3):
            reg.get("k", None, None, (i,), None, object)
        assert len(reg) == 2
        assert reg.make_key("k", None, None, (0,), None) not in reg
        assert reg.make_key("k", None, None, (2,), None) in reg

    def test_batcher_programs_share_global_registry(self, qwen):
        cfg, params = qwen
        PROGRAMS.clear()
        sc = ServingConfig(slots=2, prompt_len=8, max_len=32, chunk=2)
        reqs = _mk_requests(cfg, 2, plen=4, max_new=4)
        _run(params, cfg, sc, reqs)
        n1 = len(PROGRAMS)
        assert n1 > 0
        reqs = _mk_requests(cfg, 2, plen=4, max_new=4)
        _run(params, cfg, sc, reqs)              # second batcher, same shapes
        assert len(PROGRAMS) == n1               # no recompilation entries


class TestResumePrefixMiss:
    def test_resumed_rows_count_prefix_misses(self, qwen):
        """An OOM-resumed row is left-padded differently than its original
        prompt, so its re-admission prefix lookup misses — now a
        first-class stat (the lookup itself is the re-attempt: rows resumed
        at the same output length do align and can share)."""
        cfg, params = qwen
        sc = ServingConfig(slots=2, prompt_len=8, max_len=32,
                           attn_impl="xla", chunk=2, paged=True,
                           page_size=4, n_pages=64, prefix_cache=True)
        b = ContinuousBatcher(params, cfg, sc)
        rng = np.random.default_rng(3)
        fresh = Request(rid=0,
                        prompt=rng.integers(1, cfg.vocab, size=4).astype(np.int32),
                        max_new=4, namespace="t")
        b.submit(fresh)
        b.run(max_steps=200)
        assert b.stats.resume_prefix_misses == 0     # fresh rows never count
        # a requeued-with-kept-output request, exactly as _requeue_slot
        # re-enqueues it after an OOM eviction
        resumed = Request(rid=1,
                          prompt=rng.integers(1, cfg.vocab, size=4).astype(np.int32),
                          max_new=6, namespace="t")
        resumed.out = [5, 9]
        resumed.resumed = True
        b.submit(resumed)
        b.run(max_steps=200)
        assert b.stats.resume_prefix_misses == 1
        assert resumed.done
