"""Tensor-sharded serving: token identity, live re-meshing, registry keys.

The shard_map programs need more than one device, so every test that
actually executes a sharded batcher runs in a subprocess with
``--xla_force_host_platform_device_count=8`` set *before* jax imports
(same pattern as ``test_multidevice.py``) — the flag must never leak into
this single-device session.  Registry key semantics are unit-tested
in-process against fabricated meshes: ``ProgramRegistry.mesh_key`` only
reads ``axis_names`` / device shape / device ids.
"""

import os
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import numpy as np
import pytest


def _run_subprocess(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    p = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.path.join(os.path.dirname(__file__), ".."),
        timeout=900,
    )
    assert p.returncode == 0, (p.stdout[-2000:], p.stderr[-4000:])
    return p.stdout


PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax

    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.serving import ServingConfig
    from repro.serving.batcher import ContinuousBatcher, Request

    assert jax.device_count() == 8

    cfg = dataclasses.replace(get_reduced("qwen3-0.6b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))

    def reqs(c, n=6, max_new=12, prefix=0):
        rng = np.random.default_rng(0)
        shared = rng.integers(1, c.vocab, size=prefix).astype(np.int32)
        tail_max = 8 - prefix          # prompts must fit prompt_len=8
        out = []
        for i in range(n):
            tail = rng.integers(1, c.vocab,
                                size=1 + i % tail_max).astype(np.int32)
            out.append(Request(rid=i,
                               prompt=np.concatenate([shared, tail]),
                               max_new=max_new))
        return out

    def sc(tp, paged=False, spec=False, prefix=False, chunk=4):
        return ServingConfig(slots=3, prompt_len=8, max_len=36, chunk=chunk,
                             tp=tp, paged=paged, page_size=4,
                             n_pages=64 if paged else None,
                             prefix_cache=prefix or None,
                             speculative=spec, draft_window=4)

    def run_batcher(p, c, scfg, rs=None, **req_kw):
        b = ContinuousBatcher(p, c, scfg)
        rs = rs if rs is not None else reqs(c, **req_kw)
        for r in rs:
            b.submit(r)
        b.run(max_steps=500)
        return b, [list(map(int, r.out)) for r in rs]
""")


SCRIPT_TP2_IDENTITY = PRELUDE + textwrap.dedent("""
    # -- tp=2 == tp=1, all four serving modes ---------------------------
    for paged, spec, prefix in ((False, False, False), (True, False, False),
                                (True, False, True), (False, True, False)):
        kw = {"prefix": 4} if prefix else {}
        b1, ref = run_batcher(params, cfg, sc(1, paged, spec, prefix), **kw)
        b2, got = run_batcher(params, cfg, sc(2, paged, spec, prefix), **kw)
        assert got == ref, (paged, spec, prefix, got, ref)
        # sharding must not change the dispatch discipline: same number of
        # device dispatches and host syncs as the single-device run
        assert b2.stats.dispatches == b1.stats.dispatches
        assert b2.stats.host_syncs == b1.stats.host_syncs
        assert b2.stats.host_syncs <= b2.stats.dispatches
        print(f"IDENTITY paged={paged} spec={spec} prefix={prefix}")

    # -- tp=2 == the plain-jit generate() oracle ------------------------
    from repro.serving.engine import generate
    rng = np.random.default_rng(1)
    prompts = rng.integers(1, cfg.vocab, size=(3, 8)).astype(np.int32)
    oracle = np.asarray(generate(params, cfg, prompts, n_new=10))
    rs = [Request(rid=i, prompt=prompts[i], max_new=10) for i in range(3)]
    _, got = run_batcher(params, cfg, sc(2), rs=rs)
    assert got == [list(map(int, row)) for row in oracle], (got, oracle)
    print("ORACLE-OK")

    # -- a second same-shape tp=2 batcher hits the program registry -----
    from repro.serving.engine import PROGRAMS
    n_before, hits_before = len(PROGRAMS), dict(PROGRAMS.hits)
    run_batcher(params, cfg, sc(2))
    assert len(PROGRAMS) == n_before, "same mesh+shape must not rebuild"
    assert any(PROGRAMS.hits[k] > hits_before.get(k, 0)
               for k in PROGRAMS.hits), "re-keying onto an existing mesh must hit"
    print("SHARDED-IDENTITY-OK")
""")


SCRIPT_TP4_AND_REGISTRY = PRELUDE + textwrap.dedent("""
    # tp=4 divides n_kv_heads only at 4 kv heads on the reduced config
    cfg4 = dataclasses.replace(cfg, n_kv_heads=4)
    params4 = init_params(cfg4, jax.random.PRNGKey(0))

    for paged in (False, True):
        _, ref = run_batcher(params4, cfg4, sc(1, paged))
        _, got = run_batcher(params4, cfg4, sc(4, paged))
        assert got == ref, (paged, got, ref)
        print(f"TP4 paged={paged} identical")

    # -- two live batchers at different TP widths never collide ---------
    from repro.serving.engine import PROGRAMS
    PROGRAMS.clear()

    def drive(b):
        rs = reqs(cfg4)
        for r in rs:
            b.submit(r)
        b.run(max_steps=500)

    b2 = ContinuousBatcher(params4, cfg4, sc(2))
    drive(b2)
    keys2 = set(PROGRAMS._cache)
    b4 = ContinuousBatcher(params4, cfg4, sc(4))
    drive(b4)
    keys4 = set(PROGRAMS._cache) - keys2
    assert keys4, "the wider batcher must register its own programs"
    # every key carries its mesh fingerprint; widths differ
    width2 = {k[-1][1] for k in keys2 if k[-1] is not None}
    width4 = {k[-1][1] for k in keys4 if k[-1] is not None}
    assert width2 == {(2,)} and width4 == {(4,)}, (width2, width4)

    # hit counters stay per-key: b4's traffic never credits b2's programs
    hits2_before = {k: PROGRAMS.hits[k] for k in keys2}
    drive(b4)
    assert {k: PROGRAMS.hits[k] for k in keys2} == hits2_before
    # ... and b2's own traffic does credit b2's keys
    drive(b2)
    assert any(PROGRAMS.hits[k] > hits2_before[k] for k in keys2)
    print("SHARDED-REGISTRY-OK")
""")


SCRIPT_REMESH = PRELUDE + textwrap.dedent("""
    # -- live 1 -> 2 -> 1 re-mesh mid-stream, token-identical -----------
    for paged, spec in ((False, False), (True, False), (True, True)):
        _, ref = run_batcher(params, cfg, sc(1, paged, spec), max_new=20)
        b = ContinuousBatcher(params, cfg, sc(1, paged, spec))
        rs = reqs(cfg, max_new=20)
        for r in rs:
            b.submit(r)
        b.step(); b.step()
        b.remesh(2)
        b.step(); b.step()
        b.remesh(1)
        b.run(max_steps=500)
        got = [list(map(int, r.out)) for r in rs]
        assert got == ref, (paged, spec, got, ref)
        assert b.stats.remeshes == 2
        print(f"REMESH paged={paged} spec={spec} identical")

    # speculative: the n-gram draft state survives the re-mesh (the drafter
    # keeps accepting after migration — acceptance rate stays > 0)
    b = ContinuousBatcher(params, cfg, sc(1, spec=True))
    rs = reqs(cfg, max_new=24)
    for r in rs:
        b.submit(r)
    b.step(); b.step()
    b.remesh(2)
    before = b.stats.accepted_tokens
    b.run(max_steps=500)
    assert b.stats.accepted_tokens > before, \
        "drafter stopped accepting after the re-mesh"
    print("DRAFT-SURVIVES-OK")

    # -- EOS landing mid-chunk across a re-mesh -------------------------
    _, probe = run_batcher(params, cfg, sc(1), max_new=20)
    eos0 = probe[0][5]                       # fires inside a chunk, not at
    def eos_reqs():                          # an admission boundary
        rs = reqs(cfg, max_new=20)
        rs[0] = Request(rid=0, prompt=rs[0].prompt, max_new=20, eos=eos0)
        return rs
    _, ref = run_batcher(params, cfg, sc(1), rs=eos_reqs())
    assert len(ref[0]) < 20 and ref[0][-1] == eos0
    b = ContinuousBatcher(params, cfg, sc(1))
    rs = eos_reqs()
    for r in rs:
        b.submit(r)
    b.step()
    b.remesh(2)
    b.run(max_steps=500)
    got = [list(map(int, r.out)) for r in rs]
    assert got == ref, (got, ref)
    print("EOS-MID-CHUNK-OK")

    # -- hypervisor-driven: exec_resize re-meshes the live batcher ------
    from repro.core.hypervisor import TenantSpec
    from repro.serving.tenancy import ServingExecutor, VirtualAcceleratorPool
    _, ref = run_batcher(params, cfg, sc(1), max_new=20)
    vpool = VirtualAcceleratorPool(devices=jax.devices(), devices_per_core=1)
    ex = ServingExecutor(vpool)
    ex.exec_admit(TenantSpec(name="t", requested_cores=1, artifact=None),
                  1, at=0.0)
    b = ContinuousBatcher(params, cfg, sc(1))
    ex.register_remesh("t", lambda mesh: b.remesh(mesh=mesh))
    rs = reqs(cfg, max_new=20)
    for r in rs:
        b.submit(r)
    b.step(); b.step()
    ex.exec_resize("t", 2, at=1.0, mode=None)
    assert b.tp == 2
    b.step(); b.step()
    ex.exec_resize("t", 1, at=2.0, mode=None)
    assert b.tp == 1 and b.stats.remeshes == 2
    b.run(max_steps=500)
    got = [list(map(int, r.out)) for r in rs]
    assert got == ref, (got, ref)
    assert any("t_remesh" in e for e in ex.reconfig_log)
    print("SHARDED-REMESH-OK")
""")


@pytest.mark.slow
def test_tp2_token_identity_all_modes_and_oracle():
    """tp=2 through the batcher is token-identical to tp=1 and to the
    plain-jit ``generate`` oracle, for dense / paged / prefix-cached /
    speculative serving, with the same dispatch + host-sync counts; a
    second same-shape batcher reuses the compiled sharded programs."""
    out = _run_subprocess(SCRIPT_TP2_IDENTITY)
    assert "ORACLE-OK" in out
    assert "SHARDED-IDENTITY-OK" in out


@pytest.mark.slow
def test_tp4_identity_and_registry_width_isolation():
    """tp=4 decode is token-identical, and two live batchers at different
    TP widths keep disjoint registry keys with per-key hit counters."""
    out = _run_subprocess(SCRIPT_TP4_AND_REGISTRY)
    assert "SHARDED-REGISTRY-OK" in out


@pytest.mark.slow
def test_live_remesh_token_identity():
    """Re-meshing a live batcher 1 -> 2 -> 1 mid-stream (donated caches
    resharded via live_state/adopt_state) never changes a single token —
    dense, paged, speculative (draft state survives), EOS mid-chunk, and
    the hypervisor-driven ``exec_resize`` path."""
    out = _run_subprocess(SCRIPT_REMESH)
    assert "DRAFT-SURVIVES-OK" in out
    assert "EOS-MID-CHUNK-OK" in out
    assert "SHARDED-REMESH-OK" in out


# ---------------------------------------------------------------------------
# registry key semantics: in-process, no devices needed
# ---------------------------------------------------------------------------

def _fake_mesh(ids, axis="tp"):
    devs = np.array([SimpleNamespace(id=i) for i in ids], dtype=object)
    return SimpleNamespace(axis_names=(axis,), devices=devs)


class TestMeshKeyedRegistry:
    def test_mesh_fingerprint_separates_widths_and_device_sets(self):
        from repro.serving.engine import ProgramRegistry

        base = ("chunk", None, None, (4,), 0)
        k_none = ProgramRegistry.make_key(*base, mesh=None)
        k2 = ProgramRegistry.make_key(*base, mesh=_fake_mesh([0, 1]))
        k4 = ProgramRegistry.make_key(*base, mesh=_fake_mesh([0, 1, 2, 3]))
        k2b = ProgramRegistry.make_key(*base, mesh=_fake_mesh([2, 3]))
        assert len({k_none, k2, k4, k2b}) == 4, \
            "width or device-set change must change the key"
        # identical mesh -> identical key (a re-mesh back must cache-hit)
        assert k2 == ProgramRegistry.make_key(*base, mesh=_fake_mesh([0, 1]))

    def test_hits_are_per_key_and_dropped_on_eviction(self):
        from repro.serving.engine import ProgramRegistry

        reg = ProgramRegistry(maxsize=2)
        ka = ("a",)
        kb = ("b",)
        reg.get_raw(ka, None, lambda: "A")
        reg.get_raw(kb, None, lambda: "B")
        assert reg.hits == {ka: 0, kb: 0}
        assert reg.get_raw(ka, None, lambda: "never") == "A"
        assert reg.hits[ka] == 1 and reg.hits[kb] == 0
        # third key evicts the LRU entry (kb) along with its counter
        reg.get_raw(("c",), None, lambda: "C")
        assert kb not in reg.hits and ka in reg.hits
        reg.clear()
        assert reg.hits == {}
