"""Model zoo: per-arch smoke tests (reduced configs) + numerics invariants.

Every assigned architecture instantiates its REDUCED config, runs one
forward and one train step on CPU, and asserts output shapes + finiteness.
Prefill→decode continuity is checked against the full-sequence forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.models import (
    decode_step, encoder_forward, forward, init_caches, init_params, prefill,
)
from repro.models.transformer import n_blocks, period_len, period_structure

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16):
    batch = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab}
    kw = {}
    if cfg.family == "vlm":
        n_patch = 4
        batch["tokens"] = batch["tokens"][:, : S - n_patch]
        kw["extra_embeds"] = jnp.ones((B, n_patch, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.01
        kw["positions"] = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S)).copy()
    return batch, kw


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_reduced(arch)
        params = init_params(cfg, KEY)
        B, S = 2, 16
        batch, kw = make_batch(cfg, B, S)
        if cfg.family == "audio":
            frames = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.01
            kw["enc_out"] = encoder_forward(params, frames, cfg)
        out = forward(params, batch["tokens"], cfg, **kw)
        assert out.hidden.shape == (B, S, cfg.d_model)
        assert bool(jnp.isfinite(out.hidden.astype(jnp.float32)).all())

    def test_one_train_step(self, arch):
        from repro.optim import adamw_init
        from repro.training.steps import TrainerConfig, make_train_step

        cfg = get_reduced(arch)
        params = init_params(cfg, KEY)
        B, S = 2, 16
        batch, kw = make_batch(cfg, B, S)
        batch["labels"] = jnp.ones((B, S), jnp.int32)
        if cfg.family == "vlm":
            batch["extra_embeds"] = kw["extra_embeds"]
            batch["positions"] = kw["positions"]
        if cfg.family == "audio":
            batch["frames"] = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.01
        step = jax.jit(make_train_step(cfg, TrainerConfig(loss_chunk=8)))
        p2, o2, m = step(params, adamw_init(params), batch)
        assert bool(jnp.isfinite(m["loss"]))
        assert bool(jnp.isfinite(m["grad_norm"]))
        assert float(m["loss"]) < 2.0 * np.log(cfg.vocab_padded)
        # params actually moved
        moved = jax.tree.reduce(
            lambda a, b: a or b,
            jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, p2),
        )
        assert moved

    def test_full_config_matches_assignment(self, arch):
        """Full configs carry the exact assigned hyperparameters."""
        cfg = get_config(arch)
        assert cfg.n_layers % period_len(cfg) == 0
        assert cfg.param_count() > 0
        assert cfg.param_count(active_only=True) <= cfg.param_count()


SPOT = {
    # analytic param-count spot checks vs public figures (±12%: padding etc.)
    "qwen3-0.6b": 0.60e9,          # 0.44B blocks + 0.156B tied embedding
    "starcoder2-7b": 7.4e9,   # gelu 2-matrix MLP
    "qwen3-32b": 32.8e9,
    "command-r-plus-104b": 104e9,
    "mixtral-8x22b": 141e9,
    "deepseek-moe-16b": 16.4e9,
    "mamba2-370m": 0.37e9,
}


@pytest.mark.parametrize("arch,expected", sorted(SPOT.items()))
def test_param_count_spot(arch, expected):
    got = get_config(arch).param_count()
    assert got == pytest.approx(expected, rel=0.13), f"{arch}: {got/1e9:.2f}B"


class TestPrefillDecodeContinuity:
    @pytest.mark.parametrize("arch", ["qwen3-0.6b", "mixtral-8x22b", "mamba2-370m",
                                      "jamba-1.5-large-398b", "whisper-base"])
    def test_decode_matches_forward(self, arch):
        """prefill(t[:‑1]) + decode(t[-1]) logits == forward(t) last logits."""
        cfg = get_reduced(arch)
        params = init_params(cfg, KEY)
        B, S = 2, 12
        toks = (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) * 7) % cfg.vocab
        kw = {}
        if cfg.family == "audio":
            frames = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.01
            kw["enc_out"] = encoder_forward(params, frames, cfg)

        # ground truth: full forward over all S tokens
        from repro.models import logits_fn

        out = forward(params, toks, cfg, **kw)
        ref = logits_fn(params, out.hidden[:, -1:, :], cfg)[:, 0]

        # prefill first S-1, decode token S-1
        logits_p, caches = prefill(params, toks[:, :-1], cfg, max_len=S + 4, **kw)
        cur = jnp.full((B,), S - 1, dtype=jnp.int32)
        got, _ = decode_step(params, toks[:, -1], caches, cur, cfg)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32),
            rtol=0.05, atol=0.05,
        )


class TestSlidingWindow:
    def test_ring_buffer_cache_is_window_sized(self):
        cfg = get_reduced("mixtral-8x22b")
        assert cfg.sliding_window == 64
        caches = init_caches(cfg, batch=2, max_len=512)
        for kv in caches.kv.values():
            assert kv.k.shape[2] == cfg.sliding_window   # (nb, B, C, Hkv, dh)

    def test_ring_buffer_holds_last_window_positions(self):
        """After prefilling S > window tokens, the ring buffer contains
        exactly positions [S-window, S) — older K/V were overwritten (the
        O(window) memory property that makes long_500k runnable)."""
        cfg = get_reduced("mixtral-8x22b")
        params = init_params(cfg, KEY)
        S, W = 80, cfg.sliding_window     # 80 > 64
        toks = (jnp.arange(S, dtype=jnp.int32)[None] * 3) % cfg.vocab
        _, caches = prefill(params, toks, cfg, max_len=S + 2)
        for kv in caches.kv.values():
            pos = np.asarray(kv.pos)      # (nb, B, C)
            assert pos.shape[-1] == W
            held = set(pos[0, 0].tolist())
            assert held == set(range(S - W, S))

    def test_single_layer_window_masks_expired(self):
        """At the ATTENTION level (single layer — no cross-layer receptive
        field), tokens outside the window are provably ignored."""
        from repro.models.attention import naive_attention

        kq, kk, kv = jax.random.split(KEY, 3)
        B, S, H, dh, W = 1, 32, 2, 8, 8
        q = jax.random.normal(kq, (B, S, H, dh))
        k = jax.random.normal(kk, (B, S, H, dh))
        v = jax.random.normal(kv, (B, S, H, dh))
        out1 = naive_attention(q, k, v, causal=True, window=W)
        # perturb K/V older than the window for the last query row
        k2 = k.at[:, :8].set(0.0)
        v2 = v.at[:, :8].set(0.0)
        out2 = naive_attention(q, k2, v2, causal=True, window=W)
        np.testing.assert_allclose(np.asarray(out1[:, -1]), np.asarray(out2[:, -1]),
                                   rtol=1e-5, atol=1e-5)


class TestPeriodStructure:
    def test_jamba_interleave(self):
        cfg = get_config("jamba-1.5-large-398b")
        specs = period_structure(cfg)
        assert len(specs) == cfg.attn_every
        assert sum(1 for s in specs if s.mixer == "attn") == 1  # 1:7 ratio
        assert n_blocks(cfg) * len(specs) == cfg.n_layers

    def test_mamba_is_attention_free(self):
        cfg = get_config("mamba2-370m")
        assert all(s.mixer == "ssm" for s in period_structure(cfg))
