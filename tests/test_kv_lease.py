"""Hypervisor memory dimension (kv_pages leases + quota invariants),
EASY backfill reservations, and SLO-slack preemption victims."""

import pytest

from repro.core.hrp import HRPError, ResourcePool
from repro.core.hypervisor import (
    Hypervisor,
    PolicyContext,
    TenantSpec,
    kv_pages_proportional,
)


class RecordingExecutor:
    """Minimal pool executor that records kv resizes and serves a
    per-tenant latency table for the slack-victim tests."""

    def __init__(self, pool, latency=None):
        self.pool = pool
        self.latency = latency or {}
        self.kv_log = []

    def exec_admit(self, spec, n_cores, at):
        self.pool.alloc(spec.name, n_cores)

    def exec_resize(self, name, n_cores, at, mode):
        self.pool.resize(name, n_cores)

    def exec_remove(self, name, at):
        self.pool.release(name)

    def exec_kv_resize(self, name, pages, at):
        self.kv_log.append((name, pages))

    def estimate_latency(self, spec, n_cores):
        return self.latency.get(spec.name)


class TestResourcePoolKV:
    def test_set_and_release(self):
        pool = ResourcePool(n_cores=4, n_kv_pages=10)
        pool.alloc("a", 2)
        pool.set_kv_lease("a", 6)
        assert pool.kv_lease_of("a") == 6
        assert pool.free_kv_pages() == 4
        pool.set_kv_lease("a", 0)
        assert pool.kv_lease_of("a") == 0
        pool.set_kv_lease("a", 3)
        pool.release("a")                        # drops the kv lease too
        assert pool.kv_leases == {}
        pool.check_kv_quota()

    def test_requires_core_lease(self):
        pool = ResourcePool(n_cores=4, n_kv_pages=10)
        with pytest.raises(HRPError):
            pool.set_kv_lease("ghost", 1)

    def test_oversubscription_raises(self):
        pool = ResourcePool(n_cores=4, n_kv_pages=10)
        pool.alloc("a", 1)
        pool.alloc("b", 1)
        pool.set_kv_lease("a", 7)
        with pytest.raises(HRPError):
            pool.set_kv_lease("b", 4)
        pool.set_kv_lease("b", 3)
        pool.check_kv_quota()

    def test_negative_raises(self):
        pool = ResourcePool(n_cores=4, n_kv_pages=10)
        pool.alloc("a", 1)
        with pytest.raises(HRPError):
            pool.set_kv_lease("a", -1)


class TestKVSplit:
    def _ctx(self, tenants, alloc, n_kv=100):
        return PolicyContext(8, tenants, {t: c for t, c in alloc.items()},
                             0.0, n_kv_pages=n_kv)

    def test_memory_follows_compute(self):
        a = TenantSpec("a", 6, requested_kv_pages=100)
        b = TenantSpec("b", 2, requested_kv_pages=100, arrived_at=1.0)
        alloc = {"a": 6, "b": 2}
        kv = kv_pages_proportional(self._ctx([a, b], alloc), alloc)
        assert kv["a"] + kv["b"] == 100
        assert kv["a"] == 75 and kv["b"] == 25

    def test_floors_and_caps(self):
        a = TenantSpec("a", 4, requested_kv_pages=10, min_kv_pages=10)
        b = TenantSpec("b", 4, requested_kv_pages=200, min_kv_pages=5,
                       arrived_at=1.0)
        alloc = {"a": 4, "b": 4}
        kv = kv_pages_proportional(self._ctx([a, b], alloc), alloc)
        assert kv["a"] == 10                     # capped at request
        assert kv["b"] == 90                     # leftovers flow to b
        assert sum(kv.values()) <= 100

    def test_no_cores_no_pages(self):
        a = TenantSpec("a", 4, requested_kv_pages=50)
        b = TenantSpec("b", 4, requested_kv_pages=50, arrived_at=1.0)
        alloc = {"a": 4, "b": 0}
        kv = kv_pages_proportional(self._ctx([a, b], alloc), alloc)
        assert kv["b"] == 0


class TestHypervisorKV:
    def _hv(self, n_cores=8, n_kv=100, **kw):
        pool = ResourcePool(n_cores=n_cores, n_kv_pages=n_kv)
        ex = RecordingExecutor(pool)
        checked = []
        hv = Hypervisor(pool, executor=ex,
                        on_event=lambda h, e: checked.append(e.kind), **kw)
        return hv, ex, checked

    def test_admission_grants_pages_and_rechecks_invariants(self):
        hv, ex, checked = self._hv()
        assert hv.admit(TenantSpec("a", 4, requested_kv_pages=60,
                                   min_kv_pages=20))
        assert hv.admit(TenantSpec("b", 4, requested_kv_pages=60,
                                   min_kv_pages=20))
        kv = hv.kv_allocation()
        assert sum(kv.values()) <= 100
        assert kv["a"] >= 20 and kv["b"] >= 20
        assert ("a", kv["a"]) in ex.kv_log and ("b", kv["b"]) in ex.kv_log
        assert len(checked) == 2                 # invariants ran per event

    def test_kv_floor_blocks_admission(self):
        hv, ex, _ = self._hv()
        assert hv.admit(TenantSpec("a", 4, requested_kv_pages=70,
                                   min_kv_pages=70))
        assert not hv.admit(TenantSpec("b", 4, requested_kv_pages=80,
                                       min_kv_pages=80))
        assert hv.waiting_tenants() == ["b"]
        # departure frees pages; the waiter admits with its floor met
        hv.depart("a")
        assert hv.kv_allocation().get("b", 0) >= 80

    def test_departure_releases_pages(self):
        hv, ex, _ = self._hv()
        hv.admit(TenantSpec("a", 4, requested_kv_pages=50))
        hv.admit(TenantSpec("b", 4, requested_kv_pages=50))
        hv.depart("a")
        kv = hv.kv_allocation()
        assert "a" not in kv
        assert sum(kv.values()) <= 100
        hv.pool.check_kv_quota()

    def test_resident_resubmission_updates_kv_contract(self):
        """A resident re-ARRIVing with new kv fields renegotiates them,
        exactly like requested_cores/min_cores/priority."""
        hv, ex, _ = self._hv()
        assert hv.admit(TenantSpec("a", 8, requested_kv_pages=10))
        assert hv.kv_allocation()["a"] == 10
        assert hv.admit(TenantSpec("a", 8, requested_kv_pages=80,
                                   min_kv_pages=40))
        assert hv.specs["a"].requested_kv_pages == 80
        assert hv.specs["a"].min_kv_pages == 40
        assert hv.kv_allocation()["a"] == 80

    def test_preemption_rollback_restores_kv_lease(self):
        """A doomed preemption attempt must restore victims at their exact
        core AND page leases."""
        pool = ResourcePool(n_cores=4, n_kv_pages=100)
        ex = RecordingExecutor(pool)
        hv = Hypervisor(pool, executor=ex, preemptive=True)
        assert hv.admit(TenantSpec("low", 4, priority=1.0,
                                   requested_kv_pages=40))
        before = hv.kv_allocation()["low"]
        # the arrival wants more kv pages than exist: eviction happens, the
        # re-admission fails, and the rollback restores low's page lease
        assert not hv.admit(TenantSpec("hi", 2, priority=2.0,
                                       requested_kv_pages=200,
                                       min_kv_pages=200))
        assert hv.allocation() == {"low": 4}
        assert hv.kv_allocation()["low"] == before
        hv.pool.check_kv_quota()


class TestEasyReservation:
    """Regression: plain backfill starves a large waiter under churn of
    small short-lived tenants; EASY's head reservation bounds its start."""

    @staticmethod
    def _churn(admission):
        admitted_at = {}

        def on_event(hv, ev):
            for name in hv.allocation():
                admitted_at.setdefault(name, hv.clock)

        pool = ResourcePool(n_cores=4)
        hv = Hypervisor(pool, policy="no_realloc", admission=admission,
                        on_event=on_event)
        hv.schedule_arrival(TenantSpec("A", 2), at=0.0)
        hv.schedule_departure("A", at=2.0)
        hv.schedule_arrival(TenantSpec("H", 3, min_cores=3), at=0.1)
        t, i = 0.2, 0
        while t < 6.0:                           # churn outlives A by far
            hv.schedule_arrival(TenantSpec(f"s{i}", 2), at=t)
            hv.schedule_departure(f"s{i}", at=t + 0.5)
            t += 0.4
            i += 1
        hv.run(8.0)
        return admitted_at.get("H"), hv

    def test_backfill_starves_head_easy_does_not(self):
        t_backfill, _ = self._churn("backfill")
        t_easy, hv = self._churn("easy")
        # EASY: A's departure at t=2 hands the head its reserved cores
        assert t_easy is not None and t_easy <= 2.0
        # naive backfill: churn re-consumes every departure until it stops
        assert t_backfill is None or t_backfill > 6.0
        assert "H" in hv.allocation()

    def test_easy_still_backfills_when_harmless(self):
        """EASY is not FIFO: a small tenant that leaves the head's floor in
        free cores still slips past the blocked head; one that would eat
        into the reservation does not."""
        pool = ResourcePool(n_cores=8)
        hv = Hypervisor(pool, policy="no_realloc", admission="easy")
        assert hv.admit(TenantSpec("A", 4))
        assert not hv.admit(TenantSpec("H", 6, min_cores=6))   # waits (4 free)
        assert not hv.admit(TenantSpec("big", 2))  # 4-2=2 < 6: blocked
        assert hv.waiting_tenants() == ["H", "big"]
        hv.depart("A")                             # 8 free: H seats, big next
        assert "H" in hv.allocation() and "big" in hv.allocation()
        # with a small head floor, harmless backfill still happens
        hv2 = Hypervisor(ResourcePool(n_cores=8), policy="no_realloc",
                         admission="easy")
        assert hv2.admit(TenantSpec("B", 5))
        assert not hv2.admit(TenantSpec("h", 5, min_cores=2))  # waits (3 free)
        assert hv2.admit(TenantSpec("s", 1))       # leaves 2 >= head floor
        assert hv2.waiting_tenants() == ["h"]

    def test_reservation_covers_kv_pages(self):
        """The head's start-time guarantee must hold when kv pages, not
        cores, are the binding resource: a backfiller that would eat the
        head's kv floor is blocked under EASY."""

        def run(admission):
            pool = ResourcePool(n_cores=8, n_kv_pages=10)
            hv = Hypervisor(pool, policy="no_realloc", admission=admission)
            assert hv.admit(TenantSpec("A", 2, requested_kv_pages=6,
                                       min_kv_pages=6))
            # head: cores are plentiful, kv pages are not (needs 10)
            assert not hv.admit(TenantSpec("H", 1, requested_kv_pages=10,
                                           min_kv_pages=10))
            # small backfiller wants the remaining 4 pages
            jumped = hv.admit(TenantSpec("s", 1, requested_kv_pages=4,
                                         min_kv_pages=4))
            return hv, jumped

        hv_b, jumped_b = run("backfill")
        assert jumped_b                          # naive backfill takes them
        hv_e, jumped_e = run("easy")
        assert not jumped_e                      # reservation protects H
        hv_e.depart("A")
        assert hv_e.kv_allocation().get("H") == 10

    def test_fifo_unaffected(self):
        pool = ResourcePool(n_cores=4)
        hv = Hypervisor(pool, policy="no_realloc", admission="fifo")
        hv.admit(TenantSpec("A", 4))
        assert not hv.admit(TenantSpec("H", 2))
        assert not hv.admit(TenantSpec("s", 1))    # FIFO: never jumps
        assert hv.waiting_tenants() == ["H", "s"]


class TestSlackVictims:
    def _hv(self, latency):
        pool = ResourcePool(n_cores=4)
        ex = RecordingExecutor(pool, latency=latency)
        return Hypervisor(pool, policy="no_realloc", preemptive=True,
                          executor=ex)

    def test_largest_slack_in_lowest_tier_goes_first(self):
        hv = self._hv({"x": 1.0, "y": 5.0})
        assert hv.admit(TenantSpec("x", 2, priority=1.0, latency_slo=10.0))
        assert hv.admit(TenantSpec("y", 2, priority=1.0, latency_slo=6.0))
        assert hv.admit(TenantSpec("hi", 2, priority=2.0))
        # x has slack 9, y has slack 1: x pays
        assert hv.preemptions == ["x"]
        assert "y" in hv.allocation() and "hi" in hv.allocation()

    def test_no_slo_counts_as_infinite_slack(self):
        hv = self._hv({"tight": 5.0})
        assert hv.admit(TenantSpec("tight", 2, priority=1.0, latency_slo=6.0))
        assert hv.admit(TenantSpec("loose", 2, priority=1.0))   # no SLO
        assert hv.admit(TenantSpec("hi", 2, priority=2.0))
        assert hv.preemptions == ["loose"]

    def test_tier_outranks_slack(self):
        """Priority tier still dominates: a lower-tier tenant with small
        slack is evicted before a higher-tier tenant with huge slack."""
        hv = self._hv({"t0": 5.9, "t1": 0.1})
        assert hv.admit(TenantSpec("t0", 2, priority=0.5, latency_slo=6.0))
        assert hv.admit(TenantSpec("t1", 2, priority=1.0, latency_slo=10.0))
        assert hv.admit(TenantSpec("hi", 2, priority=2.0))
        assert hv.preemptions == ["t0"]

    def test_equal_slack_tie_breaks_youngest_then_name(self):
        hv = self._hv({})                        # no estimates: all inf slack
        assert hv.admit(TenantSpec("old", 2, priority=1.0), at=0.0)
        assert hv.admit(TenantSpec("young", 2, priority=1.0), at=1.0)
        assert hv.admit(TenantSpec("hi", 2, priority=2.0), at=2.0)
        assert hv.preemptions == ["young"]
