"""IFP tiling + two-stage static/dynamic compilation invariants."""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    DynamicCompiler, Strategy, fpga_small_core, make_layer_ifps, simulate,
)
from repro.core.ifp import dedupe_onchip
from repro.core.workloads import Layer


def _layer(w=56, c_out=256, c_in=64, kh=3, kw=3, groups=1):
    return Layer("t", w, w, c_in, c_out, kh, kw, groups=groups)


class TestTiling:
    @given(n_tiles=st.integers(1, 32), w=st.integers(1, 64), c=st.integers(1, 512))
    @settings(max_examples=100, deadline=None)
    def test_width_tiles_cover_output(self, n_tiles, w, c):
        layer = Layer("t", 8, w, 16, c, 3, 3)
        ifps = make_layer_ifps(layer, 0, Strategy.WIDTH, n_tiles)
        assert 1 <= len(ifps) <= min(n_tiles, w)
        # FLOPs conservation: tiles sum to the untiled layer
        total = sum(i.program.total_flops for i in ifps)
        assert total == pytest.approx(layer.flops, rel=1e-6)

    @given(n_tiles=st.integers(1, 32), c_out=st.integers(1, 512))
    @settings(max_examples=100, deadline=None)
    def test_oc_tiles_cover_output(self, n_tiles, c_out):
        layer = Layer("t", 8, 8, 16, c_out, 3, 3)
        ifps = make_layer_ifps(layer, 0, Strategy.OC, n_tiles)
        total = sum(i.program.total_flops for i in ifps)
        assert total == pytest.approx(layer.flops, rel=1e-6)

    def test_width_tiles_share_weights(self):
        ifps = make_layer_ifps(_layer(), 0, Strategy.WIDTH, 4)
        wloads = [
            i for ifp in ifps for i in ifp.program
            if i.tag.get("kind") == "w"
        ]
        keys = {i.tag["key"] for i in wloads}
        assert len(keys) == 1                      # same weights everywhere
        assert all(i.tag.get("shared") for i in wloads)
        # each tile still pays the FULL weight tensor when cold
        full_w = _layer().weight_nbytes
        for i in wloads:
            assert i.nbytes == pytest.approx(full_w)

    def test_oc_tiles_have_disjoint_weights(self):
        layer = _layer()
        ifps = make_layer_ifps(layer, 0, Strategy.OC, 4)
        wloads = [
            i for ifp in ifps for i in ifp.program
            if i.tag.get("kind") == "w"
        ]
        assert len({i.tag["key"] for i in wloads}) == len(ifps)
        assert not any(i.tag.get("shared") for i in wloads)
        total_w = sum(i.nbytes for i in wloads)
        assert total_w == pytest.approx(layer.weight_nbytes, rel=1e-6)

    def test_depthwise_oc_splits_input_channels(self):
        layer = _layer(c_in=64, c_out=64, groups=64)
        assert layer.is_depthwise
        ifps = make_layer_ifps(layer, 0, Strategy.OC, 4)
        total = sum(i.program.total_flops for i in ifps)
        assert total == pytest.approx(layer.flops, rel=1e-6)

    def test_narrow_dim_gives_fewer_tiles(self):
        layer = Layer("t", 7, 7, 512, 2048, 1, 1)
        ifps = make_layer_ifps(layer, 0, Strategy.WIDTH, 16)
        assert len(ifps) == 7                       # w=7 < 16 requested


class TestStaticCompiler:
    def test_artifact_complete(self, resnet_artifact):
        art = resnet_artifact
        n_layers = len(art.workload)
        assert len(art.luts) == 2 * n_layers        # both strategies
        assert len(art.mono) == n_layers
        for (li, s), lut in art.luts.items():
            assert len(lut.ifps) == len(lut.cold) == len(lut.cached)
            assert lut.precomputed is not None
            for ifp in lut.ifps:
                assert ifp.latency > 0
                assert ifp.latency_cached <= ifp.latency + 1e-12
                assert ifp.program_cached is not None

    def test_cached_drops_only_shared(self, resnet_artifact):
        lut = resnet_artifact.lut(1, Strategy.OC)
        for ifp in lut.ifps:
            cold_w = [i for i in ifp.program if i.tag.get("kind") == "w"]
            cached_w = [i for i in ifp.program_cached if i.tag.get("kind") == "w"]
            # OC weight slices are per-tile: never dropped
            assert len(cold_w) == len(cached_w)


class TestDynamicCompiler:
    def test_all_ifps_assigned_once(self, resnet_artifact):
        dyn = DynamicCompiler(resnet_artifact)
        for k in (1, 2, 5, 16):
            sch = dyn.compile(list(range(k)), single_core_fastpath=False)
            for li, plan in enumerate(sch.plans):
                lut = resnet_artifact.lut(li, plan.strategy)
                flat = sorted(i for r in plan.assignment for i in r)
                assert flat == list(range(len(lut.ifps)))

    def test_chain_matches_dedupe_reference(self, resnet_artifact):
        """The zero-copy chain runs in exactly the time of the reference
        instruction-file concatenation with on-chip reuse dedupe."""
        hw = fpga_small_core()
        dyn = DynamicCompiler(resnet_artifact)
        sch = dyn.compile(list(range(3)), single_core_fastpath=False)
        for li, plan in enumerate(sch.plans):
            lut = resnet_artifact.lut(li, plan.strategy)
            for c, idxs in enumerate(plan.assignment):
                if not idxs:
                    continue
                merged = dedupe_onchip([lut.ifps[i].program for i in idxs],
                                       hw.vmem_bytes)
                merged.sync()
                assert simulate(sch.per_core_layers[c][li], hw) == pytest.approx(
                    simulate(merged, hw), rel=1e-9
                )

    def test_sync_appended_every_layer(self, resnet_artifact):
        dyn = DynamicCompiler(resnet_artifact)
        sch = dyn.compile(list(range(4)), single_core_fastpath=False)
        for layers in sch.per_core_layers:
            assert len(layers) == len(resnet_artifact.workload)
            for chain in layers:
                last = chain.programs[-1]
                assert last.instrs[-1].is_sync

    def test_single_core_fastpath_uses_mono(self, resnet_artifact):
        dyn = DynamicCompiler(resnet_artifact)
        sch = dyn.compile([7])
        hw = fpga_small_core()
        # fastpath latency equals the mono latency (plus syncs)
        est = sch.estimated_latency(hw)
        mono = sum(resnet_artifact.mono_latency) + len(resnet_artifact.mono) * hw.sync_latency
        assert est == pytest.approx(mono, rel=1e-6)

    def test_dynamic_much_faster_than_static(self, resnet_artifact):
        dyn = DynamicCompiler(resnet_artifact)
        best = min(
            dyn.compile(list(range(8))).compile_seconds for _ in range(5)
        )
        # paper: static O(10 s) vs dynamic O(1 ms).  Our static is ~0.2 s;
        # assert at least 20x asymmetry (typically ~100x).
        assert best < resnet_artifact.compile_seconds / 20

    def test_opt_beats_or_matches_forced_strategies(self, resnet_artifact):
        """Per-layer optimized choice is never worse than either forced
        strategy (paper Table 3's 'opt' row)."""
        from repro.core import allocate

        art = resnet_artifact
        hw = fpga_small_core()
        k = 4
        dyn = DynamicCompiler(art)
        opt = dyn.compile(list(range(k)), single_core_fastpath=False)
        t_opt = opt.estimated_latency(hw)
        for strat in (Strategy.WIDTH, Strategy.OC):
            t_forced = 0.0
            for li in range(len(art.workload)):
                lut = art.lut(li, strat)
                _, ms = allocate(lut.cached, k, run_overhead=lut.run_overhead,
                                 precomputed=lut.precomputed)
                t_forced += ms + hw.sync_latency
            assert t_opt <= t_forced * 1.02 + 1e-9

    def test_context_switch_cost_structure(self, resnet_artifact):
        dyn = DynamicCompiler(resnet_artifact)
        hw = fpga_small_core()
        sch = dyn.compile(list(range(4)))
        cost = dyn.context_switch_cost(sch, hw)
        assert cost["t_context"] == pytest.approx(
            cost["t_recompile"] + cost["t_transfer"]
        )
        # the paper's headline: online reconfiguration ~1 ms (<10 ms here,
        # generous bound for CI noise on a loaded shared core)
        assert cost["t_context"] < 0.05
