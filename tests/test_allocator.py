"""Workload-balanced allocator (paper Eqs. 4-6): exactness + invariants."""

import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.allocator import (
    allocate,
    allocate_contiguous_bs,
    allocate_contiguous_dp,
    allocate_lpt,
    allocate_weighted,
    partition_candidates,
)

times_strategy = st.lists(
    st.floats(min_value=1e-6, max_value=10.0, allow_nan=False, allow_infinity=False),
    min_size=1, max_size=24,
)


def brute_force_contiguous(times, k, run_overhead=0.0):
    """Exact reference: try every contiguous split (small n only)."""
    n = len(times)
    k = min(k, n)
    best = math.inf

    def rec(i, parts_left, cur_max):
        nonlocal best
        if i == n:
            if cur_max < best:
                best = cur_max
            return
        if parts_left == 0:
            return
        s = 0.0
        for j in range(i, n):
            s += times[j]
            if n - (j + 1) >= parts_left - 1 if parts_left > 1 else True:
                rec(j + 1, parts_left - 1, max(cur_max, s + run_overhead))

    rec(0, k, 0.0)
    return best


class TestContiguousSolvers:
    @given(times=times_strategy, k=st.integers(1, 8),
           overhead=st.floats(0, 1.0, allow_nan=False))
    @settings(max_examples=200, deadline=None)
    def test_bs_equals_dp(self, times, k, overhead):
        """The binary-search solver is exact: same makespan as the DP."""
        _, ms_bs = allocate_contiguous_bs(times, k, run_overhead=overhead)
        _, ms_dp = allocate_contiguous_dp(times, k, run_overhead=overhead)
        assert ms_bs == pytest.approx(ms_dp, rel=1e-9)

    @given(times=st.lists(st.floats(1e-3, 5.0, allow_nan=False), min_size=1, max_size=9),
           k=st.integers(1, 4))
    @settings(max_examples=100, deadline=None)
    def test_dp_equals_bruteforce(self, times, k):
        _, ms = allocate_contiguous_dp(times, k)
        assert ms == pytest.approx(brute_force_contiguous(times, k), rel=1e-9)

    @given(times=times_strategy, k=st.integers(1, 20),
           overhead=st.floats(0, 1.0, allow_nan=False))
    @settings(max_examples=200, deadline=None)
    def test_partition_property(self, times, k, overhead):
        """Every IFP assigned exactly once (paper Eq. 5); contiguity holds."""
        runs, ms = allocate_contiguous_bs(times, k, run_overhead=overhead)
        flat = [i for r in runs for i in r]
        assert sorted(flat) == list(range(len(times)))
        assert flat == sorted(flat)          # contiguous, in order
        assert len(runs) == k
        # makespan consistency
        worst = max(
            (sum(times[i] for i in r) + overhead) for r in runs if r
        )
        assert ms == pytest.approx(worst, rel=1e-9)

    def test_precomputed_matches_direct(self):
        times = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]
        pre = partition_candidates(times, run_overhead=0.5)
        a = allocate_contiguous_bs(times, 3, run_overhead=0.5)
        b = allocate_contiguous_bs(times, 3, run_overhead=0.5, precomputed=pre)
        assert a[1] == pytest.approx(b[1])
        assert a[0] == b[0]

    def test_more_cores_than_tiles(self):
        runs, ms = allocate_contiguous_bs([2.0, 3.0], 16)
        assert runs[0] == [0] and runs[1] == [1]
        assert all(r == [] for r in runs[2:])
        assert ms == pytest.approx(3.0)


class TestLPT:
    @given(times=times_strategy, k=st.integers(1, 8))
    @settings(max_examples=100, deadline=None)
    def test_lpt_within_greedy_bound(self, times, k):
        """Graham's list-scheduling bound: makespan <= sum/k + max."""
        _, ms = allocate_lpt(times, k)
        assert ms <= sum(times) / k + max(times) + 1e-9
        assert ms >= max(max(times), sum(times) / k) - 1e-9

    @given(times=times_strategy, k=st.integers(1, 8))
    @settings(max_examples=100, deadline=None)
    def test_lpt_partition(self, times, k):
        runs, _ = allocate_lpt(times, k)
        assert sorted(i for r in runs for i in r) == list(range(len(times)))


class TestWeighted:
    def test_slow_core_gets_less(self):
        times = [1.0] * 16
        runs, _ = allocate_weighted(times, [1.0, 1.0, 1.0, 0.25])
        # the 4x-slow core must receive the least work
        loads = [len(r) for r in runs]
        assert loads[3] == min(loads)
        assert loads[3] <= loads[0] / 2

    @given(times=times_strategy,
           speeds=st.lists(st.floats(0.1, 2.0, allow_nan=False), min_size=1, max_size=6))
    @settings(max_examples=100, deadline=None)
    def test_weighted_partition(self, times, speeds):
        runs, ms = allocate_weighted(times, speeds)
        assert sorted(i for r in runs for i in r) == list(range(len(times)))
        assert ms >= 0


class TestAllocateFrontend:
    @given(times=times_strategy, k=st.integers(1, 8))
    @settings(max_examples=100, deadline=None)
    def test_never_worse_than_contiguous(self, times, k):
        """allocate() may use LPT when it beats contiguity, never worse."""
        _, ms = allocate(times, k)
        _, ms_bs = allocate_contiguous_bs(times, k)
        assert ms <= ms_bs + 1e-12
