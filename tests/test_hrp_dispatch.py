"""HRP leases (isolation invariants) + two-level IDM controllers."""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ContextSwitchController, HRPError, InstructionRouter,
    MultiCoreSyncController, ResourcePool, SwitchMode,
)


class TestResourcePool:
    def test_disjoint_leases(self):
        pool = ResourcePool(16)
        a = pool.alloc("a", 8)
        b = pool.alloc("b", 8)
        assert not set(a.cores) & set(b.cores)
        pool.check_isolation()

    def test_oversubscription_rejected(self):
        pool = ResourcePool(16)
        pool.alloc("a", 12)
        with pytest.raises(HRPError):
            pool.alloc("b", 8)

    def test_double_alloc_rejected(self):
        pool = ResourcePool(16)
        pool.alloc("a", 2)
        with pytest.raises(HRPError):
            pool.alloc("a", 2)

    def test_resize_retains_cores(self):
        pool = ResourcePool(16)
        lease = pool.alloc("a", 8)
        kept = lease.cores[:4]
        smaller = pool.resize("a", 4)
        assert smaller.cores == kept          # minimal migration
        bigger = pool.resize("a", 6)
        assert set(kept) <= set(bigger.cores)

    def test_release_frees(self):
        pool = ResourcePool(16)
        pool.alloc("a", 16)
        pool.release("a")
        assert len(pool.free_cores()) == 16

    def test_port_budget_at_construction(self):
        with pytest.raises(HRPError):
            ResourcePool(16, cores_per_ddr=8, ddr_port_bits=512, core_port_bits=128)

    @given(ops=st.lists(
        st.tuples(st.sampled_from(["alloc", "resize", "release"]),
                  st.sampled_from(["a", "b", "c", "d"]),
                  st.integers(1, 8)),
        max_size=30,
    ))
    @settings(max_examples=100, deadline=None)
    def test_invariants_hold_under_any_sequence(self, ops):
        """Property: after ANY alloc/resize/release sequence the pool
        maintains disjointness and the DDR port budget."""
        pool = ResourcePool(16)
        for kind, tenant, n in ops:
            try:
                if kind == "alloc":
                    pool.alloc(tenant, n)
                elif kind == "resize":
                    pool.resize(tenant, n)
                else:
                    pool.release(tenant)
            except HRPError:
                pass
            pool.check_isolation()
            pool.check_bandwidth()
        total = sum(l.n_cores for l in pool.leases.values())
        assert total + len(pool.free_cores()) == 16


class TestSyncController:
    def test_barrier_fires_once_all_arrive(self):
        sync = MultiCoreSyncController()
        sync.configure("t", {0, 1, 2})
        assert not sync.sync_local("t", 0)
        assert not sync.sync_local("t", 1)
        assert sync.sync_local("t", 2)       # sync_global
        # barrier resets
        assert not sync.sync_local("t", 0)

    def test_foreign_core_rejected(self):
        sync = MultiCoreSyncController()
        sync.configure("t", {0, 1})
        with pytest.raises(KeyError):
            sync.sync_local("t", 5)


class TestContextSwitch:
    def test_layer_level_captures_at_any_boundary(self):
        ctx = ContextSwitchController()
        ctx.request_switch("t", SwitchMode.LAYER_LEVEL)
        c = ctx.boundary("t", layer_idx=17, n_layers=54, inference_id=3)
        assert c is not None and c.layer_idx == 17
        # request consumed
        assert ctx.boundary("t", 18, 54, 3) is None

    def test_task_level_waits_for_task_end(self):
        ctx = ContextSwitchController()
        ctx.request_switch("t", SwitchMode.TASK_LEVEL)
        assert ctx.boundary("t", 17, 54, 3) is None       # mid-task: no switch
        c = ctx.boundary("t", 54, 54, 3)
        assert c is not None and c.layer_idx == 0          # restart clean

    def test_load_pops_context(self):
        ctx = ContextSwitchController()
        ctx.request_switch("t", SwitchMode.LAYER_LEVEL)
        ctx.boundary("t", 5, 10, 0)
        assert ctx.load("t").layer_idx == 5
        assert ctx.load("t") is None


class TestRouter:
    def test_rejects_core_outside_lease(self):
        with pytest.raises(PermissionError):
            InstructionRouter.route([0, 1, 9], {0, 1, 2})

    def test_maps_local_to_physical(self):
        m = InstructionRouter.route([4, 7], {4, 7})
        assert m == {0: 4, 1: 7}
