"""Shared-prefix KV cache (PR 5): radix-tree unit behaviour, cached-prefix
admission token identity vs cold prefill (incl. COW divergence and EOS
mid-chunk), pool conservation with refcounted shares under churn,
lease-shrink eviction ordering, resume-on-OOM, deadlines, and the
hypervisor's shared-page billing."""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs import get_reduced
from repro.core import (
    Hypervisor, PolicyContext, ResourcePool, TenantSpec, TraceTraffic,
    VirtualEngine, fpga_small_core,
)
from repro.core.hrp import HRPError
from repro.core.hypervisor import kv_pages_proportional
from repro.models import init_params
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.kv_cache import PagedKVPool, PageQuotaError
from repro.serving.prefix_cache import PrefixCache

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def qwen_f32():
    """f32 variant of the reduced config: the page store's dtype cast is the
    only lossy step between cold and cached prefill, so at f32 the two are
    bit-identical — which is what the identity tests pin."""
    cfg = dataclasses.replace(get_reduced("qwen3-0.6b"), dtype="float32",
                              name="qwen3-0.6b-f32")
    return cfg, init_params(cfg, KEY)


def _batcher(params, cfg, **kw):
    kw.setdefault("slots", 4)
    kw.setdefault("prompt_len", 32)
    kw.setdefault("max_len", 64)
    kw.setdefault("chunk", 4)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 8)
    return ContinuousBatcher(params, cfg, **kw)


def _run(b, reqs, max_steps=4000):
    for r in reqs:
        b.submit(r)
    b.run(max_steps=max_steps)
    return b


def _shared_prompts(cfg, n, *, prefix_len=28, tail=4, seed=0):
    rng = np.random.default_rng(seed)
    head = rng.integers(1, cfg.vocab, size=prefix_len).astype(np.int32)
    return [np.concatenate([head, rng.integers(1, cfg.vocab, size=tail)
                            .astype(np.int32)]) for _ in range(n)]


def _assert_conservation(b):
    """free + privately-mapped + cache-shared partitions the pool; a page is
    multi-mapped only if the cache owns it; host ledger + tree consistent."""
    tab = np.asarray(b.pages.table)
    free = np.asarray(b.pages.free)[: int(b.pages.free_top)].tolist()
    mapped = tab[tab >= 0].tolist()
    shared = set(b.kv_pool._shared)
    counts = {}
    for pid in mapped:
        counts[pid] = counts.get(pid, 0) + 1
    for pid, c in counts.items():
        if c > 1:
            assert pid in shared, f"page {pid} multi-mapped but not shared"
        assert pid not in free, f"page {pid} both mapped and free"
    for pid in shared:
        assert pid not in free, f"shared page {pid} on the free stack"
    assert sorted(set(mapped) | set(free) | shared) == \
        list(range(b.n_pages)), "pool partition violated"
    assert b.kv_pool.used <= b._page_limit or b.kv_pool.shared > 0
    b.kv_pool.check()
    if b.prefix is not None:
        b.prefix.check()
        assert b.prefix.n_pages == b.kv_pool.shared


# ---------------------------------------------------------------------------
# radix tree unit behaviour
# ---------------------------------------------------------------------------

class TestPrefixCacheUnit:
    def test_lookup_insert_roundtrip(self):
        c = PrefixCache(4)
        toks = list(range(100, 112))                  # 3 full pages
        assert c.lookup("ns", toks) == []
        c.insert("ns", toks, [7, 8], start_page=0)
        path = c.lookup("ns", toks)
        assert [n.page_id for n in path] == [7, 8]
        # extending the path requires the lead to exist; the default lookup
        # cap keeps the last page private, so ask for all 3 explicitly
        c.insert("ns", toks, [9], start_page=2)
        assert [n.page_id for n in c.lookup("ns", toks, max_pages=3)] == \
            [7, 8, 9]
        c.check()

    def test_namespace_isolation(self):
        c = PrefixCache(4)
        toks = list(range(8))
        c.insert("a", toks, [1], start_page=0)
        assert c.lookup("b", toks) == []
        assert [n.page_id for n in c.lookup("a", toks)] == [1]

    def test_last_page_never_shareable(self):
        c = PrefixCache(8)
        assert c.max_shareable(32) == 3               # page 3 holds token 31
        assert c.max_shareable(33) == 4
        assert c.max_shareable(8) == 0                # single-page prompts
        toks = list(range(32))
        c.insert("ns", toks, [0, 1, 2], start_page=0)
        assert len(c.lookup("ns", toks)) == 3         # capped by max_shareable

    def test_divergent_tail_splits_path(self):
        c = PrefixCache(4)
        a = [1, 2, 3, 4, 5, 6, 7, 8, 9]
        b = [1, 2, 3, 4, 5, 6, 9, 9, 9]               # diverges mid-page 1
        c.insert("ns", a, [0, 1], start_page=0)
        hit = c.lookup("ns", b)
        assert [n.page_id for n in hit] == [0]        # shares page 0 only
        c.insert("ns", b, [2], start_page=1)
        assert [n.page_id for n in c.lookup("ns", b)] == [0, 2]
        assert [n.page_id for n in c.lookup("ns", a)] == [0, 1]
        c.check()

    def test_refcount_pins_against_eviction(self):
        c = PrefixCache(4)
        toks = list(range(12))
        c.insert("ns", toks, [0, 1, 2], start_page=0)
        path = c.lookup("ns", toks, max_pages=3)
        c.acquire(path)
        assert c.evict(3) == []                       # everything pinned
        c.release(path)
        freed = c.evict(3)
        # leaf-first: deepest page evicts first, parents become leaves
        assert freed == [2, 1, 0]
        assert c.n_pages == 0

    def test_lru_eviction_order(self):
        c = PrefixCache(4)
        old = [1] * 4
        new = [2] * 4
        c.insert("ns", old, [0], start_page=0)
        c.insert("ns", new, [1], start_page=0)
        c.lookup("ns", old, max_pages=1)              # refresh old
        assert c.evict(1) == [1]                      # new is now the LRU

    def test_interior_node_not_evicted_before_child(self):
        c = PrefixCache(4)
        toks = list(range(8))
        c.insert("ns", toks, [0, 1], start_page=0)
        child = c.lookup("ns", toks, max_pages=2)[1]
        c.acquire([child])
        assert c.evict(2) == []                       # parent is interior,
        c.release([child])                            # child is pinned


# ---------------------------------------------------------------------------
# cached-prefix admission == cold prefill
# ---------------------------------------------------------------------------

class TestCachedIdentity:
    def test_warm_wave_matches_cold(self, qwen_f32):
        """Two waves of shared-prefix requests through a prefix batcher emit
        the same streams as a prefix-cache-off paged batcher; the second
        wave actually hits."""
        cfg, params = qwen_f32
        prompts = _shared_prompts(cfg, 8, seed=1)

        def reqs():
            return [Request(rid=i, prompt=p, max_new=6 + i % 3, namespace="s")
                    for i, p in enumerate(prompts)]

        cold = reqs()
        _run(_batcher(params, cfg), cold)
        warm_b = _batcher(params, cfg, prefix_cache=True)
        warm = reqs()
        _run(warm_b, warm)
        for a, g in zip(cold, warm):
            assert a.done and g.done
            assert a.out == g.out, (a.rid, a.out, g.out)
        assert warm_b.stats.prefix_hits > 0
        assert warm_b.stats.prefill_tokens_skipped > 0
        _assert_conservation(warm_b)

    def test_cow_divergence_mid_page(self, qwen_f32):
        """Prompts sharing a prefix that diverges mid-page: the divergent
        page is never shared (COW) and streams match cold exactly."""
        cfg, params = qwen_f32
        rng = np.random.default_rng(3)
        head = rng.integers(1, cfg.vocab, size=20).astype(np.int32)  # 2.5 pg
        prompts = [np.concatenate([head, np.full((8,), 5 + i, np.int32)])
                   for i in range(6)]
        cold = [Request(rid=i, prompt=p, max_new=6, namespace="s")
                for i, p in enumerate(prompts)]
        _run(_batcher(params, cfg), cold)
        b = _batcher(params, cfg, prefix_cache=True)
        warm = [Request(rid=i, prompt=p, max_new=6, namespace="s")
                for i, p in enumerate(prompts)]
        _run(b, warm)
        for a, g in zip(cold, warm):
            assert a.out == g.out, (a.rid, a.out, g.out)
        # prompt_len 32, prompts of 28: the divergent tokens live in padded
        # positions 24..31 -> pages 0..2 shareable, page 3 private per req
        assert b.prefix.n_pages <= 3
        _assert_conservation(b)

    def test_identical_full_prompts_last_page_private(self, qwen_f32):
        """Fully identical prompts: everything shareable is shared, but the
        page holding the last prompt token stays private — the admission
        must still prefill >= 1 token to emit the continuation."""
        cfg, params = qwen_f32
        rng = np.random.default_rng(5)
        p = rng.integers(1, cfg.vocab, size=32).astype(np.int32)
        prompts = [p.copy() for _ in range(6)]
        cold = [Request(rid=i, prompt=q, max_new=5, namespace="s")
                for i, q in enumerate(prompts)]
        _run(_batcher(params, cfg), cold)
        b = _batcher(params, cfg, prefix_cache=True)
        warm = [Request(rid=i, prompt=q, max_new=5, namespace="s")
                for i, q in enumerate(prompts)]
        _run(b, warm)
        for a, g in zip(cold, warm):
            assert a.out == g.out
        assert b.prefix.n_pages <= b.prefix.max_shareable(32) == 3
        hit_caps = b.stats.prefix_tokens_saved
        assert hit_caps <= (len(prompts) - 1) * 3 * 8
        _assert_conservation(b)

    def test_eos_mid_chunk_with_hits(self, qwen_f32):
        """A cached-prefix request whose EOS lands mid-chunk finishes at the
        same token as cold, and its private pages return to the stack."""
        cfg, params = qwen_f32
        prompts = _shared_prompts(cfg, 6, seed=7)
        probe = [Request(rid=i, prompt=p, max_new=8, namespace="s")
                 for i, p in enumerate(prompts)]
        _run(_batcher(params, cfg), probe)
        eos_map = {2: probe[2].out[3]}
        cold = [Request(rid=i, prompt=p, max_new=8, eos=eos_map.get(i), namespace="s")
                for i, p in enumerate(prompts)]
        _run(_batcher(params, cfg), cold)
        b = _batcher(params, cfg, prefix_cache=True)
        warm = [Request(rid=i, prompt=p, max_new=8, eos=eos_map.get(i), namespace="s")
                for i, p in enumerate(prompts)]
        _run(b, warm)
        for a, g in zip(cold, warm):
            assert a.done and g.done
            assert a.out == g.out, (a.rid, a.out, g.out)
        assert warm[2].out[-1] == eos_map[2]
        assert len(warm[2].out) < 8
        _assert_conservation(b)

    def test_chunk_one_matches_chunk_eight(self, qwen_f32):
        cfg, params = qwen_f32
        prompts = _shared_prompts(cfg, 6, seed=11)
        one = [Request(rid=i, prompt=p, max_new=7, namespace="s")
               for i, p in enumerate(prompts)]
        _run(_batcher(params, cfg, chunk=1, prefix_cache=True), one)
        eight = [Request(rid=i, prompt=p, max_new=7, namespace="s")
                 for i, p in enumerate(prompts)]
        _run(_batcher(params, cfg, chunk=8, prefix_cache=True), eight)
        for a, g in zip(one, eight):
            assert a.out == g.out, (a.rid, a.out, g.out)

    def test_prefix_cache_requires_paged_and_attn(self, qwen_f32):
        cfg, params = qwen_f32
        with pytest.raises(ValueError):
            ContinuousBatcher(params, cfg, slots=2, prompt_len=8, max_len=32,
                              prefix_cache=True)     # paged=False

    def test_pallas_warm_wave_matches_xla_cold(self, qwen_f32):
        """attn_impl="pallas" end to end: cached admission runs the
        prefix-context kernel (repro.kernels.prefix_attention) and decode
        the paged kernel; streams must match a cache-off XLA batcher — the
        cached==cold contract must survive the kernel swap."""
        cfg, params = qwen_f32
        prompts = _shared_prompts(cfg, 8, seed=1)

        def reqs():
            return [Request(rid=i, prompt=p, max_new=6 + i % 3, namespace="s")
                    for i, p in enumerate(prompts)]

        cold = reqs()
        _run(_batcher(params, cfg), cold)            # XLA, no prefix cache
        warm_b = _batcher(params, cfg, prefix_cache=True, attn_impl="pallas")
        warm = reqs()
        _run(warm_b, warm)
        for a, g in zip(cold, warm):
            assert a.done and g.done
            assert a.out == g.out, (a.rid, a.out, g.out)
        assert warm_b.stats.prefix_hits > 0          # kernel path actually ran
        assert warm_b.stats.prefill_tokens_skipped > 0
        _assert_conservation(warm_b)


# ---------------------------------------------------------------------------
# conservation with refcounted shares under churn
# ---------------------------------------------------------------------------

class TestChurnConservation:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_random_admit_finish_evict_oom(self, qwen_f32, seed):
        """Property-style: random shared-prefix traffic over a small
        over-subscribed pool (reservations off -> OOM requeues, lease
        shrink mid-run -> cache evictions).  After every few steps and at
        the end: free + mapped + cached partitions the pool exactly."""
        cfg, params = qwen_f32
        rng = np.random.default_rng(100 + seed)
        heads = [rng.integers(1, cfg.vocab, size=24).astype(np.int32)
                 for _ in range(2)]
        prompts = [np.concatenate([heads[rng.integers(0, 2)],
                                   rng.integers(1, cfg.vocab, size=4)
                                   .astype(np.int32)])
                   for _ in range(12)]
        b = _batcher(params, cfg, n_pages=12, reserve_pages=False,
                     prefix_cache=True)
        reqs = [Request(rid=i, prompt=p, max_new=int(rng.integers(2, 9)), namespace="s")
                for i, p in enumerate(prompts)]
        for r in reqs:
            b.submit(r)
        step = 0
        while (b.queue or any(r is not None for r in b.slot_req)) \
                and b.stats.steps < 4000:
            b.step()
            step += 1
            if step == 6:
                b.set_page_limit(8)              # shrink mid-churn
            if step == 12:
                b.set_page_limit(12)
            if step % 3 == 0:
                _assert_conservation(b)
            if b._stalled >= 8:
                break
        assert all(r.done for r in reqs)
        _assert_conservation(b)

    def test_completion_releases_refcounts(self, qwen_f32):
        cfg, params = qwen_f32
        prompts = _shared_prompts(cfg, 6, seed=13)
        b = _batcher(params, cfg, prefix_cache=True)
        reqs = [Request(rid=i, prompt=p, max_new=5, namespace="s")
                for i, p in enumerate(prompts)]
        _run(b, reqs)
        # all requests done: every shared page must be unpinned
        assert b.kv_pool.pinned_shared() == 0
        assert all(n.refcount == 0 for n in b.prefix._leaves())
        _assert_conservation(b)


# ---------------------------------------------------------------------------
# ledger: shares in the count discipline
# ---------------------------------------------------------------------------

class TestLedgerShares:
    def test_share_acquire_release_drop(self):
        pool = PagedKVPool(10, 8)
        pool.alloc("r1", 4)
        pool.share("r1", "ns", [0, 1])
        assert pool.held_by("r1") == 2 and pool.shared == 2
        assert pool.used == 4                       # conservation: 2 + 2
        pool.acquire([0, 1])
        with pytest.raises(PageQuotaError):
            pool.drop_shared([0])                   # refcount pinned
        pool.release([0, 1])
        assert pool.drop_shared([0, 1]) == 2
        assert pool.used == 2
        pool.check()

    def test_share_more_than_held_rejected(self):
        pool = PagedKVPool(10, 8)
        pool.alloc("r1", 1)
        with pytest.raises(PageQuotaError):
            pool.share("r1", "ns", [0, 1])

    def test_double_share_rejected(self):
        pool = PagedKVPool(10, 8)
        pool.alloc("r1", 2)
        pool.share("r1", "ns", [3])
        with pytest.raises(PageQuotaError):
            pool.share("r1", "ns", [3])

    def test_release_without_users_rejected(self):
        pool = PagedKVPool(10, 8)
        pool.alloc("r1", 1)
        pool.share("r1", "ns", [0])
        with pytest.raises(PageQuotaError):
            pool.release([0])


# ---------------------------------------------------------------------------
# lease shrink evicts the cache before live requests fault
# ---------------------------------------------------------------------------

class TestLeaseShrink:
    def test_shrink_evicts_unpinned_cache_entries(self, qwen_f32):
        cfg, params = qwen_f32
        prompts = _shared_prompts(cfg, 4, seed=17)
        b = _batcher(params, cfg, n_pages=16, prefix_cache=True)
        warm = [Request(rid=i, prompt=p, max_new=3, namespace="s")
                for i, p in enumerate(prompts)]
        _run(b, warm)                               # cache is warm, unpinned
        assert b.kv_pool.shared > 0
        before = b.stats.prefix_evictions
        shared_before = b.kv_pool.shared
        b.set_page_limit(2)                         # below the shared set
        assert b.stats.prefix_evictions > before
        # evicted down TO the new lease, not necessarily to zero: the cache
        # keeps whatever still fits under the shrunk allocation estimate
        assert b.kv_pool.shared < shared_before
        assert b.stats.pages_in_use + b._admitted_pages_since_sync <= 2
        _assert_conservation(b)
        # and the lease still serves (slowly) after growing back
        b.set_page_limit(16)
        tail = [Request(rid=100 + i, prompt=p, max_new=3, namespace="s")
                for i, p in enumerate(prompts)]
        _run(b, tail)
        assert all(r.done for r in tail)
        _assert_conservation(b)


# ---------------------------------------------------------------------------
# resume-on-OOM keeps generated tokens
# ---------------------------------------------------------------------------

class TestResumeOnOOM:
    def test_requeue_resumes_from_prompt_plus_output(self, qwen_f32):
        """Over-subscribed pool: denied faults requeue, but requests whose
        prompt+output still fit the prompt bucket keep their tokens and
        re-prefill instead of restarting."""
        cfg, params = qwen_f32
        rng = np.random.default_rng(19)
        prompts = [rng.integers(1, cfg.vocab, size=12).astype(np.int32)
                   for _ in range(8)]
        b = _batcher(params, cfg, n_pages=16, reserve_pages=False)
        reqs = [Request(rid=i, prompt=p, max_new=10, namespace="s")
                for i, p in enumerate(prompts)]
        _run(b, reqs, max_steps=8000)
        assert all(r.done for r in reqs)
        assert b.stats.oom_requeues > 0, "pool never oversubscribed"
        assert b.stats.oom_resumed > 0, "no requeue resumed"
        assert b.stats.resumed_tokens_kept > 0
        # resumed requests still delivered their full budget
        for r in reqs:
            assert len(r.out) == r.max_new or (
                r.eos is not None and r.out[-1] == r.eos)

    def test_resume_coexists_with_prefix_cache(self, qwen_f32):
        """OOM requeues under a prefix-cache batcher keep every invariant
        (the resumed row's shifted padding means it does not re-hit the
        original prompt's entries — sharing still works for fresh
        admissions around the churn)."""
        cfg, params = qwen_f32
        prompts = _shared_prompts(cfg, 8, prefix_len=20, tail=2, seed=23)
        b = _batcher(params, cfg, n_pages=14, reserve_pages=False,
                     prefix_cache=True)
        reqs = [Request(rid=i, prompt=p, max_new=8, namespace="s")
                for i, p in enumerate(prompts)]
        _run(b, reqs, max_steps=8000)
        assert all(r.done for r in reqs)
        assert b.stats.prefix_hits > 0
        _assert_conservation(b)


# ---------------------------------------------------------------------------
# deadlines: shed before start
# ---------------------------------------------------------------------------

class TestDeadlines:
    def test_batcher_sheds_expired_requests(self, qwen_f32):
        cfg, params = qwen_f32
        now = [0.0]
        b = _batcher(params, cfg, clock=lambda: now[0])
        rng = np.random.default_rng(29)
        live = Request(rid=0, prompt=rng.integers(1, cfg.vocab, size=4)
                       .astype(np.int32), max_new=3, deadline=10.0)
        dead = Request(rid=1, prompt=rng.integers(1, cfg.vocab, size=4)
                       .astype(np.int32), max_new=3, deadline=1.0)
        b.submit(live)
        b.submit(dead)
        now[0] = 5.0                                # past dead's deadline
        b.run(max_steps=500)
        assert live.done and not live.dropped and len(live.out) == 3
        assert dead.done and dead.dropped and dead.out == []
        assert b.stats.deadline_drops == 1

    def test_vengine_drop_policy(self, resnet_artifact):
        """Open-loop requests whose deadline passes while they queue are
        shed before start, counted in TenantMetrics.dropped."""
        pool = ResourcePool(16)
        eng = VirtualEngine(pool, fpga_small_core())
        hv = Hypervisor(pool, policy="even_split", executor=eng)
        hv.schedule_arrival(
            TenantSpec("t", 2, artifact=resnet_artifact, open_loop=True),
            at=0.0)
        # a burst far faster than service: the tail waits past its deadline
        recs = hv.open_traffic("t", TraceTraffic([0.01 * i for i in range(20)]),
                               1.0, slo=1.0, deadline_after=0.05)
        metrics = hv.run(60.0)
        lat = eng.single_inference_latency("t")
        assert lat > 0.05                           # queueing was inevitable
        m = metrics["t"]
        assert m.dropped > 0
        assert all(r.dropped == (r.t_complete is None) for r in recs
                   if r.t_start is not None or r.dropped)
        # dropped records count against attainment but are stamped dropped
        served = [r for r in recs if r.t_complete is not None]
        assert len(served) + m.dropped <= len(recs)

    def test_slo_report_counts_drops(self):
        from repro.core.events import RequestRecord
        from repro.serving.tenancy import (
            ServingExecutor, VirtualAcceleratorPool,
        )
        vpool = VirtualAcceleratorPool(devices=jax.devices() * 4,
                                       devices_per_core=1)
        ex = ServingExecutor(vpool)
        ex.record_latency("t", 0.2, slo=0.5)
        ex.note_drop(RequestRecord(tenant="t", rid=1, t_arrival=0.0,
                                   deadline=0.1))
        rep = ex.slo_report()["t"]
        assert rep["requests"] == 2
        assert rep["slo_met"] == 1
        assert rep["dropped"] == 1

    def test_executor_sheds_expired_requests_before_the_sink(self):
        """The serving executor's drop policy is wired in, not just
        note_drop: an expired record never reaches the tenant's sink."""
        from repro.core.events import RequestRecord
        from repro.serving.tenancy import (
            ServingExecutor, VirtualAcceleratorPool,
        )
        vpool = VirtualAcceleratorPool(devices=jax.devices() * 4,
                                       devices_per_core=1)
        ex = ServingExecutor(vpool)
        delivered = []
        ex.register_request_sink("t", delivered.append)
        live = RequestRecord(tenant="t", rid=0, t_arrival=0.0, deadline=9.0)
        dead = RequestRecord(tenant="t", rid=1, t_arrival=0.0, deadline=1.0)
        ex.exec_request("t", live, at=5.0)
        ex.exec_request("t", dead, at=5.0)
        assert delivered == [live]
        assert dead.dropped
        assert ex.slo_report()["t"]["dropped"] == 1


# ---------------------------------------------------------------------------
# hypervisor: shared pages billed once to the owning namespace
# ---------------------------------------------------------------------------

class TestSharedKvAccounting:
    def test_note_shared_kv_requires_core_lease(self):
        pool = ResourcePool(4, n_kv_pages=8)
        with pytest.raises(HRPError):
            pool.note_shared_kv("ghost", 2)
        pool.alloc("t", 2)
        pool.note_shared_kv("t", 3)
        assert pool.shared_kv == {"t": 3}
        pool.check_kv_quota()
        pool.note_shared_kv("t", 0)
        assert pool.shared_kv == {}
        pool.release("t")

    def test_release_clears_shared_kv(self):
        pool = ResourcePool(4, n_kv_pages=8)
        pool.alloc("t", 2)
        pool.note_shared_kv("t", 3)
        pool.release("t")
        assert pool.shared_kv == {}
        pool.check_kv_quota()

    def test_shared_exceeding_pool_rejected(self):
        # a single note beyond the pool fails at the write site...
        pool = ResourcePool(4, n_kv_pages=4)
        pool.alloc("t", 2)
        with pytest.raises(HRPError):
            pool.note_shared_kv("t", 5)
        # ...and a sum over the pool fails the per-event invariant sweep
        pool.alloc("u", 2)
        pool.note_shared_kv("t", 3)
        pool.note_shared_kv("u", 3)
        with pytest.raises(HRPError):
            pool.check_kv_quota()

    def test_proportional_split_floors_at_shared_set(self):
        """A tenant's pinned shared pages raise its floor in the default
        split: memory follows compute, but never below the cache a shrink
        would have to tear down."""
        a = TenantSpec("a", 2, requested_kv_pages=12, min_kv_pages=2,
                       arrived_at=0.0)
        b = TenantSpec("b", 2, requested_kv_pages=12, min_kv_pages=2,
                       arrived_at=1.0)
        ctx = PolicyContext(4, [a, b], {"a": 2, "b": 2}, 0.0, n_kv_pages=16,
                            current_kv={"a": 8, "b": 8},
                            shared_kv_pages={"a": 7})
        alloc = kv_pages_proportional(ctx, {"a": 2, "b": 2})
        assert alloc["a"] >= 7                      # the shared set held
        assert alloc["a"] + alloc["b"] <= 16
        # without the shared set the split is even
        ctx0 = dataclasses.replace(ctx, shared_kv_pages={})
        alloc0 = kv_pages_proportional(ctx0, {"a": 2, "b": 2})
        assert alloc0["a"] == alloc0["b"]

    def test_shared_kv_flows_into_policy_context(self):
        pool = ResourcePool(4, n_kv_pages=16)
        seen = {}

        def spy(ctx: PolicyContext):
            seen.update(ctx.shared_kv_pages)
            from repro.core.hypervisor import even_split
            return even_split(ctx)

        hv = Hypervisor(pool, policy=spy)
        assert hv.admit(TenantSpec("t", 2, requested_kv_pages=8,
                                   min_kv_pages=1))
        pool.note_shared_kv("t", 5)
        assert hv.admit(TenantSpec("u", 2, requested_kv_pages=8,
                                   min_kv_pages=1))
        assert seen.get("t") == 5
