"""Virtualized engine: isolation, reconfiguration, straggler mitigation —
the end-to-end behaviour the paper's Figures 5 and 7 measure."""

import pytest

from repro.core import ResourcePool, SwitchMode, VirtualEngine, fpga_small_core


HORIZON = 1.0


def make_engine(**kw):
    return VirtualEngine(ResourcePool(16), fpga_small_core(), **kw)


class TestIsolation:
    def test_cotenant_arrival_leaves_throughput_alone(self, resnet_artifact):
        """Paper Fig 5: <1% deviation for a fixed tenant when co-tenants
        occupy the remaining cores in any mix."""
        fps = []
        for others in ([], [8], [4, 4], [2, 3, 3]):
            eng = make_engine()
            eng.admit("fixed", resnet_artifact, 8)
            for i, n in enumerate(others):
                eng.admit(f"bg{i}", resnet_artifact, n)
            m = eng.run(HORIZON)
            fps.append(m["fixed"].throughput(HORIZON))
        dev = (max(fps) - min(fps)) / max(fps)
        assert dev < 0.01

    def test_lease_isolation_enforced(self, resnet_artifact):
        eng = make_engine()
        eng.admit("a", resnet_artifact, 10)
        with pytest.raises(Exception):
            eng.admit("b", resnet_artifact, 10)   # only 6 free


class TestReconfiguration:
    def test_resize_applies_and_charges_context_cost(self, resnet_artifact):
        eng = make_engine()
        eng.admit("t", resnet_artifact, 4)
        eng.request_resize("t", 12, at=0.2)
        m = eng.run(HORIZON)["t"]
        assert m.ctx_switches == 1
        assert 0 < m.ctx_overhead < 0.05          # ~ms, not ~100 s
        assert eng.pool.lease_of("t").n_cores == 12

    def test_grow_improves_throughput(self, resnet_artifact):
        eng_static = make_engine()
        eng_static.admit("t", resnet_artifact, 2)
        base = eng_static.run(HORIZON)["t"].throughput(HORIZON)

        eng = make_engine()
        eng.admit("t", resnet_artifact, 2)
        eng.request_resize("t", 16, at=0.05)
        grown = eng.run(HORIZON)["t"].throughput(HORIZON)
        assert grown > base * 1.5

    def test_layer_level_switch_preserves_progress(self, resnet_artifact):
        """Context = (task, layer) only; after the switch the tenant resumes
        from the recorded layer instead of restarting the inference.
        (Generous horizon: ctx_overhead is wall-clock and can absorb a GC
        pause under full-suite load — simulated seconds are cheap.)"""
        eng = make_engine()
        eng.admit("t", resnet_artifact, 4)
        eng.request_resize("t", 8, at=1e-4, mode=SwitchMode.LAYER_LEVEL)
        m = eng.run(5.0, max_inferences=4)["t"]
        assert m.ctx_switches == 1
        assert len(m.completions) >= 1
        assert eng.pool.lease_of("t").n_cores == 8

    def test_shrink_then_release_frees_pool(self, resnet_artifact):
        eng = make_engine()
        eng.admit("t", resnet_artifact, 16)
        eng.request_resize("t", 4, at=0.01)
        eng.run(0.2)
        assert len(eng.pool.free_cores()) == 12
        eng.remove("t")
        assert len(eng.pool.free_cores()) == 16


class TestStragglers:
    def test_mitigation_recovers_throughput(self, resnet_artifact):
        slow = 3.0
        eng_bad = make_engine()
        eng_bad.admit("t", resnet_artifact, 8)
        eng_bad.core_slowdown[0] = slow
        hit = eng_bad.run(HORIZON)["t"].throughput(HORIZON)

        eng_fix = make_engine(mitigate_stragglers=True, straggler_threshold=1.3)
        eng_fix.admit("t", resnet_artifact, 8)
        eng_fix.core_slowdown[0] = slow
        m = eng_fix.run(HORIZON)["t"]
        fixed = m.throughput(HORIZON)
        assert m.rebalances >= 1
        assert fixed > hit * 1.2

    def test_healthy_run_never_rebalances(self, resnet_artifact):
        eng = make_engine(mitigate_stragglers=True)
        eng.admit("t", resnet_artifact, 8)
        m = eng.run(0.5)["t"]
        assert m.rebalances == 0
