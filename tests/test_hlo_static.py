"""HLO static analyzer: trip-count-aware FLOPs/bytes/collectives.

This module IS the roofline's measurement instrument, so it gets its own
correctness tests against compiled programs with analytically-known costs."""

import jax
import jax.numpy as jnp
import pytest

from repro.distributed.hlo_static import HloModule, analyze_hlo


def compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestFlops:
    def test_plain_matmul(self):
        a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
        c = analyze_hlo(compiled_text(lambda x, y: x @ y, a, b))
        assert c.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)

    def test_scan_multiplies_by_trip_count(self):
        """THE bug this analyzer exists to fix: cost_analysis sees a scanned
        body once; we must see it trip_count times."""
        w = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

        def f(w, x):
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            y, _ = jax.lax.scan(body, x, w)
            return y

        c = analyze_hlo(compiled_text(f, w, x))
        expect = 7 * 2 * 8 * 64 * 64
        assert c.flops == pytest.approx(expect, rel=0.02)

    def test_nested_scan(self):
        w = jax.ShapeDtypeStruct((3, 5, 32, 32), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 32), jnp.float32)

        def f(w, x):
            def outer(c, wo):
                def inner(ci, wi):
                    return ci @ wi, None
                c2, _ = jax.lax.scan(inner, c, wo)
                return c2, None
            y, _ = jax.lax.scan(outer, x, w)
            return y

        c = analyze_hlo(compiled_text(f, w, x))
        expect = 3 * 5 * 2 * 4 * 32 * 32
        assert c.flops == pytest.approx(expect, rel=0.02)

    def test_grad_flops_about_3x_forward(self):
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 32), jnp.float32)

        def loss(w, x):
            return jnp.sum((x @ w) ** 2)

        fwd = analyze_hlo(compiled_text(loss, w, a))
        bwd = analyze_hlo(compiled_text(jax.grad(loss), w, a))
        assert bwd.flops >= 1.8 * fwd.flops   # dL/dw adds x^T @ g


class TestBytes:
    def test_dynamic_slice_counts_slice_not_operand(self):
        """A scan's dynamic-slice of stacked params must charge the slice,
        not the whole stack, per iteration."""
        w = jax.ShapeDtypeStruct((100, 64, 64), jnp.float32)   # 1.6 MB stack
        x = jax.ShapeDtypeStruct((1, 64), jnp.float32)

        def f(w, x):
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            y, _ = jax.lax.scan(body, x, w)
            return y

        c = analyze_hlo(compiled_text(f, w, x))
        stack_bytes = 100 * 64 * 64 * 4
        # total traffic should be ~O(stack) (each slice read ~once), NOT
        # O(100 * stack)
        assert c.bytes < 20 * stack_bytes

    def test_elementwise_bytes_scale_with_size(self):
        a = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)
        c = analyze_hlo(compiled_text(lambda x: x * 2 + 1, a))
        nb = (1 << 20) * 4
        assert nb <= c.bytes <= 6 * nb


class TestCollectives:
    def test_psum_wire_bytes(self):
        """shard_map psum over 4 devices: all-reduce of the full array."""
        if len(jax.devices()) < 2:
            # single-device CPU: GSPMD elides the collective; assert that
            c = analyze_hlo(compiled_text(lambda x: x + 1, jax.ShapeDtypeStruct((8,), jnp.float32)))
            assert c.collective_bytes == 0
            return

    def test_collective_parse_from_text(self):
        """Parse a hand-written module with known collectives."""
        txt = """
HloModule test, entry_computation_layout={(f32[256]{0})->f32[256]{0}}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p: f32[256]) -> f32[256] {
  %p = f32[256]{0} parameter(0)
  %ar = f32[256]{0} all-reduce(%p), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  ROOT %cp = f32[256]{0} collective-permute(%ar), channel_id=2, source_target_pairs={{0,1},{1,0}}
}
"""
        c = analyze_hlo(txt)
        assert c.collective_count == {"all-reduce": 1, "collective-permute": 1}
        nb = 256 * 4
        # all-reduce ring: 2*nb*(4-1)/4; permute: nb
        assert c.collective_bytes == pytest.approx(2 * nb * 3 / 4 + nb)
        assert c.raw_collective_bytes == pytest.approx(nb + nb)

    def test_while_scales_collectives(self):
        txt = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (t: (s32[], f32[128])) -> (s32[], f32[128]) {
  %t = (s32[], f32[128]{0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[128]{0} get-tuple-element(%t), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %ar = f32[128]{0} all-reduce(%x), channel_id=1, replica_groups=[1,2]<=[2], to_apply=%add
  ROOT %out = (s32[], f32[128]{0}) tuple(%i2, %ar)
}

%cond (t: (s32[], f32[128])) -> pred[] {
  %t = (s32[], f32[128]{0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %n = s32[] constant(6)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p: f32[128]) -> f32[128] {
  %p = f32[128]{0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128]{0}) tuple(%zero, %p)
  %w = (s32[], f32[128]{0}) while(%init), condition=%cond, body=%body
  ROOT %r = f32[128]{0} get-tuple-element(%w), index=1
}
"""
        c = analyze_hlo(txt)
        assert c.collective_count == {"all-reduce": 6}   # trip count from cond
        assert c.collective_bytes == pytest.approx(6 * 2 * 128 * 4 * (1 / 2))


class TestParsing:
    def test_tuple_types_with_index_comments(self):
        line = ("  %while.217 = (s32[], bf16[4,256,1024]{2,1,0}, "
                "/*index=5*/pred[1,4,256]{2,1,0}) while(%tuple.170), "
                "condition=%c, body=%b")
        from repro.distributed.hlo_static import _parse_instr_line

        parsed = _parse_instr_line(line)
        assert parsed is not None
        name, rtype, opcode, rest = parsed
        assert opcode == "while"
        assert "pred" in rtype
