"""Quickstart: the paper's full pipeline in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

Walks the exact flow of Figure 2: a CNN workload is statically compiled into
tiling-based instruction frame packages + a latency LUT (offline, seconds),
then tenants lease cores from the pool and the dynamic compiler re-allocates
IFPs in ~1 ms whenever the lease changes.
"""

import sys

sys.path.insert(0, "src")

from repro.core import (
    CNN_WORKLOADS, DynamicCompiler, ResourcePool, StaticCompiler,
    VirtualEngine, fpga_small_core, workload_stats,
)


def main() -> None:
    hw = fpga_small_core()
    workload = CNN_WORKLOADS["resnet50"]()
    print("workload:", workload_stats(workload))

    # ---- offline: static compilation (paper §5.2.1) -----------------------
    artifact = StaticCompiler(hw, n_tiles=16).compile(workload)
    n_ifps = sum(len(l.ifps) for l in artifact.luts.values())
    print(f"static compile: {artifact.compile_seconds*1e3:.0f} ms, "
          f"{n_ifps} cached IFPs (both tiling strategies)")

    # ---- online: dynamic re-compilation (paper §5.2.2) --------------------
    dyn = DynamicCompiler(artifact)
    for k in (1, 4, 16):
        sch = dyn.compile(list(range(k)))
        fps = 1.0 / sch.estimated_latency(hw)
        print(f"  {k:2d} cores -> recompiled in {sch.compile_seconds*1e3:.2f} ms, "
              f"{fps:6.1f} fps "
              f"(strategies: { {p.strategy.value for p in sch.plans} })")

    # ---- multi-tenant virtualization (paper §4) ----------------------------
    pool = ResourcePool(n_cores=16)
    eng = VirtualEngine(pool, hw)
    eng.admit("alice", artifact, 8)
    eng.admit("bob", artifact, 8)
    # bob's workload spikes: the hypervisor grows his lease at t=0.5 s;
    # alice must be unaffected (performance isolation)
    eng.remove("alice")
    eng.admit("alice", artifact, 4)
    eng.request_resize("bob", 12, at=0.5)
    metrics = eng.run(horizon=1.0)
    for name, m in metrics.items():
        print(f"  {name}: {m.throughput(1.0):6.1f} fps, "
              f"ctx switches {m.ctx_switches} "
              f"(overhead {m.ctx_overhead*1e3:.2f} ms)")


if __name__ == "__main__":
    main()
