"""Paged KV pool: block-granular cache virtualization end to end.

    PYTHONPATH=src python examples/paged_serving.py

The dense serving path provisions every slot a max_len-sized KV ring; the
paged path (``ContinuousBatcher(paged=True)``) replaces those rings with
one pre-allocated pool of fixed-size pages — the cache analogue of the
paper's instruction-frame tile — plus per-slot page tables.  Requests
reserve only their actual footprint (bucketed prompt + decode budget), so
the same HBM hosts more concurrent requests; page faults during decode are
served from a device-resident free list *inside* the chunked scan (still
one dispatch + one host sync per chunk).

The hypervisor treats the page pool as a second lease dimension: tenants
ask for ``requested_kv_pages`` alongside cores, the default
``kv_pages_proportional`` split makes memory follow compute, and lease
changes reach the live batcher through ``ServingExecutor.exec_kv_resize``
-> ``ContinuousBatcher.set_page_limit`` — quota invariants re-checked after
every event (``ResourcePool.check_kv_quota``).
"""

import sys

sys.path.insert(0, "src")

import numpy as np
import jax

from repro.configs import get_reduced
from repro.core import TenantSpec
from repro.models import init_params
from repro.serving import ServingConfig
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.kv_cache import pages_for
from repro.serving.tenancy import VirtualAcceleratorPool, make_serving_hypervisor

PROMPT_LEN, MAX_NEW, MAX_LEN, PAGE_SIZE = 8, 16, 64, 8


def requests(cfg, n, rng):
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        size=2 + i % 6).astype(np.int32),
                    max_new=MAX_NEW)
            for i in range(n)]


def main() -> None:
    cfg = get_reduced("qwen3-0.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # one pool: 4 cores of compute and 32 KV pages of cache memory
    pool = VirtualAcceleratorPool(devices=list(jax.devices()) * 4,
                                  devices_per_core=1, kv_pages=32)
    hv, ex = make_serving_hypervisor(pool, policy="even_split")

    # alice admits alone: she gets all cores and (memory follows compute)
    # the whole page budget
    assert hv.admit(TenantSpec("alice", 4, requested_kv_pages=32,
                               min_kv_pages=4))
    alice = ContinuousBatcher(
        params, cfg,
        ServingConfig(slots=8, prompt_len=PROMPT_LEN, max_len=MAX_LEN,
                      chunk=8, paged=True, page_size=PAGE_SIZE,
                      n_pages=hv.kv_allocation()["alice"]))
    ex.register_kv_limit("alice", alice.set_page_limit)
    per_req = pages_for(PROMPT_LEN + MAX_NEW, PAGE_SIZE)
    print(f"alice: {hv.kv_allocation()['alice']} pages "
          f"({per_req}/request) -> "
          f"{hv.kv_allocation()['alice'] // per_req} concurrent requests; "
          f"dense rings would cap at "
          f"{hv.kv_allocation()['alice'] // pages_for(MAX_LEN, PAGE_SIZE)}")

    for r in requests(cfg, 6, rng):
        alice.submit(r)
    alice.run(max_steps=2000)
    print(f"alice alone: {alice.stats.completed} done, "
          f"peak {alice.stats.peak_pages_in_use} pages, "
          f"peak residency {alice.stats.peak_resident} slots")

    # bob arrives: the hypervisor re-splits cores AND pages; alice's live
    # batcher is throttled through her registered page-limit callback
    assert hv.admit(TenantSpec("bob", 2, requested_kv_pages=16,
                               min_kv_pages=4))
    kv = hv.kv_allocation()
    print(f"bob admitted: cores {hv.allocation()}, kv pages {kv} "
          f"(alice's live limit is now {alice._page_limit})")
    assert alice._page_limit == kv["alice"]

    for r in requests(cfg, 8, rng):
        alice.submit(r)
    alice.run(max_steps=4000)
    print(f"alice throttled: {alice.stats.completed} done, "
          f"{alice.stats.pages_in_use} pages in use after the run "
          f"(lease {kv['alice']}), oom requeues "
          f"{alice.stats.oom_requeues}")
    assert alice.stats.peak_pages_in_use <= 32

    # bob departs: pages flow back; the executor pushes the bigger cap
    hv.depart("bob")
    print(f"bob departed: kv pages {hv.kv_allocation()}, "
          f"alice's limit {alice._page_limit}")
    pool.pool.check_kv_quota()
    print("kv quota invariants OK after every event")


if __name__ == "__main__":
    main()
