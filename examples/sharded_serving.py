"""Tensor-sharded serving on an elastic multi-device pool.

    PYTHONPATH=src python examples/sharded_serving.py

``ServingConfig(tp=N)`` shards the decode fast path over a flat ``("tp",)``
device mesh: attention heads and MLP features split across the tenant's
leased devices, KV caches sharded over heads, slot bookkeeping replicated,
two psums per layer — still one dispatch and one host sync per chunk.

The hypervisor side makes the width *elastic*: a ``VirtualAcceleratorPool``
lease maps to a concrete device set (``tp_mesh_for``), and a live batcher
registered via ``ServingExecutor.register_remesh`` migrates onto the new
mesh whenever policy resizes the lease — donated caches snapshot through
``live_state``/``adopt_state``, params re-permute from a kept host copy,
and the token streams are identical across the move.

Runs anywhere: the script forces 8 emulated host devices before jax
initializes (the same way the tests and ``bench_sharded`` run on CPU CI).
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")

import dataclasses

import numpy as np
import jax

from repro.configs import get_reduced
from repro.core import TenantSpec
from repro.models import init_params
from repro.serving import ServingConfig
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.tenancy import ServingExecutor, VirtualAcceleratorPool

PROMPT_LEN, MAX_NEW = 8, 24


def requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        size=2 + i % 6).astype(np.int32),
                    max_new=MAX_NEW)
            for i in range(n)]


def serving_config(tp):
    return ServingConfig(slots=4, prompt_len=PROMPT_LEN,
                         max_len=PROMPT_LEN + MAX_NEW + 2, chunk=8, tp=tp)


def main() -> None:
    # f32 so single- and multi-device streams are bit-identical; the
    # reduced config's 2 KV heads shard over tp=2
    cfg = dataclasses.replace(get_reduced("qwen3-0.6b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"devices: {jax.device_count()} "
          f"({jax.devices()[0].platform} x {len(jax.devices())})")

    # -- a tensor-sharded batcher is a drop-in: same tokens, same API ----
    ref = ContinuousBatcher(params, cfg, serving_config(tp=1))
    for r in (ref_reqs := requests(cfg, 8)):
        ref.submit(r)
    ref.run(max_steps=500)

    wide = ContinuousBatcher(params, cfg, serving_config(tp=2))
    for r in (wide_reqs := requests(cfg, 8)):
        wide.submit(r)
    wide.run(max_steps=500)
    assert [r.out for r in wide_reqs] == [r.out for r in ref_reqs]
    print(f"tp=2 == tp=1: {sum(len(r.out) for r in wide_reqs)} tokens "
          f"identical, {wide.stats.dispatches} dispatches "
          f"(same as tp=1: {ref.stats.dispatches})")

    # -- elastic width: the hypervisor resizes, the batcher re-meshes ----
    vpool = VirtualAcceleratorPool(devices=jax.devices(), devices_per_core=1)
    ex = ServingExecutor(vpool)
    ex.exec_admit(TenantSpec("tenant", requested_cores=1, artifact=None),
                  1, at=0.0)
    b = ContinuousBatcher(params, cfg, serving_config(tp=1),
                          mesh=vpool.tp_mesh_for(vpool.pool.lease_of("tenant")))
    ex.register_remesh("tenant", lambda mesh: b.remesh(mesh=mesh))
    for r in (reqs := requests(cfg, 8)):
        b.submit(r)

    b.step(); b.step()                      # decode begins on 1 device
    ex.exec_resize("tenant", 2, at=1.0, mode=None)   # grow: 2-device mesh
    print(f"resized to 2 cores mid-stream "
          f"(t_remesh={ex.reconfig_log[-1]['t_remesh']*1e3:.0f} ms)")
    b.step(); b.step()
    ex.exec_resize("tenant", 1, at=2.0, mode=None)   # shrink back
    b.run(max_steps=500)

    assert [r.out for r in reqs] == [r.out for r in ref_reqs]
    print(f"token streams identical across 1 -> 2 -> 1 re-mesh "
          f"({b.stats.remeshes} live migrations)")


if __name__ == "__main__":
    main()
