"""Elastic reconfiguration under a dynamic workload (paper Fig. 1c/1d + §6.3.3).

    PYTHONPATH=src python examples/elastic_reconfig.py

Simulates a private-cloud day as ONE continuous event-driven run: tenants
arrive and leave on a global timeline, and the hypervisor's ``even_split``
policy re-balances core leases through the ~ms dynamic compiler at every
event — no per-phase engine rebuilds.  Prints the allocation after every
event and per-phase throughput, contrasting with the two static baselines
(single big core TDM / fixed 16 small cores).
"""

import sys

sys.path.insert(0, "src")

from repro.core import (
    CNN_WORKLOADS, DynamicCompiler, Hypervisor, ResourcePool, StaticCompiler,
    TenantSpec, VirtualEngine, fpga_core, fpga_small_core,
)

#: (time, "arrive"/"depart", tenant) — one simulated day, compressed to 4 s
TIMELINE = [
    (0.0, "arrive", "svc-a"),   # night: one tenant, whole pool
    (1.0, "arrive", "svc-b"),   # morning: second tenant joins -> 8/8
    (2.0, "arrive", "svc-c"),   # peak: four tenants -> 4/4/4/4
    (2.0, "arrive", "svc-d"),
    (3.0, "depart", "svc-c"),   # evening: back to two -> 8/8
    (3.0, "depart", "svc-d"),
]
PHASES = [
    ("night: 1 tenant, whole pool", 0.0, 1.0),
    ("morning: second tenant joins", 1.0, 2.0),
    ("peak: four tenants", 2.0, 3.0),
    ("evening: back to two", 3.0, 4.0),
]
HORIZON = 4.0


def main() -> None:
    hw = fpga_small_core()
    art = StaticCompiler(hw, n_tiles=16).compile(CNN_WORKLOADS["resnet50"]())

    # static baselines
    big = fpga_core(8192, 4 * 512)
    art_big = StaticCompiler(big, n_tiles=1).compile(CNN_WORKLOADS["resnet50"]())
    tdm_total = 1.0 / DynamicCompiler(art_big).compile([0]).estimated_latency(big)
    small1 = 1.0 / DynamicCompiler(art).compile([0]).estimated_latency(hw)

    pool = ResourcePool(16)
    eng = VirtualEngine(pool, hw)
    events = []
    hv = Hypervisor(pool, policy="even_split", executor=eng,
                    on_event=lambda h, ev: events.append((ev, h.allocation())))
    for t, kind, name in TIMELINE:
        if kind == "arrive":
            hv.schedule_arrival(TenantSpec(name, requested_cores=16, artifact=art), at=t)
        else:
            hv.schedule_departure(name, at=t)
    metrics = hv.run(HORIZON)

    print("event log (policy: even_split):")
    for ev, alloc in events:
        shares = ", ".join(f"{k}:{v}" for k, v in sorted(alloc.items()))
        print(f"  t={ev.time:4.1f}s  {ev.kind.value:9s} {ev.tenant:6s} -> {shares}")

    print(f"\n{'phase':34s} {'virtualized':>12s} {'static-multi':>13s} {'static-1core':>13s}")
    for desc, t0, t1 in PHASES:
        width = t1 - t0
        virt = sum(
            sum(1 for c in m.completions if t0 < c <= t1) / width
            for m in metrics.values()
        )
        n_tenants = sum(1 for t, kind, _ in TIMELINE if t <= t0 and kind == "arrive") - \
            sum(1 for t, kind, _ in TIMELINE if t <= t0 and kind == "depart")
        static_multi = n_tenants * small1          # 1 fixed core per tenant
        print(f"{desc:34s} {virt:9.1f} fps {static_multi:10.1f} fps "
              f"{tdm_total:10.1f} fps")

    total_ctx_ms = sum(m.ctx_overhead for m in metrics.values()) * 1e3
    switches = sum(m.ctx_switches for m in metrics.values())
    print(f"\n{switches} policy-driven context switches, "
          f"total reconfiguration overhead: {total_ctx_ms:.2f} ms "
          f"(vs ~100 s per reconfiguration for bitstream/instruction regeneration)")


if __name__ == "__main__":
    main()
