"""Elastic reconfiguration under a dynamic workload (paper Fig. 1c/1d + §6.3.3).

    PYTHONPATH=src python examples/elastic_reconfig.py

Simulates a private-cloud day: tenants arrive and leave; on every change the
hypervisor re-balances core leases through the ~ms dynamic compiler.  Prints
the running allocation and per-phase throughput, contrasting with the two
static baselines (single big core TDM / fixed 16 small cores).
"""

import sys

sys.path.insert(0, "src")

from repro.core import (
    CNN_WORKLOADS, DynamicCompiler, ResourcePool, StaticCompiler,
    VirtualEngine, fpga_core, fpga_small_core,
)

PHASES = [
    # (description, {tenant: cores})
    ("night: 1 tenant, whole pool", {"svc-a": 16}),
    ("morning: second tenant joins", {"svc-a": 8, "svc-b": 8}),
    ("peak: four tenants", {"svc-a": 4, "svc-b": 4, "svc-c": 4, "svc-d": 4}),
    ("evening: back to two", {"svc-a": 12, "svc-b": 4}),
]


def main() -> None:
    hw = fpga_small_core()
    art = StaticCompiler(hw, n_tiles=16).compile(CNN_WORKLOADS["resnet50"]())

    # static baselines
    big = fpga_core(8192, 4 * 512)
    art_big = StaticCompiler(big, n_tiles=1).compile(CNN_WORKLOADS["resnet50"]())
    tdm_total = 1.0 / DynamicCompiler(art_big).compile([0]).estimated_latency(big)
    small1 = 1.0 / DynamicCompiler(art).compile([0]).estimated_latency(hw)

    print(f"{'phase':34s} {'virtualized':>12s} {'static-multi':>13s} {'static-1core':>13s}")
    total_ctx_ms = 0.0
    for desc, alloc in PHASES:
        pool = ResourcePool(16)
        eng = VirtualEngine(pool, hw)
        ctx_ms = 0.0
        for tenant, cores in alloc.items():
            eng.admit(tenant, art, cores)
            ctx_ms += eng.tenants[tenant].schedule.compile_seconds * 1e3
        m = eng.run(1.0)
        virt = sum(t.throughput(1.0) for t in m.values())
        static_multi = len(alloc) * small1          # 1 fixed core per tenant
        print(f"{desc:34s} {virt:9.1f} fps {static_multi:10.1f} fps "
              f"{tdm_total:10.1f} fps   (recompile {ctx_ms:.2f} ms)")
        total_ctx_ms += ctx_ms
    print(f"\ntotal reconfiguration overhead across the day: {total_ctx_ms:.1f} ms "
          f"(vs ~100 s per reconfiguration for bitstream/instruction regeneration)")


if __name__ == "__main__":
    main()
