"""End-to-end driver: serve a small LM with batched requests, multi-tenant.

    PYTHONPATH=src python examples/serve_multitenant.py

Two tenants share one node through the VirtualAcceleratorPool (disjoint
leases = the paper's SDM isolation), each running a ContinuousBatcher: real
prefill + decode over a reduced qwen3 model, continuous admission into free
slots, greedy sampling, per-request completion tracking.
"""

import sys

sys.path.insert(0, "src")

import numpy as np
import jax

from repro.configs import get_reduced
from repro.models import init_params
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.tenancy import VirtualAcceleratorPool


def main() -> None:
    cfg = get_reduced("qwen3-0.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    pool = VirtualAcceleratorPool(devices=list(jax.devices()) * 16,
                                  devices_per_core=1)
    print(f"pool: {pool.n_cores} cores; model: {cfg.name} "
          f"({cfg.param_count()/1e6:.1f}M params)")

    for tenant, n_cores, n_req in (("alice", 12, 10), ("bob", 4, 6)):
        lease = pool.lease(tenant, n_cores)
        batcher = ContinuousBatcher(params, cfg, slots=4, prompt_len=12,
                                    max_len=40)
        reqs = []
        for r in range(n_req):
            plen = int(rng.integers(3, 12))
            req = Request(rid=r,
                          prompt=rng.integers(1, cfg.vocab, plen).astype(np.int32),
                          max_new=10)
            reqs.append(req)
            batcher.submit(req)
        stats = batcher.run()
        print(f"{tenant}: {len(lease.cores)} cores, "
              f"{stats.completed}/{n_req} requests done, "
              f"{stats.steps} decode steps, {stats.prefills} prefills, "
              f"occupancy {stats.occupancy:.2f}")
        print(f"  sample output (req 0): {reqs[0].out}")

    # isolation invariant held throughout
    pool.pool.check_isolation()
    pool.pool.check_bandwidth()
    print("isolation + bandwidth budget invariants: OK")


if __name__ == "__main__":
    main()
