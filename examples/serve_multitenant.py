"""End-to-end driver: serve a small LM with batched requests, multi-tenant.

    PYTHONPATH=src python examples/serve_multitenant.py

Two tenants share one node through the VirtualAcceleratorPool (disjoint
leases = the paper's SDM isolation), each running a ContinuousBatcher: real
prefill + decode over a reduced qwen3 model, continuous admission into free
slots, greedy sampling, per-request completion tracking.

Decode runs the chunked/donated hot path: one device dispatch and one host
sync per chunk of tokens, caches donated in place (serving.engine).

Placement goes through the same Hypervisor as the simulation engine: the
``priority`` policy grants alice (priority 2) her full request and bob the
rest; when bob departs, a policy-driven reconfiguration grows alice — the
serving stack never calls the pool ad-hoc.  Alice's batcher registers its
live device state with the executor (pull-model register_state), so the
regrow migrates her donated caches mid-run and decode resumes in place.
"""

import sys

sys.path.insert(0, "src")

import numpy as np
import jax

from repro.configs import get_reduced
from repro.core import TenantSpec
from repro.models import init_params
from repro.serving import ServingConfig
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.tenancy import VirtualAcceleratorPool, make_serving_hypervisor


def main() -> None:
    cfg = get_reduced("qwen3-0.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    pool = VirtualAcceleratorPool(devices=list(jax.devices()) * 16,
                                  devices_per_core=1)
    hv, ex = make_serving_hypervisor(pool, policy="priority")
    print(f"pool: {pool.n_cores} cores; model: {cfg.name} "
          f"({cfg.param_count()/1e6:.1f}M params); policy: priority")

    # static stage: AOT artifacts for every lease size alice can be resized
    # to, so her reconfiguration is a cache lookup + state migration
    import jax.numpy as jnp
    import jax.sharding as jsh

    def mesh_builder(n):
        devs = np.array(list(jax.devices()) * n, dtype=object)[:n].reshape(n, 1)
        return jsh.Mesh(devs, ("data", "model"))

    ex.compiler.static_compile(
        "decode", lambda x: x, (jax.ShapeDtypeStruct((4,), jnp.float32),),
        lease_sizes=[12, 16], mesh_builder=mesh_builder,
    )

    for tenant, n_cores, n_req, prio in (("alice", 12, 10, 2.0),
                                         ("bob", 4, 6, 1.0)):
        artifact = "decode" if tenant == "alice" else None
        if not hv.admit(TenantSpec(tenant, n_cores, priority=prio,
                                   artifact=artifact)):
            raise RuntimeError(f"{tenant} was not admitted (waiting: {hv.waiting_tenants()})")
        lease = pool.pool.lease_of(tenant)
        batcher = ContinuousBatcher(
            params, cfg,
            ServingConfig(slots=4, prompt_len=12, max_len=40, chunk=8))
        # pull-model state registration: a resize landing between chunks
        # migrates the donated caches and hands them back via adopt_state
        ex.register_state(tenant, batcher.live_state,
                          on_migrate=batcher.adopt_state)
        reqs = []
        for r in range(n_req):
            plen = int(rng.integers(3, 12))
            req = Request(rid=r,
                          prompt=rng.integers(1, cfg.vocab, plen).astype(np.int32),
                          max_new=10)
            reqs.append(req)
            batcher.submit(req)
        stats = batcher.run()
        print(f"{tenant}: {len(lease.cores)} cores, "
              f"{stats.completed}/{n_req} requests done, "
              f"{stats.steps} decode steps in {stats.chunks} chunks "
              f"({stats.dispatches_per_token:.3f} dispatches/token), "
              f"{stats.prefills} prefills, occupancy {stats.occupancy:.2f}")
        print(f"  sample output (req 0): {reqs[0].out}")

    # bob's service drains; the hypervisor reclaims his cores and the policy
    # regrows alice via an explicit reconfiguration signal
    hv.depart("bob")
    hv.resize_request("alice", 16)
    last = ex.reconfig_log[-1]
    print(f"after bob departs + policy regrow: {hv.allocation()} "
          f"({len(ex.reconfig_log)} policy-driven reconfigurations; "
          f"alice's caches migrated in {last.get('t_migrate', 0)*1e3:.2f} ms)")

    # isolation invariant held throughout (also re-checked after every event)
    pool.pool.check_isolation()
    pool.pool.check_bandwidth()
    print("isolation + bandwidth budget invariants: OK")


if __name__ == "__main__":
    main()
