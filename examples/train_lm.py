"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_lm.py            # quick (reduced)
    PYTHONPATH=src python examples/train_lm.py --full100m # ~100M params

Demonstrates the launcher's fault tolerance: the run is killed mid-way
(simulated preemption, exit 42), then restarted — it resumes from the async
checkpoint and finishes with the same loss trajectory.
"""

import argparse
import subprocess
import sys
import tempfile

CMD = [sys.executable, "-m", "repro.launch.train"]


def run(args, env_path):
    p = subprocess.run(
        CMD + args, capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": env_path},
    )
    sys.stdout.write(p.stdout)
    sys.stderr.write(p.stderr[-2000:] if p.returncode not in (0, 42) else "")
    return p.returncode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full100m", action="store_true",
                    help="train the ~100M-param config (slower)")
    ap.add_argument("--steps", type=int, default=None)
    a = ap.parse_args()

    import os

    steps = a.steps or (200 if a.full100m else 120)
    base = ["--arch", "qwen3-0.6b", "--seq", "256", "--batch", "4",
            "--steps", str(steps), "--lr", "1e-3", "--ckpt-every", "40"]
    if not a.full100m:
        base += ["--reduced"]
        # reduced config is ~1M params; bump width via seq/batch only

    with tempfile.TemporaryDirectory() as d:
        ckpt = ["--ckpt-dir", d]
        die = ["--die-at-step", str(steps // 2)]
        print(f"=== phase 1: train to step {steps//2}, then simulated preemption ===")
        rc = run(base + ckpt + die, os.environ.get("PATH", ""))
        assert rc == 42, f"expected simulated preemption exit 42, got {rc}"
        print("=== phase 2: restart — resumes from checkpoint ===")
        rc = run(base + ckpt, os.environ.get("PATH", ""))
        assert rc == 0, rc
    print("fault-tolerant train/restart cycle: OK")


if __name__ == "__main__":
    main()
