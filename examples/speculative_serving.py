"""Speculative decode + prefill/decode overlap on the chunked hot path.

    PYTHONPATH=src python examples/speculative_serving.py

``ServingConfig(speculative=True)`` turns each decode chunk into a
draft-and-verify window: an on-device n-gram drafter proposes up to
``draft_window - 1`` tokens per slot from the slot's own committed
history, one batched multi-query pass verifies the whole window, and the
accepted prefix commits while rejected tokens roll back (cursor
non-advance + overwrite discipline).  The output is token-identical to
plain greedy decode by construction — speculation only changes *when*
tokens are produced, never *which*.

``overlap=True`` additionally dispatches admission prefills behind the
in-flight decode chunk (one merge point per round), so prefill-heavy
traffic overlaps host planning with device decode instead of serializing.

Acceptance rate is trace-dependent: the n-gram drafter pays on
repetitive/loopy streams (greedy decode settles into such loops as
generations run deep) and approaches zero on high-entropy prefixes.  The
demo runs the same decode-deep trace serial and spec+overlap and prints
both clocks plus the drafter's scoreboard.
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax

from repro.configs import get_reduced
from repro.models import init_params
from repro.serving import ServingConfig
from repro.serving.batcher import ContinuousBatcher, Request

SLOTS, PROMPT_LEN, MAX_NEW = 4, 8, 256
N_REQUESTS = 12


def requests(cfg):
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        size=2 + i % 6).astype(np.int32),
                    max_new=MAX_NEW)
            for i in range(N_REQUESTS)]


def serve(params, cfg, *, speculative: bool, overlap: bool):
    sc = ServingConfig(slots=SLOTS, prompt_len=PROMPT_LEN,
                       max_len=PROMPT_LEN + MAX_NEW + 8, chunk=8,
                       paged=True, page_size=16, n_pages=256,
                       speculative=speculative, draft_window=6,
                       overlap=overlap)
    b = ContinuousBatcher(params, cfg, sc)
    reqs = requests(cfg)
    for r in reqs:
        b.submit(r)
    t0 = time.perf_counter()
    stats = b.run(max_steps=1_000_000)
    jax.block_until_ready(b.caches)
    dt = time.perf_counter() - t0
    return reqs, stats, dt


def main() -> None:
    cfg = get_reduced("qwen3-0.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    serve(params, cfg, speculative=False, overlap=False)   # compile warmup
    base, base_stats, base_dt = serve(params, cfg,
                                      speculative=False, overlap=False)
    serve(params, cfg, speculative=True, overlap=True)     # compile warmup
    spec, spec_stats, spec_dt = serve(params, cfg,
                                      speculative=True, overlap=True)

    assert all(b.out == s.out for b, s in zip(base, spec)), \
        "speculative greedy must be token-identical to plain greedy"
    print(f"serial greedy:  {base_stats.decode_tokens} decode tokens in "
          f"{base_dt:.2f}s ({base_stats.decode_tokens / base_dt:,.0f} tok/s)")
    print(f"spec + overlap: {spec_stats.decode_tokens} decode tokens in "
          f"{spec_dt:.2f}s ({spec_stats.decode_tokens / spec_dt:,.0f} tok/s) "
          f"-> {base_dt / spec_dt:.2f}x")
    print(f"  outputs identical across all {len(base)} requests")
    print(f"  drafter: {spec_stats.drafted_tokens} drafted, "
          f"{spec_stats.accepted_tokens} accepted "
          f"(acceptance {spec_stats.acceptance_rate:.2f}) over "
          f"{spec_stats.spec_windows} verify windows")
    print(f"  overlap: {spec_stats.overlap_rounds} rounds dispatched an "
          f"admission prefill behind the in-flight decode chunk")


if __name__ == "__main__":
    main()
