"""Unified telemetry plane: one trace across hypervisor + serving.

    PYTHONPATH=src python examples/tracing_serving.py

Everything lands in ONE :class:`repro.obs.Telemetry` bundle — a shared
``MetricsRegistry`` plus a shared ``Tracer`` — and exports as a single
Chrome-trace JSON (open it at https://ui.perfetto.dev) with one track per
tenant plus a hypervisor track:

1. **Pool chaos (sim time)** — a seeded :class:`FaultInjector` drops core
   deaths onto a three-tenant hypervisor run.  Every event-loop event
   becomes an instant on its tenant's track (``ts=`` carries the sim
   clock), and each displaced tenant's re-placement becomes a
   ``recovery`` span.
2. **Two-tenant paged serving (wall time)** — ``tenant-a`` decodes on a
   tensor-sharded paged batcher and is re-meshed tp=1→2 live by the
   ``ServingExecutor`` (a ``remesh`` span); ``tenant-b`` runs with a
   starved ``kv_pages`` quota so denied in-scan page faults requeue
   (``oom_requeue`` instants + the ``fault_denied_slots`` device
   counter).  Both batchers label the same registry with their tenant, so
   ``round``/``dispatch``/``host_sync`` spans interleave on separate
   tracks and per-request latencies feed ``slo_report`` p50/p95/p99.

The committed sample trace in ``examples/traces/`` was produced by this
script (``max_events`` bounds its size).
"""

import os
import sys
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")

import numpy as np
import jax

from repro.configs import get_reduced
from repro.core import FaultInjector, Hypervisor, ResourcePool, TenantSpec
from repro.models import init_params
from repro.obs import Telemetry, Tracer
from repro.serving import ServingConfig
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.tenancy import (
    ServingExecutor, SwitchMode, VirtualAcceleratorPool,
)

PROMPT_LEN, MAX_NEW = 8, 12


def pool_chaos(tel: Telemetry) -> None:
    """Seeded faults over a 16-core hypervisor run — sim-time instants on
    tenant tracks, recovery spans when displaced tenants are re-placed."""
    hv = Hypervisor(ResourcePool(16), telemetry=tel)
    for name in ("gold", "silver", "bronze"):
        hv.schedule_arrival(TenantSpec(name, requested_cores=8, min_cores=2),
                            at=0.0)
    inj = FaultInjector(16, seed=1337, death_rate=0.6, slow_rate=0.2,
                        repair_after=1.5)
    faults = inj.inject(hv.queue, 6.0)
    hv.run(8.0)
    rec = hv.recovery_log
    print(f"pool chaos: {len(faults)} seeded faults, "
          f"{len(rec)} recoveries traced")


def requests(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        size=2 + i % 6).astype(np.int32),
                    max_new=MAX_NEW)
            for i in range(n)]


def serving(tel: Telemetry, clock) -> ServingExecutor:
    """Two paged tenants under load: a live tp re-mesh on tenant-a, a
    starved page quota on tenant-b, per-request latencies into the SLO
    report — all on the shared telemetry bundle."""
    cfg = get_reduced("qwen3-0.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    vpool = VirtualAcceleratorPool(devices=jax.devices(), devices_per_core=1)
    ex = ServingExecutor(vpool, clock=clock, telemetry=tel)
    ex.exec_admit(TenantSpec("tenant-a", requested_cores=1, artifact=None),
                  1, at=clock())

    tel_a = Telemetry(registry=tel.registry, tracer=tel.tracer,
                      tenant="tenant-a")
    tel_b = Telemetry(registry=tel.registry, tracer=tel.tracer,
                      tenant="tenant-b")
    # tenant-a mirrors bench_sharded's paged+tp shape (chunk=4, page_size=4)
    # so the tp=2 re-mesh compile stays example-sized on emulated devices
    a = ContinuousBatcher(
        params, cfg,
        ServingConfig(slots=4, prompt_len=PROMPT_LEN,
                      max_len=PROMPT_LEN + MAX_NEW + 4, chunk=4,
                      paged=True, page_size=4, n_pages=64, tp=1),
        mesh=vpool.tp_mesh_for(vpool.pool.lease_of("tenant-a")),
        telemetry=tel_a, clock=clock)
    b = ContinuousBatcher(
        params, cfg,
        ServingConfig(slots=4, prompt_len=PROMPT_LEN,
                      max_len=PROMPT_LEN + MAX_NEW + 4, chunk=8,
                      paged=True, page_size=8, n_pages=16, page_quota=5,
                      reserve_pages=False),
        telemetry=tel_b, clock=clock)
    ex.register_remesh("tenant-a", lambda mesh: a.remesh(mesh=mesh))

    t_submit = {}
    reqs = {}
    for who, batcher in (("tenant-a", a), ("tenant-b", b)):
        reqs[who] = requests(cfg, 8, seed={"tenant-a": 3, "tenant-b": 17}[who])
        for r in reqs[who]:
            t_submit[(who, r.rid)] = clock()
            batcher.submit(r)

    # interleave the tenants by hand so their round spans overlap on the
    # trace; re-mesh tenant-a to 2 devices a few rounds in
    def busy(batcher):
        return batcher.queue or any(r is not None for r in batcher.slot_req)

    pending = {"tenant-a": a, "tenant-b": b}
    done_at = {}
    steps = 0
    while pending:
        for who, batcher in list(pending.items()):
            batcher.step()
            for req in reqs[who]:
                key = (who, req.rid)
                if req.done and key not in done_at:
                    done_at[key] = clock()
                    ex.record_latency(who, done_at[key] - t_submit[key],
                                      slo=30.0)  # wall time incl. compiles
            if not busy(batcher):
                del pending[who]
        steps += 1
        if steps == 2:
            ex.exec_resize("tenant-a", 2, clock(), SwitchMode.TASK_LEVEL)
            print(f"re-meshed tenant-a tp=1 -> tp=2 "
                  f"(t_remesh={ex.reconfig_log[-1]['t_remesh']*1e3:.0f} ms)")

    assert b.stats.oom_requeues > 0, "quota never starved tenant-b"
    print(f"serving: tenant-a {a.stats.tokens} tokens "
          f"({a.stats.remeshes} re-mesh), tenant-b {b.stats.tokens} tokens "
          f"({b.stats.oom_requeues} OOM requeues, "
          f"{b.stats.fault_denied_slots} denied in-scan)")
    return ex


def main() -> None:
    base = time.perf_counter()
    clock = lambda: time.perf_counter() - base  # noqa: E731 — shared origin
    tel = Telemetry(tracer=Tracer(clock=clock, max_events=3000))

    pool_chaos(tel)
    ex = serving(tel, clock)

    for tenant, rep in sorted(ex.slo_report().items()):
        print(f"  slo[{tenant}]: n={rep['requests']} "
              f"attainment={rep['attainment']:.2f} "
              f"p50={rep['p50_latency']:.3f}s p99={rep['p99_latency']:.3f}s")

    out_dir = os.path.join(os.path.dirname(__file__), "traces")
    os.makedirs(out_dir, exist_ok=True)
    trace = tel.tracer.export(
        os.path.join(out_dir, "tracing_serving.trace.json"))
    metrics = tel.registry.export(
        os.path.join(out_dir, "tracing_serving.metrics.json"))
    print(f"tracks: {', '.join(tel.tracer.tracks())}")
    print(f"wrote {trace} ({os.path.getsize(trace) // 1024} KiB, "
          f"{len(tel.tracer.events)} events, {tel.tracer.dropped} dropped) "
          f"and {metrics} — open the trace at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
