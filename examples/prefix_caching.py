"""Shared-prefix KV cache: two tenants, one shared system prompt.

Two tenants ("ada" and "bob") serve prompts that all begin with the same
system preamble.  Their requests run through one paged batcher with a
prefix cache attached:

* requests submitted under the **same namespace** (the tenants agreed on
  ``"support-bot-v1"`` for the shared preamble) map the same physical
  pages read-only and prefill only their private tail — pages are billed
  once to the namespace, refcounted per in-flight request;
* a request submitted under a **private namespace** (or ``namespace=None``)
  never shares — isolation is opt-in by key;
* the hypervisor sees the shared set through
  ``ResourcePool.note_shared_kv`` and treats it as a soft floor when
  splitting kv-page leases, so a rebalance doesn't hand a tenant's warm
  cache to someone else while it is pinned.

    PYTHONPATH=src python examples/prefix_caching.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.configs import get_reduced  # noqa: E402
from repro.core import ResourcePool, TenantSpec, Hypervisor  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.serving import ServingConfig  # noqa: E402
from repro.serving.batcher import ContinuousBatcher, Request  # noqa: E402

PROMPT_LEN = 64
PAGE_SIZE = 8
SHARED_NS = "support-bot-v1"


def main():
    cfg = get_reduced("qwen3-0.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # one system preamble both tenants use, plus per-request user tails
    system_prompt = rng.integers(1, cfg.vocab, size=56).astype(np.int32)

    def request(rid):
        tail = rng.integers(1, cfg.vocab, size=8).astype(np.int32)
        return Request(rid=rid, prompt=np.concatenate([system_prompt, tail]),
                       max_new=4, namespace=SHARED_NS)

    b = ContinuousBatcher(
        params, cfg,
        ServingConfig(slots=4, prompt_len=PROMPT_LEN, max_len=96, chunk=4,
                      paged=True, page_size=PAGE_SIZE, prefix_cache=True))
    # even rids are ada's traffic, odd rids bob's — same namespace, so the
    # shared preamble's pages are physically one copy across both tenants
    reqs = [request(i) for i in range(16)]
    for r in reqs:
        b.submit(r)
    stats = b.run(max_steps=4000)

    n = len(reqs)
    print(f"served {stats.completed}/{n} requests (ada+bob interleaved)")
    print(f"prefix hits:            {stats.prefix_hits}/{n} "
          f"(hit rate {stats.prefix_hits / n:.2f})")
    print(f"prefill tokens skipped: {stats.prefill_tokens_skipped} "
          f"of {n * PROMPT_LEN} "
          f"({stats.prefill_tokens_skipped / (n * PROMPT_LEN):.0%})")
    print(f"pages in the cache:     {stats.shared_pages} "
          f"(vs {stats.prefix_tokens_saved // PAGE_SIZE} page-maps served "
          f"from them — that is the dedup)")

    # hypervisor-side billing: the shared set is recorded once against the
    # owning tenant and raises its floor in the default kv split
    pool = ResourcePool(4, n_kv_pages=64)
    hv = Hypervisor(pool, policy="even_split")
    assert hv.admit(TenantSpec("ada", 2, requested_kv_pages=48,
                               min_kv_pages=4))
    pool.note_shared_kv("ada", b.kv_pool.shared)
    assert hv.admit(TenantSpec("bob", 2, requested_kv_pages=48,
                               min_kv_pages=4))
    print(f"kv split with ada's {b.kv_pool.shared} shared pages billed "
          f"once: {hv.kv_allocation()}")
    pool.check_kv_quota()


if __name__ == "__main__":
    main()
