"""Fault-domain hypervisor: seeded chaos, detection, tenant recovery.

    PYTHONPATH=src python examples/chaos_serving.py

Two layers of the fault-tolerance story:

1. **Pool chaos (sim)** — a seeded :class:`FaultInjector` drops core
   deaths and slow cores onto a live three-tenant run.  The hypervisor
   marks failed cores unplaceable, displaces the owner *inside the same
   FAILURE event* (``check_health`` holds at every event boundary), parks
   it with exponential-backoff retries when the shrunken pool can't seat
   it, and stamps ``recovery_log`` when it is re-placed.  The same seed
   replays the identical fault schedule — chaos runs are reproducible.

2. **Serving guards (jax)** — a paged ``ContinuousBatcher`` with
   ``audit=True`` and a watchdog survives injected KV-page-table
   corruption and a wedged chunk: the audit quarantines the corrupt
   page and requeues the suspect slot (tokens preserved when they still
   fit the prompt bucket), the watchdog deactivates the stuck slot
   instead of stalling the batch, and untouched requests finish with
   byte-identical tokens — zero cross-tenant blast radius.
"""

import sys

sys.path.insert(0, "src")

import numpy as np
import jax

from repro.configs import get_reduced
from repro.core import (
    CNN_WORKLOADS, FaultInjector, Hypervisor, PoissonTraffic, ResourcePool,
    StaticCompiler, TenantSpec, VirtualEngine, fpga_small_core,
)
from repro.models import init_params
from repro.serving import ServingConfig
from repro.serving.batcher import ContinuousBatcher, Request


def pool_chaos() -> None:
    print("=== pool chaos: seeded faults over a 16-core hypervisor run ===")
    hw = fpga_small_core()
    artifact = StaticCompiler(hw, n_tiles=16).compile(
        CNN_WORKLOADS["mobilenet"]())
    pool = ResourcePool(16)
    engine = VirtualEngine(pool, hw, straggler_threshold=1.3)
    hv = Hypervisor(pool, policy="even_split", executor=engine,
                    probe_interval=0.1)
    for i, name in enumerate(("gold", "silver", "bronze")):
        spec = TenantSpec(name, requested_cores=16, min_cores=1,
                          artifact=artifact, open_loop=True)
        hv.schedule_arrival(spec, at=0.0)
        hv.open_traffic(name, PoissonTraffic(8.0, seed=11 * (i + 1)), 10.0)

    inj = FaultInjector(16, seed=1337, death_rate=0.5, slow_rate=0.3,
                        repair_after=1.5)
    faults = inj.inject(hv.queue, 8.0)
    print(f"schedule ({len(faults)} faults, seed 1337): " + ", ".join(
        f"{f.kind.value}@{f.time:.2f}s core {f.core}" for f in faults[:5])
        + " ...")
    assert inj.schedule(8.0) == faults      # same seed, same schedule

    hv.run(10.0)
    print(f"failed cores at the end: {pool.failed_cores()} "
          f"(healthy {pool.n_healthy}/{pool.n_cores})")
    for rec in hv.recovery_log:
        print(f"  {rec['tenant']}: displaced at {rec['failed_at']:.2f}s, "
              f"re-placed at {rec['recovered_at']:.2f}s "
              f"(latency {rec['recovery_latency'] * 1e3:.1f} ms)")
    served = sum(1 for r in hv.completion_log if r.t_complete is not None)
    print(f"{served} requests served through {len(faults)} faults; "
          f"every displaced tenant recovered: {not hv._displaced_at}")
    pool.check_health()


def serving_chaos() -> None:
    print("\n=== serving guards: corruption + stall in one tenant's slots ===")
    cfg = get_reduced("qwen3-0.6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab, size=2)
                    .astype(np.int32), max_new=10)
            for i in range(8)]            # rids 0-3 = tenant A, 4-7 = B

    def run(inject: bool):
        b = ContinuousBatcher(
            params, cfg,
            ServingConfig(slots=4, prompt_len=8, max_len=64, chunk=2,
                          paged=True, page_size=8, watchdog_s=0.5,
                          audit=True),
            clock=lambda: 0.0)
        for r in reqs:
            r.out.clear()
            b.submit(r)
        steps = 0
        while (any(b.slot_req) or b.queue) and steps < 2000:
            b.step()
            steps += 1
            if inject and steps == 1:     # faults hit tenant-A slots only
                victims = [i for i, r in enumerate(b.slot_req)
                           if r is not None and r.rid < 4]
                b.inject_kv_corruption(victims[0])
                if len(victims) > 1:
                    b.inject_stall(victims[1], 1.0)
        return {r.rid: list(r.out) for r in reqs}, b.stats

    clean, _ = run(inject=False)
    chaos, stats = run(inject=True)
    print(f"audit repairs {stats.audit_repairs}, watchdog trips "
          f"{stats.watchdog_trips}, quarantined pages "
          f"{stats.quarantined_pages}, tokens kept across requeues "
          f"{stats.resumed_tokens_kept}")
    b_identical = all(chaos[i] == clean[i] for i in range(4, 8))
    a_done = all(len(chaos[i]) == 10 for i in range(4))
    print(f"tenant B token-identical to the fault-free run: {b_identical}")
    print(f"tenant A recovered to full completion: {a_done}")
    assert b_identical and a_done


if __name__ == "__main__":
    pool_chaos()
    serving_chaos()
