"""SLO-aware scheduling: open-loop traffic, graceful degradation, preemption.

    PYTHONPATH=src python examples/slo_scheduling.py

The public-cloud half of the paper's claim — *guaranteed* performance under
sharing — needs three things the closed-loop simulator cannot express:
offered load that doesn't slow down when the system does (seeded Poisson
arrivals), per-request latency targets, and a policy that allocates against
them.  This example runs one continuous event-driven story:

1. ``api`` (priority 2, tight SLO) and ``batch`` (priority 1, loose SLO)
   arrive and offer open-loop Poisson traffic; the ``latency_slo`` policy
   sizes each lease from the *queue-adjusted* latency model (service time
   plus M/D/1 wait) instead of splitting evenly.
2. A high-priority ``realtime`` tenant lands mid-run: ``batch`` is shrunk
   toward its floor (graceful degradation), not locked out or evicted.
3. An ``emergency`` tenant whose demand cannot fit even at everyone's
   floor preempts: the lowest-priority resident is *evicted*, charged one
   context switch, and re-admitted from the wait-queue head (backfill
   order) when the emergency departs.

Every request's arrival→start→completion is stamped on a shared record, so
SLO attainment is computed at the end without touching engine internals.
"""

import sys

sys.path.insert(0, "src")

from repro.core import (
    CNN_WORKLOADS, Hypervisor, PoissonTraffic, ResourcePool, StaticCompiler,
    TenantSpec, VirtualEngine, fpga_small_core,
)

HORIZON = 8.0


def main() -> None:
    hw = fpga_small_core()
    resnet = StaticCompiler(hw, n_tiles=16).compile(CNN_WORKLOADS["resnet50"]())
    mobilenet = StaticCompiler(hw, n_tiles=16).compile(CNN_WORKLOADS["mobilenet"]())

    pool = ResourcePool(16)
    engine = VirtualEngine(pool, hw)
    hv = Hypervisor(pool, policy="latency_slo", executor=engine,
                    admission="backfill", preemptive=True)

    def spec(name, artifact, prio, slo_cores, rate, *, min_cores=1):
        """SLO calibrated so ``slo_cores`` cores meet it comfortably."""
        s = TenantSpec(name, 16, priority=prio, artifact=artifact,
                       min_cores=min_cores, open_loop=True, arrival_rate=rate)
        s.latency_slo = 1.5 * engine.estimate_latency(s, slo_cores)
        return s

    # floors: api never below 4 cores, batch never below 2 — so the
    # emergency's all-or-nothing demand of 12 cannot fit (16 - 4 - 2 = 10)
    # without evicting the lowest-priority resident
    api = spec("api", resnet, 2.0, 6, rate=10.0, min_cores=4)
    batch = spec("batch", mobilenet, 1.0, 2, rate=12.0, min_cores=2)
    realtime = spec("realtime", resnet, 3.0, 8, rate=8.0)
    emergency = spec("emergency", resnet, 5.0, 12, rate=6.0, min_cores=12)

    records = []
    for s, t_on, t_off in ((api, 0.0, None), (batch, 0.5, None),
                           (realtime, 2.0, 4.0), (emergency, 5.0, 6.5)):
        hv.schedule_arrival(s, at=t_on)
        end = t_off if t_off is not None else HORIZON
        records += hv.open_traffic(
            s.name, PoissonTraffic(s.arrival_rate, seed=hash(s.name) % 1000,
                                   start=t_on),
            end, slo=s.latency_slo)
        if t_off is not None:
            hv.schedule_departure(s.name, at=t_off)

    alloc_log = []
    hv.on_event = lambda h, ev: alloc_log.append((h.clock, ev, dict(h.allocation())))

    metrics = hv.run(HORIZON)

    print(f"pool: {pool.n_cores} cores | policy: latency_slo "
          f"(backfill admission, preemptive)\n")
    print("timeline (allocation after each tenant/priority event):")
    for t, ev, alloc in alloc_log:
        if ev.kind.value in ("arrival", "departure"):
            print(f"  t={t:5.2f}  {ev!r:<28} -> {alloc}")
    print(f"\npreemptions: {hv.preemptions}")
    for name in ("api", "batch", "realtime", "emergency"):
        mine = [r for r in records if r.tenant == name]
        met = sum(1 for r in mine if r.slo_met)
        m = metrics[name]
        print(f"  {name:>10}: offered {len(mine):3d}  slo-met {met:3d} "
              f"({met / max(len(mine), 1):5.1%})  evictions {m.evictions}  "
              f"ctx overhead {m.ctx_overhead * 1e3:.2f} ms")
    total = sum(1 for r in records if r.slo_met)
    print(f"\noverall SLO attainment: {total / len(records):.1%} "
          f"({total}/{len(records)} requests)")


if __name__ == "__main__":
    main()
