from .synthetic import global_batch, make_batch

__all__ = ["global_batch", "make_batch"]
