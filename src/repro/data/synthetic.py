"""Deterministic synthetic data pipeline (host-sharded).

Production shape without external storage: every batch is a pure function of
(seed, step, host) via counter-based Philox streams, so

* restarts are bit-exact (fault-tolerance tests replay the same stream),
* hosts generate disjoint shards with no coordination (``host_batch``),
* the "dataset" scales to any step count with zero I/O.

Token streams are Zipf-ish (realistic softmax pressure) and labels are the
next-token shift with the final position masked (-1).  Modality stubs: the
VLM cell gets patch embeddings + 3D M-RoPE positions; the audio cell gets
encoder frame embeddings (the conv frontend is stubbed per the brief).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

VLM_PATCHES = 256     # patch positions prepended for the vlm family
VLM_PATCHES_REDUCED = 8


def _rng(seed: int, step: int, host: int = 0) -> np.random.Generator:
    # counter-based stream: (seed, step, host) -> disjoint, replayable
    counter = [step, host, 0x5EED, 0]
    return np.random.Generator(np.random.Philox(key=seed, counter=counter))


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipf(1.1)-distributed token ids clipped to the vocab."""
    z = rng.zipf(1.1, size=shape)
    return ((z - 1) % vocab).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    seed: int = 0
    n_hosts: int = 1


def make_batch(
    cfg, *, seq_len: int, batch: int, step: int, seed: int = 0,
    host: int = 0, n_hosts: int = 1, reduced: bool = False,
) -> Dict[str, np.ndarray]:
    """One host's shard of the global batch for ``step``.

    Keys always include tokens/labels; family extras:
      vlm   → extra_embeds (B, P, d) f32, positions (3, B, P+S)
      audio → frames (B, enc_seq, d) f32
    VLM tokens cover seq_len - P positions so the total sequence length
    (patches + text) equals the cell's seq_len.
    """
    assert batch % n_hosts == 0, (batch, n_hosts)
    b_local = batch // n_hosts
    rng = _rng(seed, step, host)
    n_patch = 0
    if cfg.family == "vlm":
        n_patch = VLM_PATCHES_REDUCED if reduced else VLM_PATCHES
    s_text = seq_len - n_patch
    tokens = _zipf_tokens(rng, (b_local, s_text), cfg.vocab)
    labels = np.full((b_local, seq_len), -1, dtype=np.int32)
    # next-token prediction on the text region (patch positions stay masked)
    labels[:, n_patch : seq_len - 1] = tokens[:, 1:]
    out: Dict[str, np.ndarray] = {"tokens": tokens, "labels": labels}
    if cfg.family == "vlm":
        out["extra_embeds"] = rng.standard_normal(
            (b_local, n_patch, cfg.d_model), dtype=np.float32
        ) * 0.02
        # M-RoPE 3D ids: patches get (t=0, h, w) grid ids; text continues 1D
        side_h = int(np.sqrt(n_patch))
        while n_patch % side_h:
            side_h -= 1
        side_w = n_patch // side_h
        hh, ww = np.meshgrid(np.arange(side_h), np.arange(side_w), indexing="ij")
        pos = np.zeros((3, b_local, seq_len), dtype=np.int32)
        pos[0, :, :n_patch] = 0
        pos[1, :, :n_patch] = hh.reshape(-1)[None, :]
        pos[2, :, :n_patch] = ww.reshape(-1)[None, :]
        text_pos = max(side_h, side_w) + np.arange(s_text, dtype=np.int32)
        pos[:, :, n_patch:] = text_pos[None, None, :]
        out["positions"] = pos
    if cfg.family == "audio":
        out["frames"] = rng.standard_normal(
            (b_local, cfg.enc_seq, cfg.d_model), dtype=np.float32
        ) * 0.02
    return out


def global_batch(cfg, *, seq_len: int, batch: int, step: int, seed: int = 0,
                 n_hosts: int = 1, reduced: bool = False) -> Dict[str, np.ndarray]:
    """Assemble the full global batch (concatenating host shards) — used by
    single-host tests/examples and to verify host-shard disjointness."""
    shards = [
        make_batch(cfg, seq_len=seq_len, batch=batch, step=step, seed=seed,
                   host=h, n_hosts=n_hosts, reduced=reduced)
        for h in range(n_hosts)
    ]
    return {
        k: np.concatenate([s[k] for s in shards], axis=1 if k == "positions" else 0)
        for k in shards[0]
    }
