"""Primitive layers: explicit-pytree params, pure-functional apply.

No flax/haiku — params are nested dicts of jnp arrays so that
``jax.eval_shape(init_params, ...)`` yields allocation-free
ShapeDtypeStructs for the multi-pod dry-run, and sharding rules can be
written as path-pattern → PartitionSpec tables.

All linear layers are bias-free (every assigned arch is no-bias except the
Whisper stub, where we follow the same convention and note it in DESIGN.md).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Linear / embedding / norms
# ---------------------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, dtype="bfloat16", scale: float | None = None):
    scale = (1.0 / np.sqrt(d_in)) if scale is None else scale
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    return {"w": w.astype(_dtype(dtype))}


def dense(params, x):
    return x @ params["w"]


def init_embedding(key, vocab: int, d_model: int, dtype="bfloat16"):
    w = jax.random.normal(key, (vocab, d_model), dtype=jnp.float32) * 0.02
    return {"w": w.astype(_dtype(dtype))}


def embed(params, tokens):
    return jnp.take(params["w"], tokens, axis=0)


def init_rmsnorm(d: int, dtype="bfloat16"):
    return {"scale": jnp.ones((d,), dtype=_dtype(dtype))}


def rmsnorm(params, x, *, eps: float = 1e-6):
    """RMSNorm in f32 accumulation, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def gated_rmsnorm(params, x, gate, *, eps: float = 1e-6):
    """Mamba-2 output norm: RMSNorm(x * silu(gate))."""
    return rmsnorm(params, x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype), eps=eps)


# ---------------------------------------------------------------------------
# MLP: SwiGLU (fused gate+up, Llama family) or plain 2-matrix GELU
# (StarCoder2 / Whisper — keeps their assigned d_ff param counts faithful)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype="bfloat16", *, kind: str = "swiglu"):
    k1, k2 = jax.random.split(key)
    wi_out = 2 * d_ff if kind == "swiglu" else d_ff   # swiglu: [gate | up]
    return {
        "wi": init_dense(k1, d_model, wi_out, dtype)["w"],
        "wo": init_dense(k2, d_ff, d_model, dtype)["w"],
    }


def mlp(params, x, *, kind: str = "swiglu"):
    h = x @ params["wi"]
    if kind == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(h)
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> np.ndarray:
    """Inverse frequencies for half the head dim (host constant)."""
    half = d_head // 2
    return 1.0 / (theta ** (np.arange(half, dtype=np.float64) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S) int32."""
    d_head = x.shape[-1]
    inv = jnp.asarray(rope_freqs(d_head, theta), dtype=jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * inv          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(d_head: int) -> Tuple[int, int, int]:
    """Qwen2-VL splits the rotary half-dim into (temporal, h, w) sections;
    128-dim heads use (16, 24, 24).  Generalized proportionally."""
    half = d_head // 2
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return t, h, w


def apply_mrope(x, positions3, theta: float):
    """M-RoPE: positions3 is (3, ..., S) — (temporal, height, width) ids.
    Each rotary-frequency section uses its own position stream."""
    d_head = x.shape[-1]
    inv = jnp.asarray(rope_freqs(d_head, theta), dtype=jnp.float32)
    sec = mrope_sections(d_head)
    # section index per frequency: 0,0,...,1,1,...,2,2,...
    sec_id = jnp.asarray(
        np.concatenate([np.full(s, i) for i, s in enumerate(sec)]), dtype=jnp.int32
    )                                                              # (half,)
    # pos: (3, ..., S) -> select per-frequency stream -> (..., S, half)
    pos = jnp.moveaxis(positions3, 0, -1)                          # (..., S, 3)
    pos_f = jnp.take(pos.astype(jnp.float32), sec_id, axis=-1)     # (..., S, half)
    ang = pos_f * inv                                              # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d_model: int) -> np.ndarray:
    """Whisper-style sinusoidal embeddings (host constant)."""
    half = d_model // 2
    freq = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    pos = np.arange(n_pos)[:, None] * freq[None, :]
    return np.concatenate([np.sin(pos), np.cos(pos)], axis=1).astype(np.float32)
