"""Model assembly: period-stacked blocks under lax.scan, all six families.

Layer heterogeneity (hybrid attn/ssm interleave, MoE-every-k) is handled by
grouping layers into **periods**: P = lcm(attn_every, moe.every).  One period
of P layers is traced once; lax.scan runs it n_layers/P times over stacked
params.  This keeps the HLO O(P) instead of O(n_layers) — essential for
compiling 64–80-layer configs at 512 devices on the dry-run host — and makes
remat policy application uniform (checkpoint around the period body).

Params are nested dicts; caches are pytrees aligned with the period
structure so prefill can emit them as scan ys and decode can consume/update
them as scan xs/ys.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .attention import KVCacheView
from .layers import embed, init_embedding, init_mlp, init_rmsnorm, mlp, rmsnorm
from .ssm import SSMState


# ---------------------------------------------------------------------------
# Period structure
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str           # "attn" | "ssm"
    mlp: Optional[str]   # "mlp" | "moe" | None (ssm family has no separate MLP)


def period_len(cfg) -> int:
    p = 1
    if cfg.family == "hybrid":
        p = cfg.attn_every
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.every)
    assert cfg.n_layers % p == 0, (cfg.n_layers, p)
    return p


def period_structure(cfg) -> List[LayerSpec]:
    """Layer specs for positions 0..P-1 of one period."""
    P = period_len(cfg)
    specs = []
    for i in range(P):
        mixer = "attn" if cfg.is_attn_layer(i) else "ssm"
        if cfg.family == "ssm" or (cfg.family == "hybrid" and mixer == "ssm" and not cfg.is_moe_layer(i)):
            m = "moe" if cfg.is_moe_layer(i) else None
        else:
            m = "moe" if cfg.is_moe_layer(i) else "mlp"
        if cfg.family == "ssm":
            m = None   # pure mamba blocks carry their own gating/MLP
        specs.append(LayerSpec(mixer=mixer, mlp=m))
    return specs


def n_blocks(cfg) -> int:
    return cfg.n_layers // period_len(cfg)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_one_layer(key, cfg, spec: LayerSpec, *, cross: bool = False):
    keys = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": init_rmsnorm(cfg.d_model, cfg.dtype)}
    if spec.mixer == "attn":
        p["attn"] = attn_mod.init_attention(keys[0], cfg)
    else:
        p["ssm"] = ssm_mod.init_ssm(keys[0], cfg)
    if cross:
        p["ln_x"] = init_rmsnorm(cfg.d_model, cfg.dtype)
        p["cross"] = attn_mod.init_attention(keys[1], cfg, cross=True)
    if spec.mlp is not None:
        p["ln2"] = init_rmsnorm(cfg.d_model, cfg.dtype)
        if spec.mlp == "moe":
            p["moe"] = moe_mod.init_moe(keys[2], cfg)
        else:
            p["mlp"] = init_mlp(keys[2], cfg.d_model, cfg.d_ff, cfg.dtype,
                                kind=cfg.mlp_kind)
    return p


def _stack(trees: List[Any]):
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def init_params(cfg, key) -> Dict[str, Any]:
    """Full parameter pytree.  Works under jax.eval_shape for the dry-run."""
    specs = period_structure(cfg)
    nb = n_blocks(cfg)
    k_embed, k_blocks, k_head, k_enc = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": init_embedding(k_embed, cfg.vocab_padded, cfg.d_model, cfg.dtype),
        "final_norm": init_rmsnorm(cfg.d_model, cfg.dtype),
    }
    cross = cfg.family == "audio"
    blocks = []
    for p, spec in enumerate(specs):
        per_block = [
            _init_one_layer(jax.random.fold_in(k_blocks, b * len(specs) + p), cfg, spec, cross=cross)
            for b in range(nb)
        ]
        blocks.append(_stack(per_block))
    params["blocks"] = blocks
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(k_head, cfg.vocab_padded, cfg.d_model, cfg.dtype)
    if cfg.family == "audio":
        enc_spec = LayerSpec(mixer="attn", mlp="mlp")
        enc_blocks = [
            _init_one_layer(jax.random.fold_in(k_enc, b), cfg, enc_spec)
            for b in range(cfg.n_enc_layers)
        ]
        params["encoder"] = {
            "blocks": [_stack(enc_blocks)],
            "final_norm": init_rmsnorm(cfg.d_model, cfg.dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Shared block application
# ---------------------------------------------------------------------------


def _sinusoid(positions, d_model: int):
    """On-the-fly sinusoidal embedding (no host table in the HLO)."""
    half = d_model // 2
    freq = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1)
    )
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _shard(x, policy, name: str):
    return policy(x, name) if policy is not None else x


def _embed(params, tokens, policy):
    """Vocab-parallel lookup when the policy provides one (distributed runs);
    plain take otherwise."""
    if policy is not None and hasattr(policy, "embed"):
        return policy.embed(params["embed"]["w"], tokens)
    return embed(params["embed"], tokens)


class FwdOut(NamedTuple):
    hidden: jax.Array
    aux: jax.Array              # MoE load-balance loss (0 for non-MoE)


def _apply_layer_train(
    lp, spec: LayerSpec, x, cfg, *, positions, impl, policy, enc_kv=None,
    causal: bool = True, prefix_kv=None,
):
    """One layer, full-sequence (train/prefill shape).  Returns
    (x, aux, kv_or_None, ssm_state_or_None).  ``prefix_kv`` threads a cached
    K/V context into the attention (shared-prefix suffix prefill)."""
    aux = jnp.float32(0.0)
    kv = None
    sstate = None
    h = rmsnorm(lp["ln1"], x, eps=cfg.norm_eps)
    if spec.mixer == "attn":
        y, kv = attn_mod.self_attention(
            lp["attn"], h, cfg, positions=positions, causal=causal, impl=impl,
            prefix_kv=prefix_kv,
        )
    else:
        y, sstate = ssm_mod.ssm_forward(lp["ssm"], h, cfg, impl=impl, return_state=True)
    x = x + _shard(_shard(y, policy, "attn_out"), policy, "residual")
    if enc_kv is not None and "cross" in lp:
        hx = rmsnorm(lp["ln_x"], x, eps=cfg.norm_eps)
        x = x + attn_mod.cross_attention(lp["cross"], hx, enc_kv, cfg, impl=impl)
    if spec.mlp is not None:
        h2 = rmsnorm(lp["ln2"], x, eps=cfg.norm_eps)
        if spec.mlp == "moe":
            y2, aux = moe_mod.moe_apply(lp["moe"], h2, cfg, decode=False,
                                        policy=policy)
        else:
            y2 = mlp(lp["mlp"], h2, kind=cfg.mlp_kind)
        x = x + _shard(_shard(y2, policy, "mlp_out"), policy, "residual")
    return x, aux, kv, sstate


def _apply_layer_decode(
    lp, spec: LayerSpec, x, cfg, *, cur_pos, kv_cache, ssm_state, cross_kv,
    impl, policy, page_table=None,
):
    """One layer, single-token decode.  Returns (x, new_kv, new_ssm)."""
    h = rmsnorm(lp["ln1"], x, eps=cfg.norm_eps)
    new_kv, new_ssm = kv_cache, ssm_state
    if spec.mixer == "attn" and page_table is not None:
        y, new_kv = attn_mod.paged_decode_attention(
            lp["attn"], h, kv_cache, cur_pos, page_table, cfg,
            impl=impl, policy=policy,
        )
    elif spec.mixer == "attn":
        y, new_kv = attn_mod.decode_attention(
            lp["attn"], h, kv_cache, cur_pos, cfg, impl=impl, policy=policy
        )
    else:
        y, new_ssm = ssm_mod.ssm_decode(lp["ssm"], h, ssm_state, cfg)
    x = x + _shard(y, policy, "attn_out")
    if cross_kv is not None and "cross" in lp:
        hx = rmsnorm(lp["ln_x"], x, eps=cfg.norm_eps)
        x = x + attn_mod.cross_attention(lp["cross"], hx, cross_kv, cfg, impl=impl)
    if spec.mlp is not None:
        h2 = rmsnorm(lp["ln2"], x, eps=cfg.norm_eps)
        if spec.mlp == "moe":
            y2, _ = moe_mod.moe_apply(lp["moe"], h2, cfg, decode=True,
                                      policy=policy)
        else:
            y2 = mlp(lp["mlp"], h2, kind=cfg.mlp_kind)
        x = x + _shard(y2, policy, "mlp_out")
    return x, new_kv, new_ssm


def _remat_wrap(fn, remat: str):
    if remat == "none":
        return fn
    pol = {
        "full": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[remat]
    return jax.checkpoint(fn, policy=pol)


# ---------------------------------------------------------------------------
# Forward (train) — also the encoder stack driver
# ---------------------------------------------------------------------------


def forward(
    params, tokens, cfg, *, positions=None, extra_embeds=None, enc_out=None,
    impl: str = "xla", policy=None, remat: str = "none", causal: bool = True,
) -> FwdOut:
    """Full-sequence forward to final hidden states.

    tokens:       (B, S_txt) int32
    extra_embeds: (B, S_vis, d) precomputed patch/frame embeddings prepended
                  to the token embeddings (VLM stub frontend).
    enc_out:      (B, S_enc, d) encoder output (audio family).
    """
    specs = period_structure(cfg)
    x = _embed(params, tokens, policy)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if cfg.family == "audio":
        pos = jnp.arange(S, dtype=jnp.int32)[None, :]
        x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    x = _shard(x, policy, "hidden")

    enc_kvs = None
    if enc_out is not None:
        # Precompute per-position cross K/V once (stacked over blocks).
        enc_kvs = []
        for p, spec in enumerate(specs):
            lp = params["blocks"][p]
            enc_kvs.append(
                jax.vmap(
                    lambda lpb: attn_mod.encode_cross_kv(lpb["cross"], enc_out, cfg)
                )(lp)
            )

    def body(carry, xs_in):
        x, aux = carry
        if enc_kvs is None:
            (block_params,) = xs_in
            ekv = [None] * len(specs)
        else:
            block_params, ekv = xs_in
        for p, spec in enumerate(specs):
            x, aux_p, _, _ = _apply_layer_train(
                block_params[p], spec, x, cfg, positions=positions, impl=impl,
                policy=policy, enc_kv=ekv[p], causal=causal,
            )
            aux = aux + aux_p
        return (x, aux), None

    body_w = _remat_wrap(body, remat)
    xs = (params["blocks"],) if enc_kvs is None else (params["blocks"], enc_kvs)
    (x, aux), _ = jax.lax.scan(body_w, (x, jnp.float32(0.0)), xs)
    x = rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
    return FwdOut(hidden=x, aux=aux)


def encoder_forward(params, frames, cfg, *, impl="xla", policy=None, remat="none"):
    """Audio encoder over stub frame embeddings (B, S_enc, d)."""
    enc = params["encoder"]
    B, S, _ = frames.shape
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    x = frames + _sinusoid(pos, cfg.d_model).astype(frames.dtype)
    spec = LayerSpec(mixer="attn", mlp="mlp")

    def body(x, block_params):
        y, _, _, _ = _apply_layer_train(
            block_params, spec, x, cfg, positions=pos, impl=impl,
            policy=policy, causal=False,
        )
        return y, None

    x, _ = jax.lax.scan(_remat_wrap(body, remat), x, enc["blocks"][0])
    return rmsnorm(enc["final_norm"], x, eps=cfg.norm_eps)


# ---------------------------------------------------------------------------
# LM head + chunked loss
# ---------------------------------------------------------------------------


def unembed_weight(params, cfg):
    w = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    return w   # (Vp, d): logits = h @ w.T


def logits_fn(params, hidden, cfg):
    return hidden @ unembed_weight(params, cfg).T


def lm_loss(
    params, hidden, labels, cfg, *, chunk: int = 1024, policy=None,
) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy over (B, S) labels; ignore label < 0.  Chunked over the
    sequence axis with lax.map so the full (B,S,V) logits tensor is never
    materialized.  Returns (sum_loss, count)."""
    w = unembed_weight(params, cfg)            # (Vp, d)
    B, S, d = hidden.shape
    ck = min(chunk, S)
    n = (S + ck - 1) // ck
    pad = n * ck - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = jnp.moveaxis(hidden.reshape(B, n, ck, d), 1, 0)    # (n, B, ck, d)
    lc = jnp.moveaxis(labels.reshape(B, n, ck), 1, 0)

    def one(args):
        h, l = args
        logits = (h @ w.T).astype(jnp.float32)              # (B, ck, Vp)
        logits = _shard(logits, policy, "logits")
        lz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1
        )[..., 0]
        valid = l >= 0
        return (
            jnp.where(valid, lz - gold, 0.0).sum(),
            valid.sum(),
        )

    sums, counts = jax.lax.map(one, (hc, lc))
    return sums.sum(), counts.sum()


# ---------------------------------------------------------------------------
# Prefill and decode
# ---------------------------------------------------------------------------


class Caches(NamedTuple):
    """Decode-time state, aligned with the period structure.

    kv:    {str(p): KVCacheView stacked over blocks}   (attn positions)
    ssm:   {str(p): SSMState stacked over blocks}      (ssm positions)
    cross: {str(p): (k, v) stacked over blocks} | None (audio)
    """

    kv: Dict[str, KVCacheView]
    ssm: Dict[str, SSMState]
    cross: Optional[Dict[str, Tuple[jax.Array, jax.Array]]] = None


def init_caches(cfg, batch: int, max_len: int) -> Caches:
    specs = period_structure(cfg)
    nb = n_blocks(cfg)
    kv, ssm = {}, {}
    for p, spec in enumerate(specs):
        if spec.mixer == "attn":
            one = attn_mod.init_kv_cache(cfg, batch, max_len)
            kv[str(p)] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (nb,) + a.shape).copy(), one
            )
        else:
            one = ssm_mod.init_ssm_state(cfg, batch)
            ssm[str(p)] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (nb,) + a.shape).copy(), one
            )
    cross = None
    if cfg.family == "audio":
        # cross-attention K/V over the encoder output (seeded by prefill)
        cross = {
            str(p): (
                jnp.zeros((nb, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.d_head),
                          dtype=jnp.dtype(cfg.dtype)),
                jnp.zeros((nb, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.d_head),
                          dtype=jnp.dtype(cfg.dtype)),
            )
            for p in range(len(specs))
        }
    return Caches(kv=kv, ssm=ssm, cross=cross)


def init_paged_caches(cfg, batch: int, n_pages: int, page_size: int) -> Caches:
    """Decode caches with the attention layers backed by one shared page
    pool (:class:`~repro.models.attention.PagedKVView`) instead of per-slot
    dense buffers.  SSM and cross-attention state stay dense per slot —
    they are fixed-size per sequence, so there is nothing to page.  The
    per-slot page *table* lives with the slot bookkeeping
    (``serving.engine.PageState``), not in the cache tree."""
    specs = period_structure(cfg)
    nb = n_blocks(cfg)
    kv, ssm = {}, {}
    for p, spec in enumerate(specs):
        if spec.mixer == "attn":
            one = attn_mod.init_paged_kv_cache(cfg, n_pages, page_size)
            kv[str(p)] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (nb,) + a.shape).copy(), one
            )
        else:
            one = ssm_mod.init_ssm_state(cfg, batch)
            ssm[str(p)] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (nb,) + a.shape).copy(), one
            )
    cross = None
    if cfg.family == "audio":
        cross = {
            str(p): (
                jnp.zeros((nb, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.d_head),
                          dtype=jnp.dtype(cfg.dtype)),
                jnp.zeros((nb, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.d_head),
                          dtype=jnp.dtype(cfg.dtype)),
            )
            for p in range(len(specs))
        }
    return Caches(kv=kv, ssm=ssm, cross=cross)


def prefill(
    params, tokens, cfg, *, max_len: int, positions=None, extra_embeds=None,
    enc_out=None, impl: str = "xla", policy=None, remat: str = "none",
):
    """Run the full prompt, returning (last-token logits, seeded Caches).

    The KV buffers are sized ``min(max_len, window)``; prompt K/V are
    scattered in ring-buffer order (see serving.kv_cache.seed_cache).
    """
    from repro.serving.kv_cache import seed_kv_cache, seed_ssm_state

    specs = period_structure(cfg)
    x = _embed(params, tokens, policy)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if cfg.family == "audio":
        pos0 = jnp.arange(S, dtype=jnp.int32)[None, :]
        x = x + _sinusoid(pos0, cfg.d_model).astype(x.dtype)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    x = _shard(x, policy, "hidden")

    enc_kvs = None
    if enc_out is not None:
        enc_kvs = []
        for p, spec in enumerate(specs):
            lp = params["blocks"][p]
            enc_kvs.append(
                jax.vmap(
                    lambda lpb: attn_mod.encode_cross_kv(lpb["cross"], enc_out, cfg)
                )(lp)
            )

    def body(carry, xs_in):
        x = carry
        if enc_kvs is None:
            (block_params,) = xs_in
            ekv = [None] * len(specs)
        else:
            block_params, ekv = xs_in
        outs = {}
        for p, spec in enumerate(specs):
            x, _, kv, sstate = _apply_layer_train(
                block_params[p], spec, x, cfg, positions=positions, impl=impl,
                policy=policy, enc_kv=ekv[p], causal=True,
            )
            outs[str(p)] = kv if spec.mixer == "attn" else sstate
        return x, outs

    body_w = _remat_wrap(body, remat)
    xs = (params["blocks"],) if enc_kvs is None else (params["blocks"], enc_kvs)
    x, ys = jax.lax.scan(body_w, x, xs)
    x = rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
    last = x[:, -1:, :]
    logits = logits_fn(params, last, cfg)[:, 0]

    kv, ssm = {}, {}
    for p, spec in enumerate(specs):
        if spec.mixer == "attn":
            k, v = ys[str(p)]
            kv[str(p)] = seed_kv_cache(cfg, k, v, max_len=max_len, seq_positions=positions)
        else:
            ssm[str(p)] = seed_ssm_state(ys[str(p)])
    cross = None
    if enc_kvs is not None:
        cross = {str(p): enc_kvs[p] for p in range(len(specs))}
    return logits, Caches(kv=kv, ssm=ssm, cross=cross)


def prefix_prefill(
    params, tokens, prefix_kv, cfg, *, prefix_len: int, impl: str = "xla",
    policy=None,
):
    """Suffix prefill against a cached prompt prefix (shared-prefix
    admission): run only the uncached tail of the prompt, attending to the
    per-layer prefix K/V gathered from the paged pool.

    tokens:     (B, S_suffix) int32 — the prompt tail, absolute positions
                ``prefix_len + [0, S_suffix)``.
    prefix_kv:  {str(p): (k, v)} with k/v (nb, B, prefix_len, Hkv, dh) —
                the cached pages' contents, one entry per period position.

    Returns (last-token logits (B, Vp), {str(p): (k, v)}) where the output
    K/V cover only the suffix, in absolute-position order (ready for page
    packing).  Because causal attention makes the suffix rows independent
    of whether the prefix was recomputed or read back, this reproduces the
    cold ``prefill``'s suffix exactly (bit-for-bit when the cache dtype is
    the compute dtype — the page store's dtype cast is the only lossy step).

    Pure-attention archs only: an SSM layer's post-prompt state depends on
    every prompt token (nothing positional to cache), and audio/VLM prompts
    carry non-token context that shifts positions.
    """
    specs = period_structure(cfg)
    if any(s.mixer != "attn" for s in specs):
        raise ValueError(
            "prefix_prefill requires a pure-attention arch (SSM state is "
            "not positional — there is no per-page prefix to reuse)")
    if cfg.family in ("audio", "vlm"):
        raise ValueError(
            f"prefix_prefill does not support the {cfg.family} family")
    x = _embed(params, tokens, policy)
    B, S, _ = x.shape
    positions = prefix_len + jnp.arange(S, dtype=jnp.int32)[None, :]
    x = _shard(x, policy, "hidden")

    def body(x, xs_in):
        block_params, pkv = xs_in
        outs = {}
        for p, spec in enumerate(specs):
            x, _, kv, _ = _apply_layer_train(
                block_params[p], spec, x, cfg, positions=positions, impl=impl,
                policy=policy, causal=True, prefix_kv=pkv[str(p)],
            )
            outs[str(p)] = kv
        return x, outs

    x, ys = jax.lax.scan(body, x, (params["blocks"], prefix_kv))
    x = rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = logits_fn(params, x[:, -1:, :], cfg)[:, 0]
    return logits, ys


def decode_step(
    params, tokens, caches: Caches, cur_pos, cfg, *, impl: str = "xla",
    policy=None, page_table=None,
):
    """One decode step.  tokens: (B,) int32; cur_pos: (B,) absolute position.
    Returns (logits (B, Vp), updated Caches).

    With ``page_table`` (B, max_pages) the attention caches are treated as
    paged pools (:func:`init_paged_caches`); the table is read-only here —
    page allocation happens in the caller (chunk scan body or admission).
    """
    specs = period_structure(cfg)
    x = _embed(params, tokens, policy)[:, None, :]     # (B, 1, d)
    if cfg.family == "audio":
        x = x + _sinusoid(cur_pos[:, None], cfg.d_model).astype(x.dtype)
    x = _shard(x, policy, "hidden_decode")

    have_cross = caches.cross is not None and len(caches.cross) > 0

    def body(x, xs_in):
        if have_cross:
            block_params, kv_in, ssm_in, cross_in = xs_in
        else:
            block_params, kv_in, ssm_in = xs_in
            cross_in = {}
        kv_out, ssm_out = {}, {}
        for p, spec in enumerate(specs):
            x, nkv, nssm = _apply_layer_decode(
                block_params[p], spec, x, cfg, cur_pos=cur_pos,
                kv_cache=kv_in.get(str(p)), ssm_state=ssm_in.get(str(p)),
                cross_kv=cross_in.get(str(p)), impl=impl, policy=policy,
                page_table=page_table,
            )
            if spec.mixer == "attn":
                kv_out[str(p)] = nkv
            else:
                ssm_out[str(p)] = nssm
        return x, (kv_out, ssm_out)

    xs = (params["blocks"], caches.kv, caches.ssm)
    if have_cross:
        xs = xs + (caches.cross,)
    x, (kv_new, ssm_new) = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = logits_fn(params, x, cfg)[:, 0]
    return logits, Caches(kv=kv_new, ssm=ssm_new, cross=caches.cross)


def verify_step(
    params, tokens, caches: Caches, cur_pos, cfg, *, impl: str = "xla",
    policy=None, page_table=None, write_limit=None,
):
    """Score a window of W candidate tokens in one pass (draft-and-verify).

    tokens: (B, W) int32 — the slot's last committed token followed by
    W-1 drafted candidates, at absolute positions ``cur_pos + [0, W)``.
    Returns (logits (B, W, Vp), updated Caches): ``logits[:, j]`` is the
    model's next-token distribution *after* ``tokens[:, j]``, exactly what
    ``decode_step`` would produce having decoded the window prefix — the
    accepted-prefix outputs are identical to sequential greedy decode
    because causal attention makes each query row depend only on positions
    ``<= cur_pos + j`` (the verify attention writes the window's K/V
    before attending, so within-window causality falls out of the
    position-validity mask).

    With ``page_table`` the caches are paged pools and every window
    position must have its logical page mapped by the caller (unmapped
    positions write to the trash page); without it, ``write_limit`` (B,)
    caps how many window writes stick in the dense ring (see
    :func:`repro.models.attention.verify_decode_attention`).

    Pure-attention, non-sliding-window archs only: SSM state advances
    sequentially and cannot be rolled back for free, and audio/vlm prompts
    carry non-token context.  MoE layers are fine — decode routing is
    per-token.
    """
    specs = period_structure(cfg)
    if any(s.mixer != "attn" for s in specs):
        raise ValueError(
            "verify_step requires a pure-attention arch (SSM state cannot "
            "be rolled back to the accepted prefix)")
    if cfg.family in ("audio", "vlm"):
        raise ValueError(
            f"verify_step does not support the {cfg.family} family")
    if cfg.sliding_window:
        raise ValueError("verify_step does not support sliding-window archs")
    x = _embed(params, tokens, policy)                  # (B, W, d)
    x = _shard(x, policy, "hidden_decode")

    def body(x, xs_in):
        block_params, kv_in = xs_in
        kv_out = {}
        for p, spec in enumerate(specs):
            lp = block_params[p]
            h = rmsnorm(lp["ln1"], x, eps=cfg.norm_eps)
            if page_table is not None:
                y, nkv = attn_mod.paged_verify_attention(
                    lp["attn"], h, kv_in[str(p)], cur_pos, page_table, cfg,
                    impl=impl, policy=policy,
                )
            else:
                y, nkv = attn_mod.verify_decode_attention(
                    lp["attn"], h, kv_in[str(p)], cur_pos, cfg, impl=impl,
                    policy=policy, write_limit=write_limit,
                )
            kv_out[str(p)] = nkv
            x = x + _shard(y, policy, "attn_out")
            if spec.mlp is not None:
                h2 = rmsnorm(lp["ln2"], x, eps=cfg.norm_eps)
                if spec.mlp == "moe":
                    y2, _ = moe_mod.moe_apply(lp["moe"], h2, cfg, decode=True,
                                              policy=policy)
                else:
                    y2 = mlp(lp["mlp"], h2, kind=cfg.mlp_kind)
                x = x + _shard(y2, policy, "mlp_out")
        return x, kv_out

    x, kv_new = jax.lax.scan(body, x, (params["blocks"], caches.kv))
    x = rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = logits_fn(params, x, cfg)                  # (B, W, Vp)
    return logits, Caches(kv=kv_new, ssm=caches.ssm, cross=caches.cross)
