"""Attention: GQA + qk_norm + RoPE/M-RoPE + sliding window + KV cache decode.

Three compute paths, selected by ``impl``:

* ``"xla"``     — chunked online-softmax attention in pure JAX (lax.scan over
  KV blocks).  This is the default for lowering/dry-run: peak memory is
  O(S·block) instead of O(S²), and the HLO stays small.  It is also the
  numerical oracle for the Pallas kernel.
* ``"pallas"``  — Pallas TPU kernels, validated in interpret mode: dense
  prefill (``repro.kernels.flash_attention``), dense decode
  (``repro.kernels.decode_attention``), paged decode
  (``repro.kernels.paged_attention`` — walks the page table inside the
  kernel), and prefix-context prefill (``repro.kernels.prefix_attention``
  — attends to cached-prefix + fresh-suffix K/V without the concat).
* ``"naive"``   — materialized-scores einsum, used only by tiny tests.

Which impl is legal for which mode is owned by :data:`ATTN_CAPABILITIES`
(checked at serving-config/batcher construction via
:func:`check_attn_impl`, so a bad combination fails at build time, not
three layers deep in a jit trace).

Decode (single new token against a KV cache) uses a separate path; the
sliding-window archs keep a **ring-buffer** cache of ``min(S, window)`` slots
(the O(window) memory claim that makes long_500k runnable for Mixtral).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import apply_mrope, apply_rope, init_dense, init_rmsnorm, rmsnorm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Capability table: which attn impl is legal for which execution mode
# ---------------------------------------------------------------------------
#
# The single source of truth for impl × mode support.  Serving configs and
# the batcher validate against this at construction, replacing the
# NotImplementedErrors that used to fire three layers deep inside a traced
# decode step.  Modes:
#   train           — differentiable prefill (self_attention under grad)
#   dense           — prefill + dense-cache decode
#   paged           — paged-pool decode (block-granular KV virtualization)
#   prefix          — suffix prefill against cached prefix K/V
#   sliding_window  — any path on a sliding-window arch
#   verify          — multi-query draft verification (speculative decode);
#                     "pallas" rides the paged multi-query kernel in paged
#                     mode and falls back to the XLA multi-query path on
#                     dense caches

ATTN_CAPABILITIES = {
    "train": ("xla", "flash", "pallas", "naive"),
    "dense": ("xla", "pallas", "naive"),
    "paged": ("xla", "pallas"),
    "prefix": ("xla", "pallas", "naive"),
    "sliding_window": ("xla", "pallas", "naive", "flash"),
    "verify": ("xla", "pallas"),
}


def check_attn_impl(impl: str, mode: str) -> str:
    """Validate ``impl`` against :data:`ATTN_CAPABILITIES` for ``mode``.

    Returns ``impl`` unchanged on success so callers can validate inline;
    raises ``ValueError`` naming the mode and the supported impls otherwise.
    """
    try:
        supported = ATTN_CAPABILITIES[mode]
    except KeyError:
        raise ValueError(
            f"unknown attention mode {mode!r}; "
            f"expected one of {sorted(ATTN_CAPABILITIES)}") from None
    if impl not in supported:
        raise ValueError(
            f"attn_impl={impl!r} is not supported for mode {mode!r}; "
            f"supported: {supported}")
    return impl


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(key, cfg, *, cross: bool = False):
    """cfg: ModelConfig.  ``cross=True`` builds encoder-decoder cross-attn
    (no qk_norm, kv over encoder states)."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": init_dense(kq, cfg.d_model, cfg.q_dim, cfg.dtype)["w"],
        "wk": init_dense(kk, cfg.d_model, cfg.kv_dim, cfg.dtype)["w"],
        "wv": init_dense(kv, cfg.d_model, cfg.kv_dim, cfg.dtype)["w"],
        "wo": init_dense(ko, cfg.q_dim, cfg.d_model, cfg.dtype)["w"],
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = init_rmsnorm(cfg.d_head, cfg.dtype)
        p["k_norm"] = init_rmsnorm(cfg.d_head, cfg.dtype)
    return p


# ---------------------------------------------------------------------------
# Core attention math (shared by all impls)
# ---------------------------------------------------------------------------


def _project_qkv(params, x, cfg, *, positions=None, rope: bool = True):
    """x: (B, S, D) -> q (B,S,H,dh), k/v (B,S,Hkv,dh), rope applied."""
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
    k = (x @ params["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = (x @ params["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm and "q_norm" in params:
        q = rmsnorm(params["q_norm"], q, eps=cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, eps=cfg.norm_eps)
    if rope and cfg.rope_theta > 0:
        if positions is None:
            positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        if cfg.m_rope:
            if positions.ndim == 2:   # plain (B,S) ids (e.g. text-only decode):
                positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
            q = apply_mrope(q, positions, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k, n_heads: int):
    """(B,S,Hkv,dh) -> (B,S,H,dh) by repeating each kv head (GQA)."""
    B, S, Hkv, dh = k.shape
    group = n_heads // Hkv
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, Hkv, group, dh)).reshape(
        B, S, n_heads, dh
    )


def naive_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                    q_offset: int = 0):
    """Materialized-scores reference.  q: (B,Sq,H,dh); k,v: (B,Sk,Hkv,dh)."""
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / jnp.sqrt(
        jnp.float32(dh)
    )
    qi = jnp.arange(Sq)[:, None] + q_offset
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
    return out


def chunked_flash_attention(
    q, k, v, *, causal: bool, window: Optional[int] = None,
    q_offset: int = 0, block_k: int = 512,
):
    """Online-softmax attention, lax.scan over KV blocks (pure JAX "flash").

    Peak memory O(B·H·Sq·block_k) — this is what lets 32k-prefill cells lower
    without an O(S²) score buffer.  Also the oracle for the Pallas kernel.
    """
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    block_k = min(block_k, Sk)
    n_blocks = (Sk + block_k - 1) // block_k
    pad = n_blocks * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    group = H // Hkv
    # (B, nb, bk, Hkv, dh)
    kb = k.reshape(B, n_blocks, block_k, Hkv, dh)
    vb = v.reshape(B, n_blocks, block_k, Hkv, dh)
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))
    qg = qf.reshape(B, Sq, Hkv, group, dh)

    qi = jnp.arange(Sq, dtype=jnp.int32) + q_offset          # (Sq,)

    def body(carry, xs):
        m, l, acc = carry                                     # (B,Sq,Hkv,g), ..., (B,Sq,Hkv,g,dh)
        kc, vc, blk = xs                                      # (B,bk,Hkv,dh) x2, scalar
        ki = blk * block_k + jnp.arange(block_k, dtype=jnp.int32)
        s = jnp.einsum("bqgid,bkgd->bqgik", qg, kc.astype(jnp.float32))
        valid = ki[None, :] < Sk
        mask = jnp.broadcast_to(valid, (Sq, block_k))
        if causal:
            mask = mask & (ki[None, :] <= qi[:, None])
        if window is not None:
            mask = mask & (ki[None, :] > qi[:, None] - window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + p.sum(axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bqgik,bkgd->bqgid", p, vc.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, group), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, group), dtype=jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, group, dh), dtype=jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)                             # (nb, B, bk, Hkv, dh)
    vb_t = jnp.moveaxis(vb, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb_t, vb_t, jnp.arange(n_blocks, dtype=jnp.int32))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash attention with a custom VJP (blockwise-recompute backward)
# ---------------------------------------------------------------------------
#
# The plain chunked attention above is correct but TRAINS badly: jax.grad
# through the lax.scan saves each block's (B,Sq,Hkv,g,block_k) f32 residuals
# (probabilities/scores), resurrecting the O(Sq·Sk) memory/traffic that
# flash attention exists to avoid — measured as the dominant HLO-bytes term
# of every train/prefill cell in the baseline roofline (EXPERIMENTS.md
# §Perf).  The custom VJP saves only (q, k, v, out, LSE) and recomputes each
# block's probabilities in the backward pass — the FlashAttention backward —
# making train-time attention memory O(S·block) for real.


def _flash_fwd_lse(q, k, v, *, causal, window, q_offset, block_k):
    """Forward pass that also returns the log-sum-exp (B,Sq,Hkv,g)."""
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    block_k = min(block_k, Sk)
    n_blocks = (Sk + block_k - 1) // block_k
    pad = n_blocks * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    group = H // Hkv
    kb = jnp.moveaxis(k.reshape(B, n_blocks, block_k, Hkv, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, n_blocks, block_k, Hkv, dh), 1, 0)
    qg = (q.astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))).reshape(B, Sq, Hkv, group, dh)
    qi = jnp.arange(Sq, dtype=jnp.int32) + q_offset

    def mask_for(blk):
        ki = blk * block_k + jnp.arange(block_k, dtype=jnp.int32)
        m = jnp.broadcast_to(ki[None, :] < Sk, (Sq, block_k))
        if causal:
            m = m & (ki[None, :] <= qi[:, None])
        if window is not None:
            m = m & (ki[None, :] > qi[:, None] - window)
        return m

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, blk = xs
        s = jnp.einsum("bqgid,bkgd->bqgik", qg, kc.astype(jnp.float32))
        s = jnp.where(mask_for(blk)[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + p.sum(axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bqgik,bkgd->bqgid", p, vc.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, group), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, group), dtype=jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, group, dh), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(n_blocks, dtype=jnp.int32))
    )
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).reshape(B, Sq, H, dh).astype(q.dtype)
    lse = m + jnp.log(l_safe)                        # (B,Sq,Hkv,g)
    return out, lse


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def flash_attention_vjp(q, k, v, causal, window, q_offset, block_k):
    out, _ = _flash_fwd_lse(q, k, v, causal=causal, window=window,
                            q_offset=q_offset, block_k=block_k)
    return out


def _fa_vjp_fwd(q, k, v, causal, window, q_offset, block_k):
    out, lse = _flash_fwd_lse(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, block_k=block_k)
    return out, (q, k, v, out, lse)


def _fa_vjp_bwd(causal, window, q_offset, block_k, res, do):
    q, k, v, out, lse = res
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    group = H // Hkv
    bk = min(block_k, Sk)
    n_blocks = (Sk + bk - 1) // bk
    pad = n_blocks * bk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sm = 1.0 / jnp.sqrt(jnp.float32(dh))
    qg = q.astype(jnp.float32).reshape(B, Sq, Hkv, group, dh)
    dog = do.astype(jnp.float32).reshape(B, Sq, Hkv, group, dh)
    og = out.astype(jnp.float32).reshape(B, Sq, Hkv, group, dh)
    # D_i = rowsum(dO * O)  — the softmax-correction term
    D = jnp.sum(dog * og, axis=-1)                   # (B,Sq,Hkv,g)
    kb = jnp.moveaxis(k.reshape(B, n_blocks, bk, Hkv, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, n_blocks, bk, Hkv, dh), 1, 0)
    qi = jnp.arange(Sq, dtype=jnp.int32) + q_offset

    def body(dq_acc, xs):
        kc, vc, blk = xs                              # (B,bk,Hkv,dh)
        ki = blk * bk + jnp.arange(bk, dtype=jnp.int32)
        mask = jnp.broadcast_to(ki[None, :] < Sk, (Sq, bk))
        if causal:
            mask = mask & (ki[None, :] <= qi[:, None])
        if window is not None:
            mask = mask & (ki[None, :] > qi[:, None] - window)
        s = jnp.einsum("bqgid,bkgd->bqgik", qg * sm, kc.astype(jnp.float32))
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])               # recomputed probs
        dv = jnp.einsum("bqgik,bqgid->bkgd", p, dog)  # (B,bk,Hkv,dh)
        dp = jnp.einsum("bqgid,bkgd->bqgik", dog, vc.astype(jnp.float32))
        ds = p * (dp - D[..., None]) * sm
        dq_acc = dq_acc + jnp.einsum("bqgik,bkgd->bqgid", ds, kc.astype(jnp.float32))
        dk = jnp.einsum("bqgik,bqgid->bkgd", ds, qg)  # (B,bk,Hkv,dh)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((B, Sq, Hkv, group, dh), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(
        body, dq0, (kb, vb, jnp.arange(n_blocks, dtype=jnp.int32))
    )
    dk = jnp.moveaxis(dk_b, 0, 1).reshape(B, n_blocks * bk, Hkv, dh)[:, :Sk]
    dv = jnp.moveaxis(dv_b, 0, 1).reshape(B, n_blocks * bk, Hkv, dh)[:, :Sk]
    return (
        dq.reshape(B, Sq, H, dh).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )


flash_attention_vjp.defvjp(_fa_vjp_fwd, _fa_vjp_bwd)


def flash_attention_train(q, k, v, *, causal: bool = True,
                          window: Optional[int] = None, q_offset: int = 0,
                          block_k: int = 512):
    """Differentiable flash attention (blockwise-recompute backward)."""
    return flash_attention_vjp(q, k, v, causal, window, q_offset,
                               min(block_k, k.shape[1]))


# ---------------------------------------------------------------------------
# Full layers
# ---------------------------------------------------------------------------


def self_attention(
    params, x, cfg, *, positions=None, causal: bool = True,
    impl: str = "xla", q_offset: int = 0, block_k: int = 512,
    prefix_kv=None,
):
    """Training/prefill self-attention.  Returns (out, (k, v)) so prefill can
    seed the KV cache.

    ``prefix_kv=(pk, pv)`` prepends an already-computed K/V context of
    length ``Lp`` (shared-prefix admission: the cached prompt pages): the
    queries attend to ``[prefix; self]`` with the causal mask offset by
    ``Lp``, which is exactly rows ``[Lp:]`` of the full-sequence causal
    attention — so a suffix prefill over the same tokens/positions
    reproduces the cold prefill's suffix rows.  Callers must pass
    ``positions`` already offset by ``Lp``; the returned (k, v) cover only
    the fresh suffix.  Requires a non-windowed arch (the prefix would fall
    out of a sliding window anyway)."""
    q, k, v = _project_qkv(params, x, cfg, positions=positions)
    if prefix_kv is not None:
        if cfg.sliding_window:
            raise ValueError("prefix_kv requires a non-sliding-window arch")
        pk, pv = prefix_kv
        Lp = pk.shape[1]
        if impl == "pallas":
            from repro.kernels.prefix_attention import ops as pfx_ops

            # prefix and suffix K/V stay separate operands — the kernel
            # streams both phases over one grid axis; no concat copy
            out = pfx_ops.prefix_flash_attention(
                q, pk.astype(k.dtype), pv.astype(v.dtype), k, v,
                q_offset=q_offset)
        else:
            k_att = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
            v_att = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
            if impl == "naive":
                out = naive_attention(q, k_att, v_att, causal=causal,
                                      q_offset=q_offset + Lp)
            else:
                out = chunked_flash_attention(q, k_att, v_att, causal=causal,
                                              q_offset=q_offset + Lp,
                                              block_k=block_k)
        B, S, _, _ = q.shape
        y = out.reshape(B, S, cfg.q_dim) @ params["wo"]
        return y, (k, v)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(
            q, k, v, causal=causal, window=cfg.sliding_window, q_offset=q_offset
        )
    elif impl == "naive":
        out = naive_attention(q, k, v, causal=causal, window=cfg.sliding_window,
                              q_offset=q_offset)
    elif impl == "flash":
        # custom-VJP path: O(S·block) memory THROUGH the backward pass
        out = flash_attention_train(
            q, k, v, causal=causal, window=cfg.sliding_window,
            q_offset=q_offset, block_k=block_k,
        )
    else:
        out = chunked_flash_attention(
            q, k, v, causal=causal, window=cfg.sliding_window,
            q_offset=q_offset, block_k=block_k,
        )
    B, S, _, _ = q.shape
    y = out.reshape(B, S, cfg.q_dim) @ params["wo"]
    return y, (k, v)


def cross_attention(params, x, enc_kv, cfg, *, impl: str = "xla"):
    """Decoder cross-attention over precomputed encoder (k, v)."""
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
    k, v = enc_kv
    if impl == "naive":
        out = naive_attention(q, k, v, causal=False)
    else:
        out = chunked_flash_attention(q, k, v, causal=False)
    return out.reshape(B, S, cfg.q_dim) @ params["wo"]


def encode_cross_kv(params, enc_out, cfg):
    """Precompute cross-attention K/V from encoder output (once per request)."""
    B, S, _ = enc_out.shape
    k = (enc_out @ params["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = (enc_out @ params["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    return k, v


# ---------------------------------------------------------------------------
# Decode path (one new token vs. KV cache)
# ---------------------------------------------------------------------------


class KVCacheView(NamedTuple):
    """One layer's cache: ring buffer when the arch has a sliding window.

    k, v:  (B, C, Hkv, dh) with C = min(max_len, window or max_len)
    pos:   (B, C) int32 — absolute position stored in each slot (-1 = empty)
    """

    k: jax.Array
    v: jax.Array
    pos: jax.Array


def decode_attention(
    params, x, cache: KVCacheView, cur_pos, cfg, *, impl: str = "xla",
    policy=None,
):
    """x: (B, 1, D); cur_pos: (B,) absolute position of the new token.

    Returns (out (B,1,D), updated cache).  The new token's K/V is written at
    slot ``cur_pos % C`` (ring buffer ≡ plain buffer when C == max_len).

    When the cache-length axis is model-sharded (kv heads don't divide the
    axis), the slot write goes through ``policy.kv_slot_update`` — a
    partial-manual shard_map masked write — instead of a scatter that GSPMD
    can only implement by resharding the whole cache.
    """
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(
        params, x, cfg, positions=cur_pos[:, None], rope=True
    )                                                          # q: (B,1,H,dh)
    C = cache.k.shape[1]
    # RoPE computes in f32 — cast BEFORE the slot write, or `.at[].set`
    # promotes the whole cache to f32 and every decode step round-trips the
    # full stacked cache through converts (measured 2×279 GB/step/device on
    # command-r decode_32k — EXPERIMENTS.md §Perf iteration D3).
    k_new = k_new.astype(cache.k.dtype)
    v_new = v_new.astype(cache.v.dtype)

    if policy is not None and getattr(policy, "kv_len_sharded", False):
        k, v, pos = policy.kv_slot_update(
            cache.k, cache.v, cache.pos, k_new[:, 0], v_new[:, 0], cur_pos
        )
    else:
        slot = (cur_pos % C).astype(jnp.int32)                 # (B,)
        bidx = jnp.arange(B)
        k = cache.k.at[bidx, slot].set(k_new[:, 0])
        v = cache.v.at[bidx, slot].set(v_new[:, 0])
        pos = cache.pos.at[bidx, slot].set(cur_pos.astype(jnp.int32))

    if impl == "pallas":
        from repro.kernels.decode_attention import ops as da_ops

        out = da_ops.decode_attention(
            q[:, 0], k, v, pos, cur_pos, window=cfg.sliding_window
        )[:, None]
    else:
        out = _decode_attn_xla(q, k, v, pos, cur_pos, cfg)
    y = out.reshape(B, 1, cfg.q_dim) @ params["wo"]
    return y, KVCacheView(k=k, v=v, pos=pos)


def _decode_attn_xla(q, k, v, pos, cur_pos, cfg):
    """q: (B,1,H,dh); k/v: (B,C,Hkv,dh); pos: (B,C); cur_pos: (B,).

    K/V stay in cache dtype; the contractions accumulate in f32 via
    ``preferred_element_type`` — materializing ``k.astype(f32)`` copies the
    whole cache every layer (measured ~26 GB/step/device on command-r
    decode_32k before this change, EXPERIMENTS.md §Perf)."""
    B, _, H, dh = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = (q.reshape(B, Hkv, group, dh) / jnp.sqrt(jnp.float32(dh))).astype(q.dtype)
    s = jnp.einsum("bgid,bkgd->bgik", qg, k,
                   preferred_element_type=jnp.float32)             # (B,Hkv,g,C)
    valid = (pos >= 0) & (pos <= cur_pos[:, None])
    if cfg.sliding_window is not None:
        valid &= pos > (cur_pos[:, None] - cfg.sliding_window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgik,bkgd->bgid", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


def init_kv_cache(cfg, batch: int, max_len: int, *, dtype=None) -> KVCacheView:
    """Cache for ONE attention layer.  Ring-buffer length = min(max_len,
    window) for sliding-window archs — the O(window) decode-memory property."""
    C = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    dt = jnp.dtype(dtype or cfg.dtype)
    return KVCacheView(
        k=jnp.zeros((batch, C, cfg.n_kv_heads, cfg.d_head), dtype=dt),
        v=jnp.zeros((batch, C, cfg.n_kv_heads, cfg.d_head), dtype=dt),
        pos=jnp.full((batch, C), -1, dtype=jnp.int32),
    )


# ---------------------------------------------------------------------------
# Paged decode path (block-granular KV virtualization)
# ---------------------------------------------------------------------------


class PagedKVView(NamedTuple):
    """One layer's cache as a shared pool of fixed-size pages.

    k, v: (n_pages + 1, page_size, Hkv, dh) — one extra *trash* page at
    index ``n_pages`` that absorbs writes from slots with no mapping
    (inactive, page-fault denied).  Which pool page holds which slot's
    tokens lives outside the view, in the per-slot **page table**
    (B, max_pages) int32 where entry j maps the slot's logical page j
    (absolute positions [j*page_size, (j+1)*page_size)) to a physical
    page id, -1 = unmapped.

    No per-token ``pos`` array is needed: paged placement is
    position-indexed by construction — logical page j, offset o *is*
    absolute position j*page_size + o — so validity of a gathered key is
    ``page mapped and position <= cur_pos``.  (A slot only ever attends
    to positions it has itself written since acquiring the page, so
    stale contents of recycled pages can never leak across slots.)
    """

    k: jax.Array
    v: jax.Array

    @property
    def n_pages(self) -> int:
        return self.k.shape[0] - 1

    @property
    def page_size(self) -> int:
        return self.k.shape[1]


def init_paged_kv_cache(cfg, n_pages: int, page_size: int, *, dtype=None) -> PagedKVView:
    """Page pool for ONE attention layer (+1 trash page).  Paging assumes a
    full-length cache, i.e. no sliding-window ring (the ring would recycle
    *within* a slot; pages recycle *across* slots)."""
    if cfg.sliding_window:
        raise ValueError("paged KV does not support sliding-window archs")
    dt = jnp.dtype(dtype or cfg.dtype)
    return PagedKVView(
        k=jnp.zeros((n_pages + 1, page_size, cfg.n_kv_heads, cfg.d_head), dtype=dt),
        v=jnp.zeros((n_pages + 1, page_size, cfg.n_kv_heads, cfg.d_head), dtype=dt),
    )


def paged_decode_attention(params, x, cache: PagedKVView, cur_pos, page_table,
                           cfg, *, impl: str = "xla", policy=None):
    """Single-token decode against a paged pool.

    x: (B, 1, D); cur_pos: (B,) absolute position of the new token;
    page_table: (B, max_pages) int32 physical page per logical page.

    The new token's K/V is written at (page_table[b, cur_pos // ps],
    cur_pos % ps); unmapped slots write to the trash page.

    ``impl="xla"`` gathers the slot's pages into a
    (B, max_pages*ps, Hkv, dh) view before attending — the pool bytes
    twice (gather copy + attention read).  ``impl="pallas"``
    (``repro.kernels.paged_attention``) walks the page table inside the
    kernel instead: the table rides in as a scalar-prefetch operand and
    becomes the DMA schedule, so only the mapped pages' bytes move, once.
    The XLA path stays as the numerical oracle.

    The length-sharded ``kv_slot_update`` policy hook is
    dense-cache-only and is rejected loudly instead of silently falling
    back.
    """
    if policy is not None and getattr(policy, "kv_len_sharded", False):
        raise NotImplementedError(
            "paged decode does not support a length-sharded KV cache")
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(
        params, x, cfg, positions=cur_pos[:, None], rope=True
    )
    P = cache.n_pages
    ps = cache.page_size
    k_new = k_new.astype(cache.k.dtype)
    v_new = v_new.astype(cache.v.dtype)

    cur_pos = cur_pos.astype(jnp.int32)
    logical = cur_pos // ps                                    # (B,)
    pid = jnp.take_along_axis(page_table, logical[:, None], axis=1)[:, 0]
    dest = jnp.where(pid >= 0, pid, P)                         # trash if unmapped
    off = cur_pos % ps
    k = cache.k.at[dest, off].set(k_new[:, 0])
    v = cache.v.at[dest, off].set(v_new[:, 0])

    if impl == "pallas":
        from repro.kernels.paged_attention import ops as pa_ops

        out = pa_ops.paged_decode_attention(
            q[:, 0], k, v, page_table, cur_pos)[:, None]
    else:
        gather = jnp.where(page_table >= 0, page_table, P)     # (B, maxp)
        kg = k[gather]                                         # (B, maxp, ps, Hkv, dh)
        vg = v[gather]
        maxp = page_table.shape[1]
        L = maxp * ps
        kg = kg.reshape(B, L, cfg.n_kv_heads, cfg.d_head)
        vg = vg.reshape(B, L, cfg.n_kv_heads, cfg.d_head)
        pos_l = jnp.arange(L, dtype=jnp.int32)                 # flat == absolute
        valid = (page_table >= 0)[:, pos_l // ps] & (
            pos_l[None, :] <= cur_pos[:, None])
        out = _paged_attn_xla(q, kg, vg, valid, cfg)
    y = out.reshape(B, 1, cfg.q_dim) @ params["wo"]
    return y, PagedKVView(k=k, v=v)


def _paged_attn_xla(q, k, v, valid, cfg):
    """q: (B,1,H,dh); k/v: (B,L,Hkv,dh); valid: (B,L).  Same masked-softmax
    math as :func:`_decode_attn_xla`, validity precomputed from the page
    table instead of a per-slot ``pos`` array."""
    B, _, H, dh = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = (q.reshape(B, Hkv, group, dh) / jnp.sqrt(jnp.float32(dh))).astype(q.dtype)
    s = jnp.einsum("bgid,bkgd->bgik", qg, k,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgik,bkgd->bgid", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Verify path (speculative decode: W candidate tokens against the cache)
# ---------------------------------------------------------------------------
#
# Draft-and-verify scores a whole window of W candidate tokens in one pass:
# the window's K/V is written into the cache FIRST (positions cur_pos +
# [0, W)), then every query attends with per-query validity ``key position
# <= query position`` — which realizes within-window causality for free.
# Rollback of rejected drafts is overwrite-before-attend: the accepted
# count is always >= 1 for a surviving slot, so the next window's write
# range covers every stale position, and the position-validity mask keeps
# stale entries unattendable in the meantime.  No data is ever un-written.


def verify_decode_attention(
    params, x, cache: KVCacheView, cur_pos, cfg, *, impl: str = "xla",
    policy=None, write_limit=None,
):
    """Multi-query decode attention for draft verification (dense cache).

    x: (B, W, D) hidden states of the W window tokens; cur_pos: (B,)
    absolute position of the window's first token.  Returns
    (out (B, W, D), updated cache): query j attends every cached position
    ``<= cur_pos + j``, including the window's own writes at positions
    ``< j`` (within-window causality via the position-validity mask).

    ``write_limit`` (B,) bounds how many of the window's K/V writes stick
    (entries ``w >= write_limit[b]`` keep the old cache contents).  The
    ring buffer wraps at C: without the bound, a window overrunning a
    slot's token budget near capacity would wrap and clobber the oldest
    *live* context.  Positions ``>= write_limit`` can never be committed,
    so their garbage attention output is never observed.

    ``impl="pallas"`` has no dense multi-query kernel — the XLA multi-query
    path is the documented fallback (the paged pool is where the kernel
    leg lives; see :func:`paged_verify_attention`).
    """
    if policy is not None and getattr(policy, "kv_len_sharded", False):
        raise NotImplementedError(
            "verify decode does not support a length-sharded KV cache")
    if cfg.sliding_window:
        raise ValueError(
            "verify decode does not support sliding-window archs")
    B, W, _ = x.shape
    wi = jnp.arange(W, dtype=jnp.int32)
    pos_w = cur_pos.astype(jnp.int32)[:, None] + wi[None, :]       # (B, W)
    q, k_new, v_new = _project_qkv(params, x, cfg, positions=pos_w, rope=True)
    C = cache.k.shape[1]
    assert W <= C, (W, C)       # window slots stay distinct mod C
    k_new = k_new.astype(cache.k.dtype)
    v_new = v_new.astype(cache.v.dtype)

    slot = (pos_w % C).astype(jnp.int32)                           # (B, W)
    bidx = jnp.arange(B)[:, None]
    if write_limit is not None:
        ok = wi[None, :] < write_limit[:, None]                    # (B, W)
        k_new = jnp.where(ok[..., None, None], k_new, cache.k[bidx, slot])
        v_new = jnp.where(ok[..., None, None], v_new, cache.v[bidx, slot])
        pos_vals = jnp.where(ok, pos_w, cache.pos[bidx, slot])
    else:
        pos_vals = pos_w
    k = cache.k.at[bidx, slot].set(k_new)
    v = cache.v.at[bidx, slot].set(v_new)
    pos = cache.pos.at[bidx, slot].set(pos_vals)

    # no dense multi-query kernel: "pallas" falls back to the XLA oracle
    out = _verify_attn_xla(q, k, v, pos, pos_w, cfg)
    y = out.reshape(B, W, cfg.q_dim) @ params["wo"]
    return y, KVCacheView(k=k, v=v, pos=pos)


def _verify_attn_xla(q, k, v, pos, q_pos, cfg):
    """q: (B,W,H,dh); k/v: (B,C,Hkv,dh); pos: (B,C); q_pos: (B,W).

    :func:`_decode_attn_xla` with a query-window axis: same contractions,
    same f32 accumulation, per-query validity ``pos <= q_pos[:, j]``."""
    B, W, H, dh = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = (q.reshape(B, W, Hkv, group, dh)
          / jnp.sqrt(jnp.float32(dh))).astype(q.dtype)
    s = jnp.einsum("bwgid,bkgd->bwgik", qg, k,
                   preferred_element_type=jnp.float32)         # (B,W,Hkv,g,C)
    valid = (pos[:, None, :] >= 0) & (pos[:, None, :] <= q_pos[:, :, None])
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bwgik,bkgd->bwgid", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, W, H, dh).astype(q.dtype)


def paged_verify_attention(params, x, cache: PagedKVView, cur_pos,
                           page_table, cfg, *, impl: str = "xla",
                           policy=None):
    """Multi-query decode attention for draft verification (paged pool).

    x: (B, W, D); cur_pos: (B,) first window position; page_table as in
    :func:`paged_decode_attention`.  The window's K/V is scattered at
    ``(page_table[b, pos // ps], pos % ps)`` per token; positions whose
    logical page is unmapped or out of table range land on the trash page
    (allocation is the caller's job — the spec chunk scan faults every
    spanned page before the verify, all-or-nothing per slot).

    ``impl="pallas"`` walks the page table inside the multi-query kernel
    (``repro.kernels.paged_attention.paged_verify_attention_kernel``);
    ``impl="xla"`` is the gather oracle.
    """
    if policy is not None and getattr(policy, "kv_len_sharded", False):
        raise NotImplementedError(
            "paged decode does not support a length-sharded KV cache")
    B, W, _ = x.shape
    wi = jnp.arange(W, dtype=jnp.int32)
    pos_w = cur_pos.astype(jnp.int32)[:, None] + wi[None, :]       # (B, W)
    q, k_new, v_new = _project_qkv(params, x, cfg, positions=pos_w, rope=True)
    P = cache.n_pages
    ps = cache.page_size
    maxp = page_table.shape[1]
    k_new = k_new.astype(cache.k.dtype)
    v_new = v_new.astype(cache.v.dtype)

    logical = pos_w // ps                                          # (B, W)
    pid = jnp.take_along_axis(page_table,
                              jnp.clip(logical, 0, maxp - 1), axis=1)
    dest = jnp.where((pid >= 0) & (logical < maxp), pid, P)        # trash
    off = pos_w % ps
    k = cache.k.at[dest, off].set(k_new)
    v = cache.v.at[dest, off].set(v_new)

    if impl == "pallas":
        from repro.kernels.paged_attention import ops as pa_ops

        out = pa_ops.paged_verify_attention(q, k, v, page_table, cur_pos)
    else:
        gather = jnp.where(page_table >= 0, page_table, P)         # (B, maxp)
        kg = k[gather].reshape(B, maxp * ps, cfg.n_kv_heads, cfg.d_head)
        vg = v[gather].reshape(B, maxp * ps, cfg.n_kv_heads, cfg.d_head)
        pos_l = jnp.arange(maxp * ps, dtype=jnp.int32)             # absolute
        valid = (page_table >= 0)[:, pos_l // ps][:, None, :] & (
            pos_l[None, None, :] <= pos_w[:, :, None])             # (B, W, L)
        out = _paged_verify_attn_xla(q, kg, vg, valid, cfg)
    y = out.reshape(B, W, cfg.q_dim) @ params["wo"]
    return y, PagedKVView(k=k, v=v)


def _paged_verify_attn_xla(q, k, v, valid, cfg):
    """q: (B,W,H,dh); k/v: (B,L,Hkv,dh); valid: (B,W,L).  The multi-query
    twin of :func:`_paged_attn_xla` — the numerical oracle for the paged
    multi-query verify kernel."""
    B, W, H, dh = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = (q.reshape(B, W, Hkv, group, dh)
          / jnp.sqrt(jnp.float32(dh))).astype(q.dtype)
    s = jnp.einsum("bwgid,bkgd->bwgik", qg, k,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bwgik,bkgd->bwgid", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, W, H, dh).astype(q.dtype)
