"""Pure-JAX model zoo: explicit pytrees, scan-stacked blocks, six families."""

from .transformer import (
    Caches,
    FwdOut,
    decode_step,
    encoder_forward,
    forward,
    init_caches,
    init_paged_caches,
    init_params,
    lm_loss,
    logits_fn,
    n_blocks,
    period_len,
    period_structure,
    prefill,
    prefix_prefill,
    verify_step,
)

__all__ = [
    "Caches", "FwdOut", "decode_step", "encoder_forward", "forward",
    "init_caches", "init_paged_caches", "init_params", "lm_loss",
    "logits_fn", "n_blocks",
    "period_len", "period_structure", "prefill", "prefix_prefill",
    "verify_step",
]
