"""Mixture-of-Experts: top-k router + capacity-bounded sort dispatch.

Two compute paths (selected automatically by token count):

* **grouped-dispatch** (train / prefill) — per batch-row sort-based dispatch
  with a capacity bound, Switch-Transformer style but WITHOUT the O(T·E·C)
  one-hot dispatch tensor: tokens are argsorted by expert id, given a
  position-in-expert via a running offset, scattered into an (E, C, d) buffer,
  pushed through a stacked-expert einsum, and combined by a scatter-add.
  FLOPs ≈ top_k · T · 3 · d · d_ff · capacity_factor — HLO-honest for the
  roofline.  The sort is vmapped over the batch row, so with batch sharded on
  the "data" axis the sort is *local* (no cross-device sort network).

* **dense-decode** (few tokens) — compute every expert for every token and
  weight by the (zeroed below top-k) router probs.  Decode is memory-bound on
  expert weights regardless of dispatch (a 128-request batch activates nearly
  all experts), so this trades a negligible FLOP increase for zero gather
  traffic; recorded in DESIGN.md.

Shared experts (DeepSeek-MoE) are mathematically a single always-on MLP of
width n_shared·d_ff and are implemented as such (see test_moe_shared_equiv).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import init_dense, init_mlp, mlp


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_moe(key, cfg):
    """cfg: ModelConfig with cfg.moe set."""
    m = cfg.moe
    kr, ke1, ke2, ks = jax.random.split(key, 4)
    d, dff, E = cfg.d_model, m.expert_d_ff, m.n_experts
    p = {
        "router": init_dense(kr, d, E, "float32")["w"],   # router math in f32
        "wi": (jax.random.normal(ke1, (E, d, 2 * dff), dtype=jnp.float32)
               * (1.0 / jnp.sqrt(d))).astype(cfg.dtype),
        "wo": (jax.random.normal(ke2, (E, dff, d), dtype=jnp.float32)
               * (1.0 / jnp.sqrt(dff))).astype(cfg.dtype),
    }
    if m.n_shared_experts:
        p["shared"] = init_mlp(ks, d, m.n_shared_experts * (m.shared_d_ff or dff), cfg.dtype)
    return p


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


def router_probs(params, x, cfg):
    """(T, d) -> top-k (probs (T,k) normalized, expert ids (T,k), full probs)."""
    m = cfg.moe
    logits = (x.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm
    return top_p, top_i, probs


def load_balance_loss(probs, top_i, n_experts: int) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * P_e (1.0 = perfectly balanced)."""
    counts = jnp.zeros((n_experts,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    P = probs.mean(axis=0)
    return n_experts * jnp.sum(f * P)


# ---------------------------------------------------------------------------
# Grouped (sort-based) dispatch — train / prefill
# ---------------------------------------------------------------------------


def _route_row(x, top_p, top_i, E: int, capacity: int):
    """One batch row.  x: (T, d); top_p/top_i: (T, k).

    Returns (buf (E, capacity, d), slot (T·k,), t_sorted, w_sorted, keep)."""
    T, d = x.shape
    k = top_i.shape[1]
    Tk = T * k
    expert = top_i.reshape(Tk)                      # assignment expert ids
    token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    weight = top_p.reshape(Tk)

    order = jnp.argsort(expert, stable=True)        # group by expert
    e_sorted = expert[order]
    t_sorted = token[order]
    w_sorted = weight[order]

    counts = jnp.zeros((E,), jnp.int32).at[expert].add(1)
    starts = jnp.cumsum(counts) - counts            # first sorted index of e
    pos = jnp.arange(Tk, dtype=jnp.int32) - starts[e_sorted]   # pos within expert
    keep = pos < capacity                           # overflow tokens dropped
    slot = jnp.where(keep, e_sorted * capacity + pos, E * capacity)  # OOB sink

    buf = jnp.zeros((E * capacity + 1, d), dtype=x.dtype)
    buf = buf.at[slot].set(x[t_sorted])             # dropped -> sink row
    return buf[:-1].reshape(E, capacity, d), slot, t_sorted, w_sorted, keep


def _combine_row(y, slot, t_sorted, w_sorted, keep, T: int):
    """y: (E, capacity, d) expert outputs -> (T, d) combined tokens."""
    E, capacity, d = y.shape
    y_flat = jnp.concatenate([y.reshape(E * capacity, d),
                              jnp.zeros((1, d), y.dtype)], axis=0)
    contrib = y_flat[slot] * w_sorted[:, None].astype(y.dtype)
    return jnp.zeros((T, d), dtype=y.dtype).at[t_sorted].add(
        jnp.where(keep[:, None], contrib, 0)
    )


def moe_grouped(params, x, cfg, *, capacity: Optional[int] = None, policy=None):
    """x: (B, S, d).  Per-row dispatch (sort local to each batch row); the
    expert matmuls run in batch form so the expert axis of the capacity
    buffers can be sharding-constrained over "model" (EP).  Without the
    constraint GSPMD materializes + all-reduces the full (B, E, cap, 2·dff)
    buffer every layer — measured 8×290 GB/step/device on jamba train_4k
    (EXPERIMENTS.md §Perf cell 2, iteration J4)."""
    m = cfg.moe
    B, S, d = x.shape
    if capacity is None:
        capacity = max(1, int(S * m.top_k / m.n_experts * m.capacity_factor))
        capacity = min(capacity, S * m.top_k)
    x2 = x.reshape(B, S, d)
    top_p, top_i, probs = router_probs(params, x2.reshape(B * S, d), cfg)
    top_p = top_p.reshape(B, S, m.top_k)
    top_i = top_i.reshape(B, S, m.top_k)

    bufs, slot, t_sorted, w_sorted, keep = jax.vmap(
        lambda xr, pr, ir: _route_row(xr, pr, ir, m.n_experts, capacity)
    )(x2, top_p, top_i)

    def shard(t):
        return policy(t, "moe_ecap") if policy is not None else t

    bufs = shard(bufs)                              # (B, E, cap, d) E-sharded
    h = jnp.einsum("becd,edf->becf", bufs, params["wi"])
    h = shard(h)
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    y = jnp.einsum("becf,efd->becd", h, params["wo"])
    y = shard(y)

    routed = jax.vmap(
        lambda yr, sl, ts, ws, kp: _combine_row(yr, sl, ts, ws, kp, S)
    )(y, slot, t_sorted, w_sorted, keep)
    out = routed
    if "shared" in params:
        out = out + mlp(params["shared"], x)
    aux = load_balance_loss(probs, top_i.reshape(-1, m.top_k), m.n_experts)
    return out, aux


# ---------------------------------------------------------------------------
# Dense decode path
# ---------------------------------------------------------------------------


def moe_dense_decode(params, x, cfg):
    """x: (B, 1, d) or (B, S_small, d): all experts, prob-weighted combine."""
    m = cfg.moe
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    top_p, top_i, _ = router_probs(params, x2, cfg)
    # scatter normalized top-k probs into a dense (T, E) weight matrix
    w = jnp.zeros((B * S, m.n_experts), jnp.float32)
    w = w.at[jnp.arange(B * S)[:, None], top_i].set(top_p)
    h = jnp.einsum("td,edf->tef", x2, params["wi"])
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    y = jnp.einsum("tef,efd->ted", h, params["wo"])
    out = jnp.einsum("ted,te->td", y, w.astype(y.dtype)).reshape(B, S, d)
    if "shared" in params:
        out = out + mlp(params["shared"], x)
    return out, jnp.float32(0.0)


def moe_apply(params, x, cfg, *, decode: bool = False, policy=None):
    """Entry point: grouped dispatch for training/prefill, dense for decode."""
    if decode or x.shape[1] <= 4:
        return moe_dense_decode(params, x, cfg)
    return moe_grouped(params, x, cfg, policy=policy)
