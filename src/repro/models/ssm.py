"""Mamba-2 (SSD — state-space duality) blocks. [arXiv:2405.21060]

Training/prefill uses the **chunked SSD algorithm**: the sequence is split
into chunks of length L; within a chunk the recurrence is computed as a
masked attention-like quadratic form (the "duality"), and chunk-to-chunk
state is carried by a lax.scan.  Complexity O(S·L) instead of O(S²), state
passing exact.  The per-chunk quadratic form is the compute hot-spot that the
``repro.kernels.ssd_scan`` Pallas kernel implements for TPU; this module's
pure-JAX version is its oracle and the default lowering path.

Decode is the O(1) recurrent update: ``state = state·exp(dtA) + dt·x⊗B``,
``y = C·state + D·x`` — the reason mamba2/jamba run the long_500k cell.

Head/group layout follows Mamba-2: d_inner = expand·d_model split into
``nh = d_inner/head_dim`` heads; B and C are shared across ``nh/n_groups``
heads (the GQA analogue, "multi-value attention").
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import gated_rmsnorm, init_dense, init_rmsnorm


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def ssm_dims(cfg):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_ssm_heads(cfg.d_model)
    d_bc = 2 * s.n_groups * s.d_state
    return d_in, nh, d_bc


def init_ssm(key, cfg):
    """Projections are SEPARATE matrices (wz/wx/wb/wc/wdt) rather than one
    fused in_proj: slicing a fused output dim that is sharded on the "model"
    mesh axis would cut across shard boundaries and force all-gathers; with
    separate matrices each stream gets a clean tensor-parallel spec
    (DESIGN.md §Sharding)."""
    s = cfg.ssm
    d_in, nh, d_bc = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "wz": init_dense(k1, cfg.d_model, d_in, cfg.dtype)["w"],
        "wx": init_dense(k2, cfg.d_model, d_in, cfg.dtype)["w"],
        "wb": init_dense(k3, cfg.d_model, gn, cfg.dtype)["w"],
        "wc": init_dense(k5, cfg.d_model, gn, cfg.dtype)["w"],
        "wdt": init_dense(k6, cfg.d_model, nh, cfg.dtype)["w"],
        "conv_w": (jax.random.normal(k4, (s.d_conv, d_in + d_bc), dtype=jnp.float32)
                   * (1.0 / jnp.sqrt(s.d_conv))).astype(cfg.dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),                 # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": init_rmsnorm(d_in, cfg.dtype),
        "out_proj": init_dense(jax.random.fold_in(k4, 1), d_in, cfg.d_model, cfg.dtype)["w"],
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv (d_conv is tiny: implemented as shifted adds)
# ---------------------------------------------------------------------------


def causal_conv(x, conv_w):
    """x: (B, S, C); conv_w: (K, C).  y[t] = sum_i w[i] * x[t - (K-1) + i]."""
    K = conv_w.shape[0]
    B, S, C = x.shape
    pad = jnp.zeros((B, K - 1, C), dtype=x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(K):
        y = y + xp[:, i : i + S, :] * conv_w[i]
    return y


def causal_conv_step(x_t, conv_state, conv_w):
    """One decode step.  x_t: (B, C); conv_state: (B, K-1, C) (oldest first).
    Returns (y_t, new_conv_state)."""
    K = conv_w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)   # (B,K,C)
    y_t = jnp.einsum("bkc,kc->bc", window, conv_w)
    return y_t, window[:, 1:, :] if K > 1 else conv_state


# ---------------------------------------------------------------------------
# SSD chunked scan (pure JAX; oracle for kernels/ssd_scan)
# ---------------------------------------------------------------------------


def _segsum(dA):
    """dA: (..., L).  Returns M[..., i, j] = sum_{j < t <= i} dA[t]  (i >= j),
    -inf above the diagonal — the log of the causal decay matrix."""
    L = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    M = cs[..., :, None] - cs[..., None, :]   # sum over t in (j, i]
    mask = jnp.tril(jnp.ones((L, L), dtype=bool), k=0)
    return jnp.where(mask, M, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int, return_state: bool = False):
    """Chunked SSD.

    x:  (B, S, nh, hd)   inputs per head
    dt: (B, S, nh)       discretization steps (post-softplus)
    A:  (nh,)            negative-real state decay
    Bm: (B, S, G, N)     input projections (shared across nh/G heads)
    Cm: (B, S, G, N)     output projections
    Returns y: (B, S, nh, hd).
    """
    Bsz, S, nh, hd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        # dt=0 padding is exact: dA=0 ⇒ within-chunk decay 1 (the state
        # passes through untouched) and padded rows carry weight dt_j=0 in
        # every output/state sum — ragged prompt lengths prefill correctly.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_out, S = S, S + pad
    nc = S // L
    rep = nh // G

    # reshape to chunks; move chunk axis first for the scan
    xc = x.reshape(Bsz, nc, L, nh, hd)
    dtc = dt.reshape(Bsz, nc, L, nh)
    Bc = Bm.reshape(Bsz, nc, L, G, N)
    Cc = Cm.reshape(Bsz, nc, L, G, N)

    dA = dtc * A[None, None, None, :]                     # (B,nc,L,nh)
    cum = jnp.cumsum(dA, axis=2)                          # within-chunk cumsum

    def body(state, inp):
        """state: (B, nh, hd, N)."""
        xk, dtk, Bk, Ck, dAk, cumk = inp
        # ----- intra-chunk (quadratic duality form) ------------------
        # decay matrix per head: (B, nh, L, L)
        Mlog = _segsum(jnp.moveaxis(dAk, -1, 1))          # (B,nh,L,L)
        decay = jnp.exp(Mlog)
        CB = jnp.einsum("blgn,bmgn->bglm", Ck, Bk)        # (B,G,L,L)
        CB = jnp.repeat(CB, rep, axis=1)                  # (B,nh,L,L)
        scores = CB * decay * jnp.moveaxis(dtk, -1, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhlm,bmhp->blhp", scores.astype(xk.dtype), xk)
        # ----- inter-chunk (carried state) ---------------------------
        state_decay = jnp.exp(cumk)                       # (B,L,nh)
        Crep = jnp.repeat(Ck, rep, axis=2)                # (B,L,nh,N)
        y_inter = jnp.einsum("blhn,bhpn->blhp", Crep, state)
        y_inter = y_inter * state_decay[..., None]
        # ----- state update ------------------------------------------
        total = cumk[:, -1, :]                            # (B,nh) total decay
        w = jnp.exp(total[:, None, :] - cumk) * dtk       # (B,L,nh)
        Brep = jnp.repeat(Bk, rep, axis=2)                # (B,L,nh,N)
        state_new = state * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "blhp,blhn,blh->bhpn", xk.astype(jnp.float32),
            Brep.astype(jnp.float32), w
        )
        return state_new, (y_intra + y_inter.astype(xk.dtype))

    state0 = jnp.zeros((Bsz, nh, hd, N), dtype=jnp.float32)
    xs = (
        jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0), jnp.moveaxis(Bc, 1, 0),
        jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(dA, 1, 0), jnp.moveaxis(cum, 1, 0),
    )
    state_f, yc = jax.lax.scan(body, state0, xs)
    y = jnp.moveaxis(yc, 0, 1).reshape(Bsz, S, nh, hd)[:, :S_out]
    if return_state:
        return y, state_f
    return y


# ---------------------------------------------------------------------------
# Block forward (train/prefill) and decode step
# ---------------------------------------------------------------------------


class SSMState(NamedTuple):
    conv: jax.Array   # (B, K-1, d_in + d_bc)
    ssm: jax.Array    # (B, nh, hd, N) f32


def init_ssm_state(cfg, batch: int) -> SSMState:
    s = cfg.ssm
    d_in, nh, d_bc = ssm_dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, s.d_conv - 1, d_in + d_bc), dtype=jnp.dtype(cfg.dtype)),
        ssm=jnp.zeros((batch, nh, s.head_dim, s.d_state), dtype=jnp.float32),
    )


def _split_proj(params, x, cfg):
    """Per-stream projections; xBC is the concat fed through the causal conv
    (conv is depthwise, so conv(concat) == concat(per-segment conv))."""
    z = x @ params["wz"]
    xc = x @ params["wx"]
    bc = x @ params["wb"]
    cc = x @ params["wc"]
    dt = x @ params["wdt"]
    xBC = jnp.concatenate([xc, bc, cc], axis=-1)
    return z, xBC, dt


def ssm_forward(params, x, cfg, *, impl: str = "xla", return_state: bool = False):
    """x: (B, S, d_model) -> (B, S, d_model).  Training/prefill path.

    ``return_state=True`` additionally returns the :class:`SSMState` after
    the last token (prefill seeding for decode)."""
    s = cfg.ssm
    d_in, nh, d_bc = ssm_dims(cfg)
    G, N, hd = s.n_groups, s.d_state, s.head_dim
    B, S, _ = x.shape

    z, xBC_raw, dt = _split_proj(params, x, cfg)
    xBC = causal_conv(xBC_raw, params["conv_w"])
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs = xBC[..., :d_in].reshape(B, S, nh, hd)
    Bm = xBC[..., d_in : d_in + G * N].reshape(B, S, G, N)
    Cm = xBC[..., d_in + G * N :].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    if impl == "pallas":
        from repro.kernels.ssd_scan import ops as ssd_ops

        y, final_state = ssd_ops.ssd(xs, dt, A, Bm, Cm, chunk=s.chunk,
                                     return_state=True)
    else:
        y, final_state = ssd_chunked(xs, dt, A, Bm, Cm, chunk=s.chunk,
                                     return_state=True)
    y = y + params["D"][None, None, :, None].astype(y.dtype) * xs
    y = y.reshape(B, S, d_in)
    y = gated_rmsnorm(params["norm"], y, z, eps=cfg.norm_eps)
    out = y @ params["out_proj"]
    if not return_state:
        return out
    conv_state = xBC_raw[:, S - (s.d_conv - 1):, :]
    return out, SSMState(conv=conv_state, ssm=final_state)


def ssm_decode(params, x, state: SSMState, cfg) -> Tuple[jax.Array, SSMState]:
    """One token.  x: (B, 1, d_model) -> (y (B,1,d_model), new state)."""
    s = cfg.ssm
    d_in, nh, d_bc = ssm_dims(cfg)
    G, N, hd = s.n_groups, s.d_state, s.head_dim
    B = x.shape[0]

    z, xBC, dt = _split_proj(params, x[:, 0, :], cfg)
    xBC, conv_new = causal_conv_step(xBC, state.conv, params["conv_w"])
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xt = xBC[..., :d_in].reshape(B, nh, hd)
    Bt = xBC[..., d_in : d_in + G * N].reshape(B, G, N)
    Ct = xBC[..., d_in + G * N :].reshape(B, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,nh)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                                               # (B,nh)

    rep = nh // G
    Brep = jnp.repeat(Bt, rep, axis=1)                                  # (B,nh,N)
    Crep = jnp.repeat(Ct, rep, axis=1)
    ssm_new = state.ssm * dA[..., None, None] + (
        dt[..., None, None]
        * xt.astype(jnp.float32)[..., :, None]
        * Brep.astype(jnp.float32)[..., None, :]
    )
    y = jnp.einsum("bhpn,bhn->bhp", ssm_new, Crep.astype(jnp.float32))
    y = y.astype(x.dtype) + params["D"][None, :, None].astype(x.dtype) * xt
    y = y.reshape(B, d_in)
    y = gated_rmsnorm(params["norm"], y, z, eps=cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None, :]
    return out, SSMState(conv=conv_new, ssm=ssm_new)
