from .adamw import (
    AdamWState,
    QTensor,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    opt_state_specs,
)
from .schedules import SCHEDULES, constant, warmup_cosine, warmup_linear

__all__ = [
    "AdamWState", "QTensor", "adamw_init", "adamw_update",
    "clip_by_global_norm", "global_norm", "opt_state_specs",
    "SCHEDULES", "constant", "warmup_cosine", "warmup_linear",
]
