"""AdamW with optional block-quantized (8-bit) first/second moments.

Plain-function optimizer (no optax dependency):

    state  = adamw_init(params, quantize=...)
    params, state = adamw_update(grads, state, params, lr=..., ...)

Memory modes:
  * f32 moments (default) — 8 B/param of optimizer state.
  * ``quantize=True`` — int8 block-quantized m and v (1 B + 4 B/256-block
    each ≈ 2.03 B/param), the production setting for the 104B/398B configs
    where f32 moments would not fit 16 GB/chip at 256 chips
    (DESIGN.md §Memory).  Dequant→update→requant per step; the second moment
    is quantized in sqrt-space to keep relative error uniform.

Optimizer-state sharding (ZeRO): moments inherit the parameter sharding,
which under the FSDP("data") × TP("model") param specs means states are
fully sharded across the pod — the ZeRO-1 memory split falls out of GSPMD
rather than being a separate wiring (tests assert the spec pytrees match).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.compression import dequantize_int8, quantize_int8

QBLOCK = 256


class QTensor(NamedTuple):
    """Block-quantized tensor: q (nb, QBLOCK) int8, scale (nb, 1) f32."""

    q: jax.Array
    scale: jax.Array
    # static metadata carried in aux? shape must be recoverable: kept by the
    # param it shadows (same pytree position), so not stored here.


def _q(x):
    q, s = quantize_int8(x, block=QBLOCK)
    return QTensor(q=q, scale=s)


def _dq(qt: QTensor, shape):
    return dequantize_int8(qt.q, qt.scale, shape, block=QBLOCK)


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any     # pytree of f32 arrays or QTensors
    v: Any


def adamw_init(params, *, quantize: bool = False) -> AdamWState:
    if quantize:
        zeros = jax.tree.map(lambda p: _q(jnp.zeros(p.shape, jnp.float32)), params)
        zeros_v = jax.tree.map(lambda p: _q(jnp.zeros(p.shape, jnp.float32)), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros_v)
    def z(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(z, params),
        v=jax.tree.map(z, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    quantized: bool = False,
) -> Tuple[Any, AdamWState]:
    """One AdamW step.  ``lr`` may be a scalar or a 0-d array."""
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def one(p, g, m, v):
        g = g.astype(jnp.float32)
        if quantized:
            m_f = _dq(m, p.shape)
            v_f = jnp.square(_dq(v, p.shape))     # v stored in sqrt-space
        else:
            m_f, v_f = m, v
        m_new = b1 * m_f + (1 - b1) * g
        v_new = b2 * v_f + (1 - b2) * jnp.square(g)
        m_hat = m_new / c1
        v_hat = v_new / c2
        upd = m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if quantized:
            return p_new, _q(m_new), _q(jnp.sqrt(v_new))
        return p_new, m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [one(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params_new = tdef.unflatten([o[0] for o in out])
    m_new = tdef.unflatten([o[1] for o in out])
    v_new = tdef.unflatten([o[2] for o in out])
    return params_new, AdamWState(step=step, m=m_new, v=v_new)


# ---------------------------------------------------------------------------
# State sharding specs (ZeRO via GSPMD: moments mirror the param specs)
# ---------------------------------------------------------------------------


def opt_state_specs(p_specs, *, quantize: bool = False, params=None, mesh=None):
    """Spec pytree matching ``adamw_init``'s state.

    f32 moments mirror the param specs (ZeRO falls out of FSDP specs).
    Quantized moments are (n_blocks, QBLOCK) int8 + (n_blocks, 1) scales;
    the block axis is sharded over "data" (pure ZeRO-1 split) only when the
    leaf's block count divides the axis — small tensors (norm scales, A_log)
    stay replicated.  Needs ``params`` (abstract ok) + ``mesh`` to size this.
    """
    from jax.sharding import PartitionSpec as P

    if quantize:
        import numpy as np

        if params is None:
            raise ValueError("opt_state_specs(quantize=True) needs params=")
        data = int(mesh.shape["data"]) if (mesh is not None and "data" in mesh.axis_names) else 1

        def qspec_for(p):
            n = int(np.prod(p.shape)) if p.shape else 1
            nb = -(-n // QBLOCK)
            ax = "data" if (data > 1 and nb % data == 0) else None
            return QTensor(q=P(ax, None), scale=P(ax, None))

        mspec = jax.tree.map(qspec_for, params)
    else:
        mspec = p_specs
    return AdamWState(step=P(), m=mspec, v=mspec)
