"""LR schedules as pure functions of the (traced) step."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def warmup_linear(lr: float, warmup: int, total: int):
    def f(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        w = jnp.minimum(s / max(warmup, 1), 1.0)
        decay = jnp.maximum(0.0, 1.0 - jnp.maximum(s - warmup, 0.0) / max(total - warmup, 1))
        return jnp.float32(lr) * w * decay
    return f


def warmup_cosine(lr: float, warmup: int, total: int, *, final_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        w = jnp.minimum(s / max(warmup, 1), 1.0)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr) * w * cos
    return f


SCHEDULES = {
    "constant": constant,
    "warmup_linear": warmup_linear,
    "warmup_cosine": warmup_cosine,
}
