from .store import AsyncCheckpointer, latest_step, read_metadata, restore, save

__all__ = ["AsyncCheckpointer", "latest_step", "read_metadata", "restore", "save"]
