"""Checkpointing: atomic, async, elastic-restore.

Design (single-file-per-step, npz + JSON manifest):

* **Atomicity** — write to ``<dir>/tmp.<step>``, fsync, rename to
  ``<dir>/step_<step>``; a crash mid-write never corrupts the latest
  checkpoint (the paper's layer-level context switch plays the same trick
  with layer-index granularity; here the granularity is the step).
* **Async** — ``save_async`` snapshots to host RAM (device_get) on the
  caller's thread (cheap, and required for consistency) and does file I/O on
  a background thread; ``wait()`` joins before the next save.
* **Elastic restore** — ``restore`` takes the *target* pytree structure and
  optional shardings; arrays are re-laid-out via device_put, so a checkpoint
  written on one mesh restores onto any other (tested: save on 1 "core",
  restore logically onto a resized tenant — the private-cloud
  reconfiguration primitive applied to training state).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_SEP = "|"


# numpy's npz cannot store ml_dtypes (bfloat16, fp8); encode them as a raw
# bit-pattern view + the logical dtype name, decoded on restore.
_RAW_VIEW = {2: np.uint16, 1: np.uint8}


def _encode(arr: np.ndarray):
    """-> (storable array, logical dtype name or None)."""
    try:
        np.dtype(arr.dtype).name  # noqa: B018 — probe
        np.zeros(1, arr.dtype).astype(np.float64, casting="unsafe")
        native = arr.dtype.kind in "biufc"
    except (TypeError, ValueError):
        native = False
    if native and arr.dtype.kind in "biufc":
        return arr, None
    raw = arr.view(_RAW_VIEW[arr.dtype.itemsize])
    return raw, str(arr.dtype)


def _decode(arr: np.ndarray, logical: Optional[str]):
    if not logical:
        return arr
    import ml_dtypes  # ships with jax

    return arr.view(np.dtype(getattr(ml_dtypes, logical)))


def _flatten_named(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        # np.array(copy=True): snapshot semantics even for host numpy inputs
        out[key] = np.array(jax.device_get(leaf), copy=True)
    return out


def save(path: str, step: int, tree: Any, *, metadata: Optional[dict] = None,
         keep: int = 3) -> str:
    """Synchronous atomic save.  Returns the final checkpoint directory."""
    os.makedirs(path, exist_ok=True)
    tmp = os.path.join(path, f"tmp.{step}")
    final = os.path.join(path, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    named = _flatten_named(tree)
    encoded, logical = {}, {}
    for k, v in named.items():
        enc, logi = _encode(v)
        encoded[k.replace("/", _SEP)] = enc
        if logi:
            logical[k] = logi
    np.savez(os.path.join(tmp, "arrays.npz"), **encoded)
    manifest = {
        "step": step,
        "keys": list(named.keys()),
        "shapes": {k: list(v.shape) for k, v in named.items()},
        "dtypes": {k: str(v.dtype) for k, v in named.items()},
        "logical_dtypes": logical,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(path, keep)
    return final


def _gc(path: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(path) if d.startswith("step_")
    )
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(path) if d.startswith("step_")
    )
    return steps[-1] if steps else None


def restore(path: str, like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (same structure, optional) re-lays-out
    every leaf — the elastic-reshard path."""
    step = latest_step(path) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    final = os.path.join(path, f"step_{step:08d}")
    data = np.load(os.path.join(final, "arrays.npz"))
    with open(os.path.join(final, "manifest.json")) as f:
        logical = json.load(f).get("logical_dtypes", {})
    arrays = {
        k.replace(_SEP, "/"): _decode(data[k], logical.get(k.replace(_SEP, "/")))
        for k in data.files
    }

    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves_like, treedef = jax.tree.flatten(like)
    flat_sh = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves_like)
    )
    out_leaves: List[Any] = []
    for (path_k, leaf), sh in zip(flat_like[0], flat_sh):
        key = jax.tree_util.keystr(path_k)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        want_dtype = getattr(leaf, "dtype", None)
        if want_dtype is not None and arr.dtype != want_dtype:
            arr = arr.astype(want_dtype)
        out_leaves.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return treedef.unflatten(out_leaves)


def read_metadata(path: str, *, step: Optional[int] = None) -> dict:
    step = latest_step(path) if step is None else step
    with open(os.path.join(path, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)


class AsyncCheckpointer:
    """Background-thread writer with snapshot-on-call semantics."""

    def __init__(self, path: str, *, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save_async(self, step: int, tree: Any, *, metadata: Optional[dict] = None):
        self.wait()
        named = _flatten_named(tree)   # snapshot NOW (device -> host)

        def _write():
            os.makedirs(self.path, exist_ok=True)
            tmp = os.path.join(self.path, f"tmp.{step}")
            final = os.path.join(self.path, f"step_{step:08d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            encoded, logical = {}, {}
            for k, v in named.items():
                enc, logi = _encode(v)
                encoded[k.replace("/", _SEP)] = enc
                if logi:
                    logical[k] = logi
            np.savez(os.path.join(tmp, "arrays.npz"), **encoded)
            manifest = {
                "step": step,
                "keys": list(named.keys()),
                "logical_dtypes": logical,
                "metadata": metadata or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _gc(self.path, self.keep)
            self.last_saved = step

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
