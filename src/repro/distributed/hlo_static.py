"""Static analyzer for post-optimization HLO text: trip-count-aware cost.

``compiled.cost_analysis()`` undercounts programs that keep their layer stack
under ``lax.scan``: XLA's HloCostAnalysis visits a ``while`` body **once**,
so a 28-layer model reports ~1/28th of its FLOPs (verified in
tests/test_hlo_static.py).  Since every model here scans its blocks (the HLO
must stay O(period) to compile 80-layer configs at 512 devices), the roofline
would be garbage without correcting for trip counts.

This module re-derives the three roofline inputs from ``compiled.as_text()``:

* **flops** — 2 · prod(result dims) · prod(contracting dims) per ``dot``
  (+ convolutions), summed over the call graph with every ``while`` body
  multiplied by its trip count (XLA annotates ``known_trip_count`` in
  ``backend_config``; fallback: the ``compare(..., constant)`` in the
  condition computation).
* **bytes** — HBM-traffic proxy: Σ (result + operand bytes) of every
  *top-level* instruction in each executed computation.  Fusion interiors
  are excluded (a fusion is one kernel: only its boundary tensors touch HBM)
  but their dots still count toward flops.  parameter/constant/tuple/GTE/
  bitcast contribute nothing.
* **collective bytes** — wire traffic per device of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute.  Per-op convention
  (ring algorithms, group size N):
      all-reduce          2·size·(N-1)/N
      all-gather          result·(N-1)/N
      reduce-scatter      operand·(N-1)/N
      all-to-all          size·(N-1)/N
      collective-permute  size
  ``raw_collective_bytes`` (Σ operand sizes, the brief's plain definition) is
  reported alongside.

All numbers are **per device**: the compiled module is the per-device SPMD
program.  Aggregate with ×chips when comparing against global quantities.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "tuple": 0,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

# ops whose operands/results don't represent real HBM traffic (control flow
# buffers are counted at their producers; tuples/GTE/bitcast are views)
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "while", "conditional", "call", "custom-call", "optimization-barrier",
}


_TYPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")


def _parse_instr_line(line: str) -> Optional[Tuple[str, str, str, str]]:
    """-> (name, result_type_text, opcode, rest-after-open-paren) or None.

    Hand-parsed because tuple result types embed ``/*index=N*/`` comments and
    nested layout braces that defeat a single regex.
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i < len(line) and line[i] == "(":          # tuple type: scan to match
        depth, j = 1, i + 1
        while j < len(line) and depth:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
            j += 1
        rtype = line[i:j]
        i = j
    else:                                          # scalar/array type token
        j = i
        while j < len(line) and not line[j].isspace():
            j += 1
        rtype = line[i:j]
        i = j
    om = _OPCODE_RE.match(line, i)
    if not om:
        return None
    return name, rtype, om.group(1), line[om.end():]
# header: `%name (params...) -> type {`  — params may nest parens (tuple
# types), so just require: starts with optional ENTRY + %name(, contains ->,
# ends with `{`.
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# `call` instructions name their target `to_apply=%comp` on newer XLA
# versions and `calls=%comp` on older ones; accept both.
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_DIM_LABELS_RE = re.compile(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)")
_WINDOW_SIZE_RE = re.compile(r"size=([0-9x]+)")
_FEATURE_GROUPS_RE = re.compile(r"feature_group_count=(\d+)")


def _shape_bytes(dtype: str, dims: List[int]) -> float:
    nb = DTYPE_BYTES.get(dtype, 0)
    n = 1
    for d in dims:
        n *= d
    return n * nb


def _parse_type(text: str) -> List[Tuple[str, List[int]]]:
    """All dtype[dims] tensors in a (possibly tuple) type string."""
    out = []
    for m in _TYPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result: List[Tuple[str, List[int]]]     # one or more (dtype, dims)
    rest: str                               # operand list + attributes

    @property
    def result_bytes(self) -> float:
        return sum(_shape_bytes(dt, dims) for dt, dims in self.result)

    def operand_names(self) -> List[str]:
        # operands come before the first "),"; attrs can also contain %names
        # (calls=%c) — cut at the closing paren of the operand list.
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return _OPERANDS_RE.findall(self.rest[:i])
        return _OPERANDS_RE.findall(self.rest)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr]
    order: List[str]


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0           # modeled wire bytes / device
    raw_collective_bytes: float = 0.0       # Σ operand sizes (brief's formula)
    collective_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: Dict[str, int] = dataclasses.field(default_factory=dict)
    unknown_trip_counts: int = 0

    def add(self, other: "CostTotals", scale: float = 1.0) -> None:
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.collective_bytes += other.collective_bytes * scale
        self.raw_collective_bytes += other.raw_collective_bytes * scale
        for k, v in other.collective_by_op.items():
            self.collective_by_op[k] = self.collective_by_op.get(k, 0.0) + v * scale
        for k, v in other.collective_count.items():
            self.collective_count[k] = self.collective_count.get(k, 0) + int(v * scale)
        self.unknown_trip_counts += other.unknown_trip_counts


class HloModule:
    """Parsed post-optimization HLO text with cost roll-up."""

    def __init__(self, text: str):
        self.computations: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self._fusion_bodies: set = set()
        self._parse(text)
        self._memo: Dict[Tuple[str, bool], CostTotals] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[Computation] = None
        for line in text.splitlines():
            if cur is None:
                if "->" in line and line.rstrip().endswith("{"):
                    m = _COMP_HDR_RE.match(line)
                    if m:
                        cur = Computation(m.group(1), {}, [])
                        if line.lstrip().startswith("ENTRY"):
                            self.entry = cur.name
                continue
            if line.startswith("}"):
                self.computations[cur.name] = cur
                cur = None
                continue
            parsed = _parse_instr_line(line)
            if parsed is None:
                continue
            name_, rtype, opcode, rest = parsed
            ins = Instr(
                name=name_, opcode=opcode,
                result=_parse_type(rtype), rest=rest,
            )
            cur.instrs[ins.name] = ins
            cur.order.append(ins.name)
        # pre-scan for fusion/call targets (their interior bytes don't count)
        for comp in self.computations.values():
            for ins in comp.instrs.values():
                if ins.opcode in ("fusion", "call", "async-start"):
                    cm = _CALLS_RE.search(ins.rest)
                    if cm:
                        self._fusion_bodies.add(cm.group(1))

    # ------------------------------------------------------------------
    def _group_size(self, ins: Instr) -> int:
        m = _GROUPS_IOTA_RE.search(ins.rest)
        if m:
            return max(int(m.group(2)), 1)
        m = _GROUPS_LIST_RE.search(ins.rest)
        if m:
            return max(len([x for x in m.group(1).split(",") if x.strip() != ""]), 1)
        return 1

    def _operand_bytes(self, comp: Computation, ins: Instr) -> float:
        total = 0.0
        for name in ins.operand_names():
            op = comp.instrs.get(name)
            if op is not None:
                total += op.result_bytes
        return total

    _SLICE_OPS = {"dynamic-slice", "gather", "slice"}
    _VIEW_OPS = {"bitcast", "reshape", "copy", "convert", "transpose"}

    def _inplace_dus_fusion_bytes(self, ins: Instr) -> Optional[float]:
        """Traffic of a dynamic-update-slice-rooted fusion, modeled as the
        TPU backend executes it: the base buffer aliases in place and only
        the updated region is written (2 × update bytes).

        The CPU pipeline we compile on promotes bf16 DUS/scatter to f32,
        which blocks aliasing and copies the whole loop-carried buffer every
        scan iteration — e.g. the decode step's stacked KV-cache ys write
        measured 2×279 GB/step/device of artifact traffic.  Those converts
        do not exist on the TPU target, so the roofline charges the slice.
        Returns None when the fusion root isn't a DUS on a parameter."""
        cm = _CALLS_RE.search(ins.rest)
        callee = self.computations.get(cm.group(1)) if cm else None
        if callee is None or not callee.order:
            return None
        # root = last instruction; peel views (convert/bitcast inserted by
        # CPU float normalization)
        node = callee.instrs[callee.order[-1]]
        for _ in range(3):
            if node.opcode in self._VIEW_OPS:
                nxt = callee.instrs.get(next(iter(node.operand_names()), ""))
                if nxt is None:
                    return None
                node = nxt
            else:
                break
        if node.opcode not in ("dynamic-update-slice", "scatter"):
            return None
        ops_ = node.operand_names()
        upd_idx = 1 if node.opcode == "dynamic-update-slice" else 2
        if len(ops_) <= upd_idx:
            return None
        # base must trace back to a fusion parameter (aliasable)
        base = callee.instrs.get(ops_[0])
        for _ in range(3):
            if base is None:
                return None
            if base.opcode == "parameter":
                break
            if base.opcode in self._VIEW_OPS:
                base = callee.instrs.get(next(iter(base.operand_names()), ""))
            else:
                return None
        upd = callee.instrs.get(ops_[upd_idx])
        upd_bytes = upd.result_bytes if upd is not None else 0.0
        return 2.0 * upd_bytes

    def _fusion_operand_bytes(self, comp: Computation, ins: Instr) -> float:
        """Operand traffic of a fusion: a parameter consumed ONLY by
        slice-type ops inside the fused computation reads the slice, not the
        whole tensor (CPU wraps dynamic-slice in wrapped_* fusions; charging
        the full stacked-params operand per scan iteration would overcount
        the layer scan ~n_layers×)."""
        cm = _CALLS_RE.search(ins.rest)
        callee = self.computations.get(cm.group(1)) if cm else None
        if callee is None:
            return self._operand_bytes(comp, ins)
        # positional parameter index -> instruction name in the callee
        param_by_idx: Dict[int, str] = {}
        for cn, ci in callee.instrs.items():
            if ci.opcode == "parameter":
                m = re.match(r"\s*(\d+)", ci.rest)
                if m:
                    param_by_idx[int(m.group(1))] = cn
        total = 0.0
        for idx, name in enumerate(ins.operand_names()):
            op = comp.instrs.get(name)
            if op is None:
                continue
            pname = param_by_idx.get(idx)
            if pname is None:
                total += op.result_bytes
                continue
            # find the callee uses of this parameter (follow 1 view hop)
            uses: List[Instr] = []
            frontier = {pname}
            for _hop in range(2):
                nxt = set()
                for ci in callee.instrs.values():
                    if any(u in ci.operand_names() for u in frontier):
                        if ci.opcode in self._VIEW_OPS:
                            nxt.add(ci.name)
                        else:
                            uses.append(ci)
                frontier = nxt
                if not frontier:
                    break
            if uses and all(u.opcode in self._SLICE_OPS for u in uses):
                total += sum(u.result_bytes for u in uses)
            else:
                total += op.result_bytes
        return total

    def _trip_count(self, comp: Computation, ins: Instr) -> Optional[int]:
        m = _TRIP_RE.search(ins.rest)
        if m:
            return int(m.group(1))
        # fallback: find `compare(..., constant)` in the condition computation
        cm = _COND_RE.search(ins.rest)
        if cm and cm.group(1) in self.computations:
            cond = self.computations[cm.group(1)]
            const_vals = {}
            for ci in cond.instrs.values():
                if ci.opcode == "constant":
                    vm = re.search(r"constant\((-?\d+)\)", "constant(" + ci.rest)
                    if vm:
                        const_vals[ci.name] = int(vm.group(1))
            for ci in cond.instrs.values():
                if ci.opcode == "compare" and "direction=LT" in ci.rest:
                    for name in ci.operand_names():
                        if name in const_vals:
                            return const_vals[name]
        return None

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out_elems = 1
        for _, dims in ins.result:
            for d in dims:
                out_elems *= d
        contract = 1
        m = _LHS_CONTRACT_RE.search(ins.rest)
        lhs_name = next(iter(ins.operand_names()), None)
        lhs = comp.instrs.get(lhs_name) if lhs_name else None
        if m and lhs is not None and lhs.result:
            dims = lhs.result[0][1]
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(dims):
                    contract *= dims[idx]
        return 2.0 * out_elems * contract

    def _conv_flops(self, comp: Computation, ins: Instr) -> float:
        out_elems = 1
        for _, dims in ins.result:
            for d in dims:
                out_elems *= d
        ops = ins.operand_names()
        rhs = comp.instrs.get(ops[1]) if len(ops) > 1 else None
        if rhs is None or not rhs.result:
            return 2.0 * out_elems
        # kernel total elems / output features = per-output MAC count
        kdims = rhs.result[0][1]
        kelems = 1
        for d in kdims:
            kelems *= d
        ofeat = max(ins.result[0][1][-1] if ins.result[0][1] else 1, 1)
        fg = 1
        m = _FEATURE_GROUPS_RE.search(ins.rest)
        if m:
            fg = int(m.group(1))
        per_out = kelems / max(ofeat, 1)
        return 2.0 * out_elems * per_out / max(fg, 1) * fg  # fg cancels: kelems already /fg per group

    # ------------------------------------------------------------------
    def cost(self, comp_name: Optional[str] = None, *, top_level: bool = True) -> CostTotals:
        """Roll up cost of ``comp_name`` (default: entry), scaling while
        bodies by trip count.  ``top_level=False`` = fusion interior: flops
        count, bytes don't."""
        name = comp_name or self.entry
        key = (name, top_level)
        if key in self._memo:
            return self._memo[key]
        comp = self.computations.get(name)
        total = CostTotals()
        if comp is None:
            return total
        self._memo[key] = total  # guards (benign) recursion
        for iname in comp.order:
            ins = comp.instrs[iname]
            op = ins.opcode
            base_op = op[:-6] if op.endswith("-start") else op
            if op == "dot":
                total.flops += self._dot_flops(comp, ins)
            elif op == "convolution":
                total.flops += self._conv_flops(comp, ins)
            if base_op in COLLECTIVE_OPS and not op.endswith("-done"):
                n = self._group_size(ins)
                res = ins.result_bytes
                opnd = self._operand_bytes(comp, ins)
                frac = (n - 1) / n if n > 1 else 0.0
                if base_op == "all-reduce":
                    wire = 2.0 * res * frac
                elif base_op == "all-gather":
                    wire = res * frac
                elif base_op == "reduce-scatter":
                    wire = opnd * frac
                elif base_op == "collective-permute":
                    wire = res
                else:  # all-to-all & friends
                    wire = res * frac
                total.collective_bytes += wire
                total.raw_collective_bytes += opnd
                total.collective_by_op[base_op] = (
                    total.collective_by_op.get(base_op, 0.0) + wire
                )
                total.collective_count[base_op] = (
                    total.collective_count.get(base_op, 0) + 1
                )
            if top_level and op not in _NO_TRAFFIC:
                # slice-like ops touch only the slice region of their
                # (possibly huge) operands — e.g. the layer scan's
                # dynamic-slice of stacked params must not charge the whole
                # stack every iteration.
                if op in ("dynamic-slice", "slice", "gather"):
                    total.bytes += 2.0 * ins.result_bytes          # read+write slice
                elif op in ("dynamic-update-slice", "scatter"):
                    ops_ = ins.operand_names()
                    idx = 1 if op == "dynamic-update-slice" else 2
                    upd = comp.instrs.get(ops_[idx]) if len(ops_) > idx else None
                    total.bytes += 2.0 * (upd.result_bytes if upd else ins.result_bytes)
                elif op == "broadcast":
                    total.bytes += ins.result_bytes + min(
                        self._operand_bytes(comp, ins), ins.result_bytes
                    )
                elif op == "fusion":
                    dus_bytes = self._inplace_dus_fusion_bytes(ins)
                    if dus_bytes is not None:
                        total.bytes += dus_bytes
                    else:
                        total.bytes += ins.result_bytes + self._fusion_operand_bytes(comp, ins)
                else:
                    total.bytes += ins.result_bytes + self._operand_bytes(comp, ins)
            # --- recurse into called computations -------------------------
            if op == "while":
                trip = self._trip_count(comp, ins)
                if trip is None:
                    trip = 1
                    total.unknown_trip_counts += 1
                bm = _BODY_RE.search(ins.rest)
                cm = _COND_RE.search(ins.rest)
                if bm:
                    total.add(self.cost(bm.group(1), top_level=top_level), scale=trip)
                if cm:
                    total.add(self.cost(cm.group(1), top_level=top_level), scale=trip)
            elif op in ("fusion", "async-start"):
                m = _CALLS_RE.search(ins.rest)
                if m:
                    total.add(self.cost(m.group(1), top_level=False))
            elif op == "call":
                m = _CALLS_RE.search(ins.rest)
                if m:
                    total.add(self.cost(m.group(1), top_level=top_level))
            elif op == "conditional":
                m = _BRANCH_RE.search(ins.rest)
                if m:
                    branches = [
                        b.strip().lstrip("%") for b in m.group(1).split(",") if b.strip()
                    ]
                    costs = [self.cost(b, top_level=top_level) for b in branches]
                    if costs:
                        # charge the most expensive branch
                        total.add(max(costs, key=lambda c: c.flops + c.bytes))
        self._memo[key] = total
        return total


def analyze_hlo(text: str) -> CostTotals:
    """Parse + roll up a compiled module's per-device cost."""
    return HloModule(text).cost()
