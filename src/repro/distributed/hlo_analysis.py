"""HLO analysis: collective-traffic extraction and roofline terms.

``cost_analysis()`` reports FLOPs and bytes-accessed but NOT collective
traffic, so we parse the (optimized) HLO text and sum the operand sizes of
every communication op:

    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
    (+ their -start async forms; -done forms are skipped to avoid double
    counting, as are `*-update`s of the same op).

Operand sizes are read from the typed operand list the HLO printer emits,
e.g. ``%ar = bf16[256,1024] all-reduce(bf16[256,1024] %add.7), ...``.

Roofline terms (per the brief, TPU v5e):
    compute    = HLO_FLOPs      / (chips · 197e12 FLOP/s)
    memory     = HLO_bytes      / (chips · 819e9  B/s)
    collective = collective_B   / (chips · 50e9   B/s per ICI link)
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

# typed tensor token, e.g. bf16[8,128]{1,0} or f32[] ; captures dtype + dims
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:[a-z0-9]*)?|pred|token)\[([0-9,]*)\]")
# "%name = <result-type> <opcode>(<operands>)"
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(COLLECTIVE_OPS) + r")(-start)?\((.*)\)\s*(?:,|$)"
)


def shape_bytes(dtype: str, dims: str) -> float:
    nb = DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0.0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * nb


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float]
    count_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def summary(self) -> str:
        parts = [
            f"{op}: {cnt}x {self.bytes_by_op[op] / 1e9:.3f} GB"
            for op, cnt in sorted(self.count_by_op.items())
        ]
        return "; ".join(parts) if parts else "none"


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective in an HLO module dump."""
    bytes_by: Dict[str, float] = {}
    count_by: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        operands = m.group(3)
        nb = sum(shape_bytes(d, s) for d, s in _SHAPE_RE.findall(operands))
        bytes_by[op] = bytes_by.get(op, 0.0) + nb
        count_by[op] = count_by.get(op, 0) + 1
    return CollectiveStats(bytes_by_op=bytes_by, count_by_op=count_by)


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

TPU_V5E = {
    "peak_flops": 197e12,   # bf16 per chip
    "hbm_bw": 819e9,        # B/s per chip
    "ici_bw": 50e9,         # B/s per link
}


@dataclasses.dataclass
class Roofline:
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * TPU_V5E["peak_flops"])

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * TPU_V5E["hbm_bw"])

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * TPU_V5E["ici_bw"])

    @property
    def bound(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute term / dominant term — 1.0 means pure compute-bound
        (the best the hardware can do for this program)."""
        return self.t_compute / self.t_bound if self.t_bound else 0.0

    def as_dict(self) -> dict:
        return {
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bound": self.bound,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def cost_flops_bytes(cost: dict) -> tuple:
    """Extract (flops, bytes-accessed) from compiled.cost_analysis()."""
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    if nbytes == 0.0:
        nbytes = sum(
            float(v) for k, v in cost.items() if k.startswith("bytes accessed")
        )
    return flops, nbytes
