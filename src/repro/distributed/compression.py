"""Gradient compression: block-wise int8 quantization with error feedback.

Targets the cross-pod gradient all-reduce — the one collective that crosses
the slow inter-pod links in the 2×16×16 multi-pod mesh.  Params are
replicated across pods (pure DP), so each step moves
``2·(P-1)/P · param_bytes`` per pod over DCI; int8 cuts that 2× vs bf16
(4× vs f32) at the cost of one extra max-reduce for the scales.

Error feedback (Seide et al.; EF-SGD) keeps the quantization bias from
accumulating: the residual of each step's quantization is added back before
the next step's quantization — convergence-neutral for smooth objectives
(demonstrated on a quadratic in tests/test_compression.py).

``pod_psum_compressed`` is designed for use inside shard_map with the "pod"
axis manual and data/model auto (see training/steps.py).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _blockify(x, block: int):
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = (n + block - 1) // block
    pad = nb * block - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(nb, block), n


def quantize_int8(x, *, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Block-wise symmetric int8.  Returns (q (nb, block) int8, scale (nb,1))."""
    xb, _ = _blockify(x.astype(jnp.float32), block)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, shape, *, block: int = 256):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def quantization_error(x, *, block: int = 256):
    q, s = quantize_int8(x, block=block)
    return x - dequantize_int8(q, s, x.shape, block=block).astype(x.dtype)


# ---------------------------------------------------------------------------
# Error-feedback compressed psum over a manual mesh axis
# ---------------------------------------------------------------------------


def init_error_feedback(params):
    """Zero residual pytree matching the grads."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(g, e, *, block: int = 256):
    """One tensor: returns (q, scale, new_residual)."""
    corrected = g.astype(jnp.float32) + e
    q, s = quantize_int8(corrected, block=block)
    deq = dequantize_int8(q, s, g.shape, block=block)
    return q, s, corrected - deq


def pod_psum_compressed(grads, error_fb, *, axis: str = "pod", block: int = 256):
    """All-reduce ``grads`` over the (manual) ``axis`` with an int8 wire.

    Scheme (shared-scale, overflow-safe):
      1. shared block scale  s = pmax(|g/n + e|) / (127 / n)   (4 B/block wire)
      2. q = round(x / s) ∈ [-127/n, 127/n]  int8
      3. psum(q) ∈ [-127, 127] — fits int8, so the big collective moves
         1 B/element instead of 4 (f32) or 2 (bf16)
      4. g_red = psum(q)·s ; error feedback keeps the n×-coarser grid
         from biasing updates.

    Returns (reduced mean-gradient f32 pytree, new error-feedback pytree).
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        x = g.astype(jnp.float32) / n + e
        xb, total = _blockify(x, block)
        local_max = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
        gmax = jax.lax.pmax(local_max, axis)               # tiny wire: 4 B/block
        scale = jnp.maximum(gmax / (127.0 / n), 1e-12)
        q = jnp.clip(jnp.round(xb / scale), -127.0 / n, 127.0 / n).astype(jnp.int8)
        q_sum = jax.lax.psum(q, axis)                      # big wire: 1 B/elem
        red = (q_sum.astype(jnp.float32) * scale).reshape(-1)[:total].reshape(g.shape)
        e_new = (x - (q.astype(jnp.float32) * scale).reshape(-1)[:total].reshape(g.shape))
        return red, e_new

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_fb)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    g_new = tdef.unflatten([o[0] for o in out])
    e_new = tdef.unflatten([o[1] for o in out])
    return g_new, e_new
