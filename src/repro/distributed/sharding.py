"""Sharding rules: parameter specs, activation policy, batch specs.

Mesh axes (see launch/mesh.py):
  single-pod:  ("data", "model")          = (16, 16)
  multi-pod:   ("pod", "data", "model")   = (2, 16, 16)

Policy (the paper's per-layer {width | output-channel} tiling choice, as a
sharding selector — DESIGN.md §3):

* **Params**: tensor-parallel over "model" on the width dimension
  (heads·d_head, d_ff, experts, vocab), FSDP over "data" on the other
  dimension.  Params are REPLICATED over "pod" (pure DP across pods; the
  cross-pod gradient all-reduce is the compressible collective).
* **Activations**: batch over ("pod", "data"); TP dims over "model".
* **Fallbacks** (recorded per-arch): a dim that doesn't divide the axis size
  is left unsharded — e.g. starcoder2's 36 heads on a 16-way model axis make
  per-head attention TP impossible, so its attention runs sequence-sharded
  (the "width tiling" arm of the paper's chooser) while its FFN stays
  output-channel-sharded.

Everything here is *structural* — specs are built by walking the same period
structure as ``models.transformer.init_params``, so the two pytrees match by
construction (asserted in tests/test_sharding.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import LayerSpec, period_structure


# ---------------------------------------------------------------------------
# Axis helpers
# ---------------------------------------------------------------------------


def shard_map_compat(f, mesh: Mesh, *, in_specs, out_specs, manual_axes):
    """Partial-manual shard_map across jax versions: ``jax.shard_map``
    (axis_names = the MANUAL axes) on new jax, else
    ``jax.experimental.shard_map.shard_map`` (auto = the complement)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=set(manual_axes), check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
        auto=frozenset(mesh.axis_names) - frozenset(manual_axes),
    )


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_shard(mesh: Mesh, batch: int):
    """Largest prefix of the data axes that divides ``batch`` (None if the
    batch can't be sharded at all, e.g. global_batch=1 long-context)."""
    axes = []
    prod = 1
    for a in data_axes(mesh):
        if batch % (prod * mesh_axis_size(mesh, a)) == 0:
            axes.append(a)
            prod *= mesh_axis_size(mesh, a)
        else:
            break
    return tuple(axes) if axes else None


class _Div:
    """Divisibility-gated axis chooser for one mesh.

    ``fsdp=False`` disables the "data"-axis param sharding: the serving
    layout.  FSDP weights are fatal for decode — every token re-gathers the
    full parameter set (measured ~0.77 TB/step/device on command-r
    decode_32k, EXPERIMENTS.md §Perf); TP-only weights read locally."""

    def __init__(self, mesh: Mesh, *, fsdp: bool = True, moe_ep: bool = True):
        self.mesh = mesh
        self.model = mesh_axis_size(mesh, "model")
        self.data = mesh_axis_size(mesh, "data")
        self.fsdp = fsdp
        self.moe_ep = moe_ep

    def m(self, dim: int):
        return "model" if dim % self.model == 0 else None

    def d(self, dim: int):
        if not self.fsdp:
            return None
        return "data" if dim % self.data == 0 else None


# ---------------------------------------------------------------------------
# Parameter specs (mirrors models/*.init_* structures)
# ---------------------------------------------------------------------------


def _spec_attn(cfg, dv: _Div, *, cross: bool = False) -> Dict[str, Any]:
    p = {
        "wq": P(dv.d(cfg.d_model), dv.m(cfg.q_dim)),
        "wk": P(dv.d(cfg.d_model), dv.m(cfg.kv_dim)),
        "wv": P(dv.d(cfg.d_model), dv.m(cfg.kv_dim)),
        "wo": P(dv.m(cfg.q_dim), dv.d(cfg.d_model)),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = {"scale": P(None)}
        p["k_norm"] = {"scale": P(None)}
    return p


def _spec_mlp(cfg, dv: _Div, d_ff: int, *, kind: str = None) -> Dict[str, Any]:
    kind = cfg.mlp_kind if kind is None else kind
    wi_out = 2 * d_ff if kind == "swiglu" else d_ff
    return {
        "wi": P(dv.d(cfg.d_model), dv.m(wi_out)),
        "wo": P(dv.m(d_ff), dv.d(cfg.d_model)),
    }


def _spec_moe(cfg, dv: _Div) -> Dict[str, Any]:
    m = cfg.moe
    p: Dict[str, Any] = {"router": P(dv.d(cfg.d_model), None)}
    if m.n_experts % dv.model == 0 and dv.moe_ep:
        # expert parallelism: experts over "model".  NOTE: under GSPMD the
        # dense dispatch (scatter into model-sharded buckets) reshards the
        # capacity buffers every layer — measured 8.6 TB/step/device of
        # all-reduce on jamba train_4k; expert-TP below avoids it entirely
        # (EXPERIMENTS.md §Perf cell 2), so moe_ep=False is the optimized
        # default for training cells.
        p["wi"] = P("model", dv.d(cfg.d_model), None)
        p["wo"] = P("model", None, dv.d(cfg.d_model))
    else:
        # TP within each expert: buckets stay local to each device's tokens
        # (zero dispatch collectives), each expert's width is model-sharded;
        # per-device FLOPs identical to EP.
        p["wi"] = P(None, dv.d(cfg.d_model), dv.m(2 * m.expert_d_ff))
        p["wo"] = P(None, dv.m(m.expert_d_ff), dv.d(cfg.d_model))
    if m.n_shared_experts:
        p["shared"] = _spec_mlp(cfg, dv, m.n_shared_experts * (m.shared_d_ff or m.expert_d_ff), kind="swiglu")
    return p


def _spec_ssm(cfg, dv: _Div) -> Dict[str, Any]:
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_ssm_heads(cfg.d_model)
    gn = s.n_groups * s.d_state
    return {
        "wz": P(dv.d(cfg.d_model), dv.m(d_in)),
        "wx": P(dv.d(cfg.d_model), dv.m(d_in)),
        "wb": P(dv.d(cfg.d_model), dv.m(gn)),
        "wc": P(dv.d(cfg.d_model), dv.m(gn)),
        "wdt": P(dv.d(cfg.d_model), dv.m(nh)),
        "conv_w": P(None, None),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "norm": {"scale": P(None)},
        "out_proj": P(dv.m(d_in), dv.d(cfg.d_model)),
    }


def _spec_layer(cfg, dv: _Div, spec: LayerSpec, *, cross: bool) -> Dict[str, Any]:
    p: Dict[str, Any] = {"ln1": {"scale": P(None)}}
    if spec.mixer == "attn":
        p["attn"] = _spec_attn(cfg, dv)
    else:
        p["ssm"] = _spec_ssm(cfg, dv)
    if cross:
        p["ln_x"] = {"scale": P(None)}
        p["cross"] = _spec_attn(cfg, dv, cross=True)
    if spec.mlp is not None:
        p["ln2"] = {"scale": P(None)}
        if spec.mlp == "moe":
            p["moe"] = _spec_moe(cfg, dv)
        else:
            p["mlp"] = _spec_mlp(cfg, dv, cfg.d_ff)
    return p


def _add_leading(tree, axis=None):
    """Stacked-block params get an unsharded leading (block) axis."""
    return jax.tree.map(
        lambda s: P(axis, *s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def param_specs(cfg, mesh: Mesh, *, fsdp: bool = True, moe_ep: bool = True) -> Dict[str, Any]:
    """PartitionSpec pytree structurally matching models.init_params(cfg).

    Embedding tables are vocab-sharded over "model" with the feature dim
    REPLICATED (not FSDP): the lookup runs as a vocab-parallel masked gather
    + psum (Megatron-style, see ``make_policy``), and the tied LM head then
    produces vocab-sharded logits with zero resharding.  A d-sharded table
    would force XLA's "involuntary full rematerialization" of the gather —
    a 6.3 GB table replication per chip at command-r scale."""
    dv = _Div(mesh, fsdp=fsdp, moe_ep=moe_ep)
    specs = period_structure(cfg)
    cross = cfg.family == "audio"
    embed_spec = P(dv.m(cfg.vocab_padded), None)
    out: Dict[str, Any] = {
        "embed": {"w": embed_spec},
        "final_norm": {"scale": P(None)},
        "blocks": [
            _add_leading(_spec_layer(cfg, dv, s, cross=cross)) for s in specs
        ],
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = {"w": embed_spec}
    if cfg.family == "audio":
        enc_spec = LayerSpec(mixer="attn", mlp="mlp")
        out["encoder"] = {
            "blocks": [_add_leading(_spec_layer(cfg, dv, enc_spec, cross=False))],
            "final_norm": {"scale": P(None)},
        }
    return out


def param_shardings(cfg, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(cfg, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Activation policy (with_sharding_constraint hooks inside the model)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ActivationPolicy:
    """Callable passed as ``policy=`` into model forward functions.

    Also carries the vocab-parallel embedding lookup (``embed``): a masked
    local gather + psum over the "model" axis under partial-manual shard_map
    — Megatron's vocab-parallel embedding, avoiding XLA's gather-over-
    sharded-dim replication fallback.
    """

    mesh: Mesh
    batch_axes: Optional[Tuple[str, ...]]
    rules: Dict[str, P]
    vocab_parallel: bool = False
    # decode KV cache has its LENGTH axis sharded over "model" (set when the
    # arch's kv heads don't divide the model axis — see cache_specs); the
    # slot write must then use kv_slot_update.
    kv_len_sharded: bool = False

    def __call__(self, x, name: str):
        spec = self.rules.get(name)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def kv_slot_update(self, k_cache, v_cache, pos_cache, k_new, v_new, cur_pos):
        """Ring-buffer slot write for a LENGTH-sharded KV cache.

        A plain ``cache.at[b, slot].set(...)`` scatter across the
        model-sharded cache-length axis makes GSPMD reshard the whole cache
        ("involuntary full rematerialization" — measured as ~770 GB/step of
        HBM traffic on command-r decode_32k, EXPERIMENTS.md §Perf).  Under
        partial-manual shard_map each shard masks the write to its own slot
        range: zero collective, zero copy.

        k_cache/v_cache: (B, C, Hkv, dh) sharded (?, "model", None, None);
        pos_cache: (B, C); k_new/v_new: (B, Hkv, dh); cur_pos: (B,).
        """
        C = k_cache.shape[1]

        def upd(kc, vc, pc, kn, vn, cur):
            c_loc = kc.shape[1]
            lo = jax.lax.axis_index("model") * c_loc
            slot = (cur % C).astype(jnp.int32) - lo
            ok = (slot >= 0) & (slot < c_loc)
            safe = jnp.clip(slot, 0, c_loc - 1)
            b = jnp.arange(kc.shape[0])
            kc = kc.at[b, safe].set(
                jnp.where(ok[:, None, None], kn, kc[b, safe])
            )
            vc = vc.at[b, safe].set(
                jnp.where(ok[:, None, None], vn, vc[b, safe])
            )
            pc = pc.at[b, safe].set(
                jnp.where(ok, cur.astype(jnp.int32), pc[b, safe])
            )
            return kc, vc, pc

        return shard_map_compat(
            upd, self.mesh,
            in_specs=(
                P(None, "model"), P(None, "model"), P(None, "model"),
                P(), P(), P(),
            ),
            out_specs=(P(None, "model"), P(None, "model"), P(None, "model")),
            manual_axes={"model"},
        )(k_cache, v_cache, pos_cache, k_new, v_new, cur_pos)

    def embed(self, table, ids):
        """table: (Vp, d) vocab-sharded over "model"; ids: int32 (...)."""
        if not self.vocab_parallel:
            return jnp.take(table, ids, axis=0)

        def lookup(tbl, ids_):
            vloc = tbl.shape[0]
            lo = jax.lax.axis_index("model") * vloc
            local = ids_ - lo
            ok = (local >= 0) & (local < vloc)
            safe = jnp.clip(local, 0, vloc - 1)
            out = jnp.take(tbl, safe, axis=0)
            out = jnp.where(ok[..., None], out, 0)
            # psum in f32: exactly one shard contributes per row, so this is
            # value-exact; it also sidesteps an XLA-CPU AllReducePromotion
            # crash on bf16 all-reduces emitted inside partial-manual
            # shard_map (CloneAllReduce check-fails on the cloned region).
            return jax.lax.psum(out.astype(jnp.float32), "model").astype(tbl.dtype)

        return shard_map_compat(
            lookup, self.mesh,
            in_specs=(P("model", None), P()),
            out_specs=P(),
            manual_axes={"model"},
        )(table, ids)


def make_policy(cfg, mesh: Mesh, *, batch: int, moe_ep: bool = True) -> ActivationPolicy:
    ba = batch_shard(mesh, batch)
    dv = _Div(mesh)
    rules = {
        "hidden": P(ba, None, None),
        "residual": P(ba, None, None),
        "hidden_decode": P(ba, None, None),
        "logits": P(ba, None, dv.m(cfg.vocab_padded)),
    }
    if (cfg.moe is not None and moe_ep and dv.model > 1
            and cfg.moe.n_experts % dv.model == 0):
        # keep the MoE capacity buffers expert-sharded over "model": without
        # this GSPMD all-reduces the full (B,E,cap,2·dff) tensor every layer
        rules["moe_ecap"] = P(ba, "model", None, None)
    return ActivationPolicy(
        mesh=mesh, batch_axes=ba, rules=rules,
        # manual (shard_map) paths only make sense on a non-trivial axis —
        # a size-1 "model" axis trips XLA's manual-subgroup RET_CHECK
        vocab_parallel=dv.m(cfg.vocab_padded) is not None and dv.model > 1,
        kv_len_sharded=(
            cfg.family != "ssm" and cfg.n_kv_heads % dv.model != 0 and dv.model > 1
        ),
    )


# ---------------------------------------------------------------------------
# Batch / cache specs (inputs and outputs of the step functions)
# ---------------------------------------------------------------------------


def train_batch_specs(cfg, mesh: Mesh, *, batch: int) -> Dict[str, P]:
    ba = batch_shard(mesh, batch)
    specs = {"tokens": P(ba, None), "labels": P(ba, None)}
    if cfg.family == "vlm":
        specs["extra_embeds"] = P(ba, None, None)            # (B, Sv, d)
        specs["positions"] = P(None, ba, None)               # (3, B, S)
    if cfg.family == "audio":
        specs["frames"] = P(ba, None, None)                  # (B, S_enc, d)
    return specs


def cache_specs(cfg, mesh: Mesh, *, batch: int):
    """Spec pytree structurally matching ``models.transformer.Caches``.

    KV sharding policy: shard kv-heads over "model" when divisible; otherwise
    shard the cache-length axis over "model" (flash-decoding style partial
    softmax, handled by GSPMD's sharded-softmax rewrite).  Batch over the
    data axes when divisible (decode_32k), else unsharded (long_500k B=1,
    where the length axis carries all the parallelism).
    """
    from repro.models.attention import KVCacheView
    from repro.models.ssm import SSMState
    from repro.models.transformer import Caches

    dv = _Div(mesh)
    ba = batch_shard(mesh, batch)
    specs = period_structure(cfg)
    kv: Dict[str, Any] = {}
    ssm: Dict[str, Any] = {}
    kv_heads_ok = cfg.n_kv_heads % dv.model == 0
    for p, sp in enumerate(specs):
        if sp.mixer == "attn":
            if kv_heads_ok:
                kvspec = P(None, ba, None, "model", None)
                pspec = P(None, ba, None)
            else:
                kvspec = P(None, ba, "model", None, None)
                pspec = P(None, ba, "model")
            kv[str(p)] = KVCacheView(k=kvspec, v=kvspec, pos=pspec)
        else:
            s = cfg.ssm
            nh = s.n_ssm_heads(cfg.d_model)
            ssm[str(p)] = SSMState(
                conv=P(None, ba, None, None),   # (K-1)-row window: tiny, replicate channels
                ssm=P(None, ba, dv.m(nh), None, None),
            )
    cross = None
    if cfg.family == "audio":
        cross = {
            str(p): (P(None, ba, None, None, None), P(None, ba, None, None, None))
            for p in range(len(specs))
        }
    return Caches(kv=kv, ssm=ssm, cross=cross)


# ---------------------------------------------------------------------------
# Tensor-parallel serving (full-manual shard_map over a flat ("tp",) mesh)
# ---------------------------------------------------------------------------
#
# The serving fast path shards ONE tenant's decode over the devices of its
# hypervisor lease: attention heads and MLP hidden features are split over a
# 1D "tp" axis, slot bookkeeping / page tables / draft state stay replicated,
# and each layer costs exactly two psums (attention output + MLP output).
# Unlike the train-side partial-manual policy above, these helpers run the
# model *entirely* inside shard_map (manual over every mesh axis) — the only
# mode the jax-0.4.37 SPMD partitioner handles without the PartitionId issue
# that gates tests/test_multidevice.py.  The trick that keeps the model code
# untouched: every program is traced with a *shard-local* cfg
# (n_heads/n_kv_heads/d_ff divided by tp, d_head unchanged), so per-shard
# shapes are just a smaller model, and the TPShardPolicy turns the two
# residual hooks ("attn_out"/"mlp_out") into psums.


class TPShardPolicy:
    """Activation policy for fully-manual tensor-parallel decode.

    Sums the row-sharded attention/MLP output projections over the "tp"
    axis; identity for every other rule name.  Deliberately has NO ``embed``
    attribute (the table is replicated, each shard does the plain take) and
    ``kv_len_sharded`` False (KV is sharded over *heads*, never length).
    """

    kv_len_sharded = False

    def __init__(self, axis: str = "tp") -> None:
        self.axis = axis

    def __call__(self, x, name: str):
        if name not in ("attn_out", "mlp_out"):
            return x
        if x.dtype == jnp.float32:
            return jax.lax.psum(x, self.axis)
        # psum in f32: XLA-CPU check-fails cloning bf16 all-reduces emitted
        # inside shard_map (AllReducePromotion), same issue as .embed above
        return jax.lax.psum(x.astype(jnp.float32), self.axis).astype(x.dtype)


#: Shared instance for the default "tp" axis.  The program registry keys on
#: policy *identity*, so every batcher (and every re-mesh) must shard
#: through the same object for same-shape programs to cache-hit; the policy
#: is stateless, so sharing it is free.
TP_POLICY = TPShardPolicy()


def tp_supported(cfg) -> Optional[str]:
    """None when ``cfg`` can tensor-shard on the serving path, else the
    reason it cannot (pure-attention dense-MLP text archs only — SSM state,
    MoE dispatch, and cross-attention caches have no head axis to split)."""
    if cfg.family in ("audio", "vlm"):
        return f"family {cfg.family!r} has cross-attention/encoder state"
    specs = period_structure(cfg)
    if any(s.mixer != "attn" for s in specs):
        return "SSM/hybrid archs have no head axis in their recurrent state"
    if any(s.mlp == "moe" for s in specs):
        return "MoE expert dispatch is not tensor-shardable on this path"
    return None


def check_tp(cfg, tp: int) -> None:
    """Validate that ``cfg`` divides into ``tp`` shards; raises ValueError."""
    why = tp_supported(cfg)
    if why is not None:
        raise ValueError(f"tp={tp} unsupported for {cfg.name}: {why}")
    for dim, val in (("n_heads", cfg.n_heads), ("n_kv_heads", cfg.n_kv_heads),
                     ("d_ff", cfg.d_ff)):
        if val % tp:
            raise ValueError(
                f"tp={tp} must divide {dim}={val} for {cfg.name}")


def tp_local_cfg(cfg, tp: int):
    """The shard-local model: heads and hidden width divided by tp.  d_head
    is an explicit field (set in __post_init__), so it survives the replace;
    vocab / rope / norms are untouched (embeddings stay replicated)."""
    if tp <= 1:
        return cfg
    check_tp(cfg, tp)
    return dataclasses.replace(
        cfg,
        n_heads=cfg.n_heads // tp,
        n_kv_heads=cfg.n_kv_heads // tp,
        d_ff=cfg.d_ff // tp,
    )


def make_tp_mesh(tp: int, devices=None) -> Mesh:
    """Flat 1D ("tp",) mesh over ``devices`` (default: the first ``tp``
    process devices) — the per-tenant sub-mesh a hypervisor lease maps to."""
    import numpy as np

    if devices is None:
        devices = jax.devices()
    devices = list(devices)
    if len(devices) < tp:
        raise ValueError(f"need {tp} devices for tp={tp}, have {len(devices)}")
    return Mesh(np.asarray(devices[:tp]), ("tp",))


def _swiglu_tp_perm(d_ff: int, tp: int):
    """Column permutation putting swiglu's packed [gate | up] wi into
    per-shard-contiguous [gate_i | up_i] blocks, so a plain contiguous
    chunking over the last axis hands shard i exactly its gate/up columns
    (and the silu(gate_i)*up_i features line up with wo's row shard i)."""
    import numpy as np

    f = d_ff // tp
    idx = []
    for i in range(tp):
        idx.extend(range(i * f, (i + 1) * f))
        idx.extend(range(d_ff + i * f, d_ff + (i + 1) * f))
    return np.asarray(idx, dtype=np.int64)


def permute_params_for_tp(params, cfg, tp: int):
    """Host-side relayout making every sharded matrix *contiguously*
    chunkable over its tp axis.  Only swiglu's packed wi needs moving;
    attention projections are head-contiguous already (contiguous head
    chunks preserve the GQA group ratio because tp divides both head
    counts).  Returns a new pytree; leaves come back as host numpy."""
    import numpy as np

    host = jax.device_get(params)
    if tp <= 1 or cfg.mlp_kind != "swiglu":
        return host
    perm = _swiglu_tp_perm(cfg.d_ff, tp)
    out = dict(host)
    out["blocks"] = [dict(layer) for layer in host["blocks"]]
    for layer in out["blocks"]:
        if "mlp" in layer:
            m = dict(layer["mlp"])
            m["wi"] = np.ascontiguousarray(np.asarray(m["wi"])[..., perm])
            layer["mlp"] = m
    return out


def tp_param_specs(cfg) -> Dict[str, Any]:
    """PartitionSpec pytree over the "tp" axis, structurally matching
    ``init_params`` for the pure-attention archs ``check_tp`` admits.
    Attention q/k/v are column-sharded (head-contiguous), output projections
    row-sharded; embeddings / lm_head / every norm scale replicated.  All
    leaves carry the leading stacked-blocks axis (hence the extra None)."""
    attn: Dict[str, Any] = {
        "wq": P(None, None, "tp"),
        "wk": P(None, None, "tp"),
        "wv": P(None, None, "tp"),
        "wo": P(None, "tp", None),
    }
    if cfg.qk_norm:
        attn["q_norm"] = {"scale": P()}
        attn["k_norm"] = {"scale": P()}
    layer = {
        "ln1": {"scale": P()},
        "attn": attn,
        "ln2": {"scale": P()},
        "mlp": {"wi": P(None, None, "tp"), "wo": P(None, "tp", None)},
    }
    out: Dict[str, Any] = {
        "embed": {"w": P()},
        "final_norm": {"scale": P()},
        "blocks": [layer for _ in period_structure(cfg)],
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = {"w": P()}
    return out


def tp_cache_specs(cfg, *, paged: bool):
    """Spec pytree matching serving's ``Caches``: K/V sharded over the head
    axis (axis 3 of both the dense ring and the page pool), positions
    replicated."""
    from repro.models.attention import KVCacheView, PagedKVView
    from repro.models.transformer import Caches

    kvspec = P(None, None, None, "tp", None)
    kv: Dict[str, Any] = {}
    for p, sp in enumerate(period_structure(cfg)):
        if sp.mixer != "attn":        # unreachable under check_tp; defensive
            raise ValueError("tp caches require a pure-attention arch")
        if paged:
            kv[str(p)] = PagedKVView(k=kvspec, v=kvspec)
        else:
            kv[str(p)] = KVCacheView(k=kvspec, v=kvspec, pos=P())
    return Caches(kv=kv, ssm={}, cross=None)


def tp_shardings(mesh: Mesh, spec_tree):
    """NamedShardings for a spec pytree (PartitionSpec leaves)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def tp_put_replicated(mesh: Mesh, tree):
    """device_put every leaf of ``tree`` replicated over the tp mesh (slot
    bookkeeping, page tables, draft state, PRNG keys)."""
    sh = NamedSharding(mesh, P())
    return jax.tree.map(lambda a: jax.device_put(a, sh), tree)
