"""Distribution layer: sharding rules, HLO analysis, gradient compression."""

from .sharding import (
    ActivationPolicy,
    batch_shard,
    cache_specs,
    data_axes,
    make_policy,
    mesh_axis_size,
    param_shardings,
    param_specs,
    train_batch_specs,
)
from .hlo_analysis import CollectiveStats, Roofline, collective_stats, cost_flops_bytes
from .compression import (
    dequantize_int8,
    init_error_feedback,
    pod_psum_compressed,
    quantize_int8,
)

__all__ = [
    "ActivationPolicy", "batch_shard", "cache_specs", "data_axes",
    "make_policy", "mesh_axis_size", "param_shardings", "param_specs",
    "train_batch_specs", "CollectiveStats", "Roofline", "collective_stats",
    "cost_flops_bytes", "dequantize_int8", "init_error_feedback",
    "pod_psum_compressed", "quantize_int8",
]
