from . import ops, ref
from .kernel import rmsnorm_kernel
from .ops import rmsnorm

__all__ = ["rmsnorm", "rmsnorm_kernel", "ops", "ref"]
