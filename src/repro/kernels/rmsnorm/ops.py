"""jit'd public wrapper for fused RMSNorm."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from ..common import default_interpret
from .kernel import rmsnorm_kernel


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: Optional[bool] = None):
    """x: (..., d); scale: (d,)."""
    interpret = default_interpret() if interpret is None else interpret
    shape = x.shape
    out = rmsnorm_kernel(
        x.reshape(-1, shape[-1]), scale, eps=eps, block_rows=block_rows,
        interpret=interpret,
    )
    return out.reshape(shape)
