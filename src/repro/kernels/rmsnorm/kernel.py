"""Fused RMSNorm Pallas TPU kernel.

Memory-bound elementwise+reduction op: the win over the unfused XLA lowering
is a single HBM round-trip (read x, write y) instead of separate
square/mean/rsqrt/mul kernels when XLA's fuser declines (it usually fuses,
but the kernel also serves as the template for the fused residual+norm and
gated-norm variants used by the Mamba blocks).

Grid over row blocks; the full feature dim stays resident in VMEM
(d ≤ 12288 → ≤ 24 KiB/row at bf16 — trivially fits; block_rows picked so a
tile is ~1 MiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import cdiv


def _rms_kernel(x_ref, s_ref, o_ref, *, eps: float, n_rows: int, block_rows: int):
    ri = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                    # (br, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    # mask padded tail rows (harmless garbage otherwise, but keep it clean)
    rows = ri * block_rows + jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    y = jnp.where(rows < n_rows, y, 0.0)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_kernel(x2d, scale, *, eps: float = 1e-6, block_rows: int = 256,
                   interpret: bool = False):
    """x2d: (T, d); scale: (d,) → (T, d)."""
    T, d = x2d.shape
    block_rows = min(block_rows, T)
    n_b = cdiv(T, block_rows)
    pad = n_b * block_rows - T
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    kern = functools.partial(_rms_kernel, eps=eps, n_rows=T, block_rows=block_rows)
    out = pl.pallas_call(
        kern,
        grid=(n_b,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((n_b * block_rows, d), x2d.dtype),
        interpret=interpret,
    )(x2d, scale)
    return out[:T] if pad else out
