"""Pallas TPU kernels for the compute hot-spots.

Each kernel package has kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd model-layout wrapper), and ref.py (pure-jnp oracle).  On
non-TPU backends kernels run with interpret=True (see common.py).

  flash_attention  — prefill/training attention (GQA, causal, window)
  decode_attention — flash-decoding vs ring-buffer KV cache
  paged_attention  — flash-decoding vs paged pool; page-table walk in-kernel
                     via scalar prefetch (no materialized gather)
  prefix_attention — suffix prefill vs cached-prefix + fresh K/V (no concat)
  ssd_scan         — Mamba-2 chunked state-space scan
  rmsnorm          — fused normalization
  matmul           — Eq.-1 (PP, ICP, OCP) -> (block_m, block_k, block_n) tiling
"""

from . import common  # noqa: F401
