from . import ops, ref
from .kernel import matmul_kernel
from .ops import matmul

__all__ = ["matmul", "matmul_kernel", "ops", "ref"]
