"""Tiled matmul Pallas TPU kernel — the paper's Eq. 1 transposed to the MXU.

The Angel-Eye PE array computes ``2·PP·ICP·OCP`` OPs/cycle by tiling the
output feature map over (pixels, in-channels, out-channels).  On TPU the
same three tiling degrees become the (block_m, block_k, block_n) VMEM tile
of a matmul feeding the 128×128 systolic MXU:

    PP  (pixel parallelism)          → block_m   (rows / tokens / pixels)
    ICP (input-channel parallelism)  → block_k   (contraction)
    OCP (output-channel parallelism) → block_n   (output features)

The utilization-cliff argument of Eq. 2 (ceil-quantization of work to the
tile) is exactly why block dims must divide into 128-multiples here; the
latency simulator's ``compute_tile=(8, 128, 128)`` TPU model prices the same
effect for the scheduling layer.

Grid = (nM, nN, nK), K innermost; partial products accumulate in an f32
VMEM scratch tile and are cast out once on the last K step (one HBM write
per output tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import cdiv


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(ki == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_kernel(
    a, b, *, block_m: int = 512, block_n: int = 512, block_k: int = 512,
    out_dtype=None, interpret: bool = False,
):
    """a: (M, K) @ b: (K, N) → (M, N) with f32 accumulation."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    out_dtype = out_dtype or a.dtype
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    nm, nn, nk = cdiv(M, block_m), cdiv(N, block_n), cdiv(K, block_k)
    pm, pn, pk = nm * block_m - M, nn * block_n - N, nk * block_k - K
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))

    out = pl.pallas_call(
        functools.partial(_mm_kernel, n_k=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((nm * block_m, nn * block_n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:M, :N] if (pm or pn) else out
