"""Pure-jnp oracle for the tiled matmul kernel."""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a, b, *, out_dtype=None):
    """a: (M, K); b: (K, N) — f32 accumulation."""
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    return out.astype(out_dtype or a.dtype)
