"""jit'd public wrapper for the tiled matmul."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from ..common import default_interpret
from .kernel import matmul_kernel


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret")
)
def matmul(
    a, b, *, block_m: int = 512, block_n: int = 512, block_k: int = 512,
    out_dtype=None, interpret: Optional[bool] = None,
):
    """(..., K) @ (K, N) — leading dims of ``a`` are flattened into M."""
    interpret = default_interpret() if interpret is None else interpret
    lead = a.shape[:-1]
    a2 = a.reshape(-1, a.shape[-1])
    out = matmul_kernel(
        a2, b, block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out.reshape(*lead, b.shape[-1])
