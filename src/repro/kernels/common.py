"""Shared kernel plumbing.

All Pallas kernels in this package target TPU (BlockSpec VMEM tiling,
128-aligned MXU dims).  On non-TPU backends (this CPU container) they run in
``interpret=True`` mode, which executes the kernel body per grid step in
Python — bit-exact semantics, no TPU required.
"""

from __future__ import annotations

import functools

import jax


@functools.cache
def default_interpret() -> bool:
    return jax.devices()[0].platform != "tpu"


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


NEG_INF = -1e30
