"""Flash-attention Pallas TPU kernel (forward).

Layout: q (B, H, Sq, dh), k/v (B, Hkv, Sk, dh) — heads-major so each grid
cell owns one (batch, head) pair and BlockSpec index maps implement GQA
(kv head = q head // group) without materializing the expanded K/V.

Grid = (B, H, nQ, nK) — the KV-block axis is the innermost (sequential on
TPU), so the online-softmax state (m, l, acc) lives in VMEM scratch and is
carried across the nK steps of each (b, h, qi) cell:

  step ki == 0      → init scratch
  every step        → one (block_q × block_k) score tile on the MXU,
                      online-softmax rescale, accumulate P·V
  step ki == nK-1   → normalize and write the output tile

Fully-masked tiles (causal: k-block entirely above the diagonal; window:
k-block entirely expired) are skipped with @pl.when, so the causal schedule
does ~half the MXU work — the same utilization argument as the paper's
tiling Eq. 2.

VMEM budget per grid cell (block_q = block_k = 512, dh = 128, f32 scratch):
q/k/v tiles 3·512·128·2B ≈ 0.4 MiB, acc 512·128·4B = 0.25 MiB — far under
the ~128 MiB/core VMEM of v5e, leaving room for double-buffered prefetch.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import NEG_INF, cdiv


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, causal: bool, window: Optional[int], q_offset: int,
    block_q: int, block_k: int, sk: int, n_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute row/col ranges of this tile
    q_lo = qi * block_q + q_offset
    k_lo = ki * block_k

    # tile-level skip: causal ⇒ skip tiles fully above the diagonal;
    # window ⇒ skip tiles fully expired.  (q rows are q_lo..q_lo+bq-1)
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_lo <= q_lo + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k_lo + block_k - 1 > q_lo - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) / jnp.sqrt(jnp.float32(q.shape[-1]))          # (bq, bk)

        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = cols < sk                                # Sk padding
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        if window is not None:
            mask = jnp.logical_and(mask, cols > rows - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                             # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        scale = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * scale + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * scale + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    q_offset: int = 0, block_q: int = 512, block_k: int = 512,
    interpret: bool = False,
):
    """q: (B, H, Sq, dh); k, v: (B, Hkv, Sk, dh) → (B, H, Sq, dh)."""
    B, H, Sq, dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    n_q = cdiv(Sq, block_q)
    n_k = cdiv(Sk, block_k)
    assert Sq % block_q == 0, (Sq, block_q)
    pad_k = n_k * block_k - Sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    grid = (B, H, n_q, n_k)
    kern = functools.partial(
        _fa_kernel, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, sk=Sk, n_k=n_k,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # m
            pltpu.VMEM((block_q, 1), jnp.float32),     # l
            pltpu.VMEM((block_q, dh), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)
