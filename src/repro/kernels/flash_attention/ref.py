"""Pure-jnp oracle for the flash-attention kernel (GQA + causal + window)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    q_offset: int = 0,
):
    """q: (B, H, Sq, dh); k, v: (B, Hkv, Sk, dh).  Returns (B, H, Sq, dh).

    Materialized-scores reference in f32 — the ground truth every kernel
    variant is asserted against.
    """
    B, H, Sq, dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = H // Hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(dh))
    qi = jnp.arange(Sq)[:, None] + q_offset
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)).astype(q.dtype)
