"""jit'd public wrapper for flash attention.

Accepts the model layout (B, S, H, dh) and handles transposition, GQA, and
interpret-mode fallback.  ``flash_attention`` is what
``models.attention.self_attention(impl="pallas")`` calls.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import default_interpret
from .kernel import flash_attention_kernel


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    q_offset: int = 0, block_q: int = 512, block_k: int = 512,
    interpret: Optional[bool] = None,
):
    """q: (B, Sq, H, dh); k, v: (B, Sk, Hkv, dh) → (B, Sq, H, dh)."""
    interpret = default_interpret() if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_kernel(
        qt, kt, vt, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return jnp.swapaxes(out, 1, 2)
