from . import ops, ref
from .kernel import flash_attention_kernel
from .ops import flash_attention

__all__ = ["flash_attention", "flash_attention_kernel", "ops", "ref"]
