from . import ops, ref
from .kernel import decode_attention_kernel
from .ops import decode_attention

__all__ = ["decode_attention", "decode_attention_kernel", "ops", "ref"]
