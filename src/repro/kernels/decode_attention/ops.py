"""jit'd public wrapper for decode attention (model layout adapters)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import default_interpret
from .kernel import decode_attention_kernel


@functools.partial(jax.jit, static_argnames=("window", "block_c", "interpret"))
def decode_attention(
    q, k, v, pos, cur_pos, *, window: Optional[int] = None,
    block_c: int = 1024, interpret: Optional[bool] = None,
):
    """q: (B, H, dh); k/v: (B, C, Hkv, dh); pos: (B, C); cur_pos: (B,).
    Returns (B, H, dh)."""
    interpret = default_interpret() if interpret is None else interpret
    B, H, dh = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, dh)
    kt = jnp.swapaxes(k, 1, 2)            # (B, Hkv, C, dh)
    vt = jnp.swapaxes(v, 1, 2)
    out = decode_attention_kernel(
        qg, kt, vt, pos, cur_pos[:, None].astype(jnp.int32),
        window=window, block_c=block_c, interpret=interpret,
    )
    return out.reshape(B, H, dh)
