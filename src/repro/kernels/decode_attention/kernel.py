"""Decode-attention (flash-decoding) Pallas TPU kernel.

One new token per sequence attends to a ring-buffer KV cache.  Decode is
memory-bandwidth-bound (every KV byte is read once per token), so the kernel
is organized to stream K/V through VMEM in large contiguous blocks:

Grid = (B, Hkv, nC): each cell owns one (batch, kv-head) pair; the C
(cache-slot) axis is innermost and carries online-softmax scratch across
steps exactly like the prefill kernel.  All ``group`` q-heads that share the
kv head ride along in the same cell — they reuse the streamed K/V block from
VMEM ``group`` times, which is the GQA arithmetic-intensity win (paper
Eq. 2's ICP/OCP reuse, transposed to the memory hierarchy).

Validity masking comes from the stored absolute positions (``pos`` array) —
this is what makes the ring buffer work without data movement: a slot is
attendable iff ``0 <= pos[slot] <= cur_pos`` (and within the sliding window
if one is configured).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import NEG_INF, cdiv


def _dec_kernel(
    q_ref, k_ref, v_ref, pos_ref, cur_ref, o_ref, m_ref, l_ref, acc_ref,
    *, window: Optional[int], block_c: int, n_c: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                # (group, dh)
    k = k_ref[0, 0].astype(jnp.float32)                # (bc, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    pos = pos_ref[0]                                   # (bc,)
    cur = cur_ref[0]                                   # scalar

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(q.shape[-1]))             # (group, bc)

    valid = jnp.logical_and(pos >= 0, pos <= cur)
    if window is not None:
        valid = jnp.logical_and(valid, pos > cur - window)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]                                # (group, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    scale = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * scale + p.sum(axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * scale + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )

    @pl.when(ci == n_c - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_kernel(
    q, k, v, pos, cur_pos, *, window: Optional[int] = None,
    block_c: int = 1024, interpret: bool = False,
):
    """q: (B, Hkv, group, dh); k/v: (B, Hkv, C, dh); pos: (B, C);
    cur_pos: (B, 1) int32 → (B, Hkv, group, dh)."""
    B, Hkv, group, dh = q.shape
    C = k.shape[2]
    block_c = min(block_c, C)
    n_c = cdiv(C, block_c)
    pad = n_c * block_c - C
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)

    grid = (B, Hkv, n_c)
    kern = functools.partial(_dec_kernel, window=window, block_c=block_c, n_c=n_c)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, dh), lambda b, h, ci: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_c, dh), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, block_c, dh), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, block_c), lambda b, h, ci: (b, ci)),
            pl.BlockSpec((1, 1), lambda b, h, ci: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh), lambda b, h, ci: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, pos, cur_pos)
