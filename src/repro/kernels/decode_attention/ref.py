"""Pure-jnp oracle for the decode-attention kernel (ring-buffer KV)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, pos, cur_pos, *, window: Optional[int] = None):
    """q: (B, H, dh); k/v: (B, C, Hkv, dh); pos: (B, C) absolute positions
    (-1 = empty slot); cur_pos: (B,).  Returns (B, H, dh)."""
    B, H, dh = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, dh).astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))
    s = jnp.einsum("bgid,bkgd->bgik", qg, k.astype(jnp.float32))
    valid = (pos >= 0) & (pos <= cur_pos[:, None])
    if window is not None:
        valid &= pos > (cur_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgik,bkgd->bgid", w, v.astype(jnp.float32))
    return out.reshape(B, H, dh).astype(q.dtype)
