"""jit'd public wrapper for prefix-context flash attention (model layout).

``prefix_flash_attention`` is what ``models.attention.self_attention``
dispatches to when ``prefix_kv`` is set and ``impl == "pallas"``: suffix
queries attend to the cached prefix K/V plus the fresh suffix K/V without
ever concatenating the two (the XLA path's per-layer concat copy).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import default_interpret
from .kernel import prefix_flash_attention_kernel


@functools.partial(
    jax.jit, static_argnames=("q_offset", "block_q", "block_k", "interpret"))
def prefix_flash_attention(
    q, pk, pv, k, v, *, q_offset: int = 0,
    block_q: int = 512, block_k: int = 512,
    interpret: Optional[bool] = None,
):
    """q: (B, Sq, H, dh); pk/pv: (B, Lp, Hkv, dh); k/v: (B, Sk, Hkv, dh).
    Query row i is suffix position ``q_offset + i`` (chunked admission);
    it sees the full prefix and suffix cols ``<= q_offset + i``.
    Returns (B, Sq, H, dh)."""
    interpret = default_interpret() if interpret is None else interpret
    B, Sq, H, dh = q.shape
    qt = jnp.swapaxes(q, 1, 2)              # (B, H, Sq, dh)
    pkt = jnp.swapaxes(pk, 1, 2)
    pvt = jnp.swapaxes(pv, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    bq = min(block_q, Sq)
    pad_q = (-Sq) % bq
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    out = prefix_flash_attention_kernel(
        qt, pkt, pvt, kt, vt, q_offset=q_offset,
        block_q=bq, block_k=block_k, interpret=interpret,
    )
    if pad_q:
        out = out[:, :, :Sq]
    return jnp.swapaxes(out, 1, 2)
