"""Prefix-context flash-attention Pallas TPU kernel (suffix prefill).

Shared-prefix admission runs only the uncached tail of a prompt: suffix
queries attend to ``[cached prefix K/V ; fresh suffix K/V]``.  The XLA path
concatenates the two before its flash scan — a full extra copy of the
prefix context per layer.  This kernel keeps the two operands separate and
never materializes the concat: the innermost grid axis runs
``n_kp + n_ks`` steps, the first ``n_kp`` streaming prefix blocks, the rest
suffix blocks.  Each operand has its own BlockSpec whose index map *clamps*
into its own array during the other phase (consecutive equal block indices
make Pallas skip the re-fetch, so the idle operand costs one stale block in
VMEM, not bandwidth).

Masking: every prefix position precedes every suffix query row, so the
prefix phase needs only the padding mask (``col < Lp``); the suffix phase
applies the standard causal mask in suffix-local coordinates
(``col <= row + q_offset``), which is exactly rows ``[Lp:]`` of the
full-sequence causal attention — the cached==cold identity contract.
Online-softmax scratch (m, l, acc) is carried across both phases, as in
``kernels/flash_attention``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import NEG_INF, cdiv


def _pfx_kernel(
    q_ref, pk_ref, pv_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, lp: int, sk: int, q_offset: int,
    block_q: int, block_kp: int, block_ks: int, n_kp: int, n_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                # (bq, dh)

    def online_update(k, v, mask):
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) / jnp.sqrt(jnp.float32(q.shape[-1]))          # (bq, bk)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                             # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        scale = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * scale + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * scale + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )

    @pl.when(ki < n_kp)
    def _prefix_phase():
        # every prefix col precedes every suffix row: padding mask only
        cols = ki * block_kp + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kp), 1)
        online_update(pk_ref[0, 0].astype(jnp.float32),
                      pv_ref[0, 0].astype(jnp.float32), cols < lp)

    # suffix-local coordinates; tile-level causal skip as in flash_attention
    q_lo = qi * block_q + q_offset
    k_lo = (ki - n_kp) * block_ks

    @pl.when(jnp.logical_and(ki >= n_kp, k_lo <= q_lo + block_q - 1))
    def _suffix_phase():
        rows = q_lo + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_ks), 0)
        cols = k_lo + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_ks), 1)
        mask = jnp.logical_and(cols < sk, cols <= rows)
        online_update(k_ref[0, 0].astype(jnp.float32),
                      v_ref[0, 0].astype(jnp.float32), mask)

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def prefix_flash_attention_kernel(
    q, pk, pv, k, v, *, q_offset: int = 0,
    block_q: int = 512, block_k: int = 512, interpret: bool = False,
):
    """q: (B, H, Sq, dh); pk/pv: (B, Hkv, Lp, dh); k/v: (B, Hkv, Sk, dh)
    → (B, H, Sq, dh).  Suffix rows are causal with offset ``Lp + q_offset``
    over the virtual concat [prefix; suffix]; Sq must divide into block_q
    (the ops wrapper pads)."""
    B, H, Sq, dh = q.shape
    Hkv, Lp = pk.shape[1], pk.shape[2]
    Sk = k.shape[2]
    group = H // Hkv
    block_q = min(block_q, Sq)
    block_kp = min(block_k, Lp)
    block_ks = min(block_k, Sk)
    n_q = cdiv(Sq, block_q)
    n_kp = cdiv(Lp, block_kp)
    n_ks = cdiv(Sk, block_ks)
    n_k = n_kp + n_ks
    assert Sq % block_q == 0, (Sq, block_q)
    pad_p = n_kp * block_kp - Lp
    if pad_p:
        pk = jnp.pad(pk, ((0, 0), (0, 0), (0, pad_p), (0, 0)))
        pv = jnp.pad(pv, ((0, 0), (0, 0), (0, pad_p), (0, 0)))
    pad_s = n_ks * block_ks - Sk
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0)))

    grid = (B, H, n_q, n_k)
    kern = functools.partial(
        _pfx_kernel, lp=Lp, sk=Sk, q_offset=q_offset, block_q=block_q,
        block_kp=block_kp, block_ks=block_ks, n_kp=n_kp, n_k=n_k,
    )
    # clamped index maps: during the other phase an operand re-presents its
    # previous block (same index -> no DMA), so phases don't double-fetch
    pfx_map = lambda b, h, qi, ki: (b, h // group,
                                    jnp.minimum(ki, n_kp - 1), 0)
    sfx_map = lambda b, h, qi, ki: (b, h // group,
                                    jnp.clip(ki - n_kp, 0, n_ks - 1), 0)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_kp, dh), pfx_map),
            pl.BlockSpec((1, 1, block_kp, dh), pfx_map),
            pl.BlockSpec((1, 1, block_ks, dh), sfx_map),
            pl.BlockSpec((1, 1, block_ks, dh), sfx_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # m
            pltpu.VMEM((block_q, 1), jnp.float32),     # l
            pltpu.VMEM((block_q, dh), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, pk, pv, k, v)
