"""Pure-jnp oracle: materialized-concat prefix attention (the XLA path's
semantics — concat [prefix; suffix] K/V, causal over the virtual sequence)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import NEG_INF


def prefix_flash_attention_ref(q, pk, pv, k, v, *, q_offset=0):
    """Same layout as ``ops.prefix_flash_attention``: q (B, Sq, H, dh),
    pk/pv (B, Lp, Hkv, dh), k/v (B, Sk, Hkv, dh) → (B, Sq, H, dh).
    Query row i sits at suffix-local position ``q_offset + i``; it attends
    to the whole prefix plus suffix cols ``<= q_offset + i``."""
    B, Sq, H, dh = q.shape
    Lp = pk.shape[1]
    Sk = k.shape[1]
    Hkv = k.shape[2]
    group = H // Hkv

    kc = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
    vc = jnp.concatenate([pv.astype(v.dtype), v], axis=1)

    qg = q.reshape(B, Sq, Hkv, group, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc,
                   preferred_element_type=jnp.float32) / jnp.sqrt(jnp.float32(dh))
    rows = q_offset + jnp.arange(Sq, dtype=jnp.int32)
    cols = jnp.arange(Lp + Sk, dtype=jnp.int32) - Lp   # suffix-local; prefix < 0
    mask = cols[None, :] <= rows[:, None]
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(vc.dtype), vc,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, dh).astype(q.dtype)
