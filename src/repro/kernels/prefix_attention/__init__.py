"""Prefix-context flash attention: suffix prefill against cached prefix K/V."""
