"""SSD (Mamba-2 state-space duality) chunked-scan Pallas TPU kernel.

The hot spot of every SSM/hybrid arch.  The GPU reference implementation
(Triton, mamba_ssm) fuses the chunk-local quadratic with a warp-level state
carry; the TPU adaptation restructures it around the MXU and the sequential
grid:

Grid = (B, nh, nC) with the chunk axis innermost.  The inter-chunk state
(hd × N, f32) lives in VMEM scratch and is carried across chunk steps —
the TPU grid's sequential-minor-axis guarantee replaces the GPU's
cross-block semaphore chain.  Per chunk step, four MXU contractions:

  CB    = C_c · B_cᵀ            (L×N · N×L  → L×L)
  y_in  = (CB ∘ decay ∘ dt) · x (L×L · L×hd → L×hd)   intra-chunk
  y_st  = C_c · stateᵀ          (L×N · N×hd → L×hd)   inter-chunk read
  state = exp(total)·state + xᵀ·(w ∘ B_c)             state write

L (chunk) and hd are 128-multiples for MXU alignment; N = d_state = 128.
VMEM per cell: x/B/C tiles + (L,L) decay ≈ (3·L·128 + L²)·4 B ≈ 0.4 MiB at
L = 256 — small enough to double-buffer the streams.

B and C are shared across nh/G heads (Mamba-2 grouping); the BlockSpec index
map (h → h // rep) reads the shared tile without materializing the repeat.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref, dt_ref, dA_ref, b_ref, c_ref, y_ref, state_out_ref, state_ref,
    *, n_c: int, chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (L, hd)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (L,)
    dA = dA_ref[0, 0].astype(jnp.float32)        # (L,)
    Bc = b_ref[0, 0].astype(jnp.float32)         # (L, N)
    Cc = c_ref[0, 0].astype(jnp.float32)         # (L, N)
    L = chunk

    cum = jnp.cumsum(dA)                         # (L,)
    # decay[i, j] = exp(cum[i] - cum[j]) for j <= i else 0
    M = cum[:, None] - cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(cols <= rows, jnp.exp(M), 0.0)

    CB = jax.lax.dot_general(
        Cc, Bc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (L, L)
    scores = CB * decay * dt[None, :]
    y_intra = jax.lax.dot(scores, x, preferred_element_type=jnp.float32)

    state = state_ref[...]                       # (hd, N)
    y_inter = jax.lax.dot_general(
        Cc, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cum)[:, None]                    # (L, hd)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    total = cum[L - 1]
    w = jnp.exp(total - cum) * dt                # (L,)
    state_ref[...] = state * jnp.exp(total) + jax.lax.dot_general(
        x, Bc * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (hd, N)

    @pl.when(ci == n_c - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_ref[...]


def ssd_scan_kernel(x, dt, dA, Bm, Cm, *, chunk: int, interpret: bool = False):
    """x: (B, nh, S, hd); dt/dA: (B, nh, S); Bm/Cm: (B, G, S, N).
    Returns (y (B, nh, S, hd), final_state (B, nh, hd, N) f32)."""
    Bsz, nh, S, hd = x.shape
    G, N = Bm.shape[1], Bm.shape[3]
    rep = nh // G
    assert S % chunk == 0, (S, chunk)
    n_c = S // chunk

    grid = (Bsz, nh, n_c)
    kern = functools.partial(_ssd_kernel, n_c=n_c, chunk=chunk)
    y, state = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, ci: (b, h, ci)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, ci: (b, h, ci)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ci: (b, h // rep, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ci: (b, h // rep, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, nh, S, hd), x.dtype),
            jax.ShapeDtypeStruct((Bsz, nh, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, dA, Bm, Cm)
    return y, state
