from . import ops, ref
from .kernel import ssd_scan_kernel
from .ops import ssd

__all__ = ["ssd", "ssd_scan_kernel", "ops", "ref"]
