"""Pure-jnp oracles for the SSD (Mamba-2) scan kernel.

Two references:
* :func:`ssd_naive` — the O(S²) "duality" form: one big masked quadratic,
  mathematically the definition of the SSD operator.  Ground truth.
* the chunked pure-JAX implementation in ``repro.models.ssm.ssd_chunked`` —
  the lowering default, asserted against ssd_naive in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_naive(x, dt, A, Bm, Cm):
    """Quadratic-form SSD.

    x:  (B, S, nh, hd); dt: (B, S, nh); A: (nh,);
    Bm, Cm: (B, S, G, N).  Returns y: (B, S, nh, hd) (f32).

    y_i = sum_{j<=i} exp(sum_{t in (j, i]} dt_t A) * dt_j * (C_i·B_j) * x_j
    """
    Bsz, S, nh, hd = x.shape
    G = Bm.shape[2]
    rep = nh // G
    dA = dt * A[None, None, :]                       # (B,S,nh)
    cum = jnp.cumsum(dA, axis=1)
    # decay[b,h,i,j] = exp(cum[i] - cum[j]) for j<=i
    M = cum[:, :, None, :] - cum[:, None, :, :]      # (B,i,j,nh)
    M = jnp.moveaxis(M, -1, 1)                       # (B,nh,i,j)
    mask = jnp.tril(jnp.ones((S, S), dtype=bool))
    decay = jnp.where(mask[None, None], jnp.exp(M), 0.0)
    CB = jnp.einsum("bign,bjgn->bgij", Cm.astype(jnp.float32), Bm.astype(jnp.float32))
    CB = jnp.repeat(CB, rep, axis=1)                 # (B,nh,i,j)
    scores = CB * decay * jnp.moveaxis(dt, -1, 1)[:, :, None, :]
    y = jnp.einsum("bhij,bjhp->bihp", scores, x.astype(jnp.float32))
    return y
