"""jit'd public wrapper for the SSD scan (model layout adapters)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import default_interpret
from .kernel import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "return_state", "interpret"))
def ssd(
    x, dt, A, Bm, Cm, *, chunk: int, return_state: bool = False,
    interpret: Optional[bool] = None,
):
    """Model layout: x (B,S,nh,hd); dt (B,S,nh); A (nh,); Bm/Cm (B,S,G,N).
    Returns y (B,S,nh,hd) [, final_state (B,nh,hd,N)]."""
    interpret = default_interpret() if interpret is None else interpret
    xt = jnp.moveaxis(x, 2, 1)                       # (B,nh,S,hd)
    dtt = jnp.moveaxis(dt, 2, 1).astype(jnp.float32)  # (B,nh,S)
    dAt = dtt * A[None, :, None]
    Bt = jnp.moveaxis(Bm, 2, 1)                      # (B,G,S,N)
    Ct = jnp.moveaxis(Cm, 2, 1)
    y, state = ssd_scan_kernel(xt, dtt, dAt, Bt, Ct, chunk=chunk, interpret=interpret)
    y = jnp.moveaxis(y, 1, 2)
    if return_state:
        return y, state
    return y
