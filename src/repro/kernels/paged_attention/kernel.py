"""Paged decode-attention Pallas TPU kernel (flash-decoding over a page pool).

One new token per slot attends to K/V scattered across a shared pool of
fixed-size pages, ``(n_pages + 1, page_size, Hkv, dh)`` with a trash page at
index ``n_pages``.  The XLA path materializes a gathered
``(B, max_pages*page_size, Hkv, dh)`` view of the pool before attending —
the same bytes twice (pool -> gather copy -> attention read).  This kernel
walks the slot's **page table inside the kernel** instead:

* the page table (and ``cur_pos``) ride in as *scalar-prefetch* operands
  (``pltpu.PrefetchScalarGridSpec``), so the K/V BlockSpec index maps can
  pick the physical page ``table[b, j]`` for grid step ``(b, h, j)`` — the
  gather becomes the DMA schedule, not a materialized array.  Pallas's
  pipeline double-buffers these page loads across the innermost grid axis
  (page ``j+1`` streams into VMEM while page ``j`` is being reduced);
* unmapped logical pages are redirected to the trash page for the *load*
  (never out of bounds) and masked out of the softmax for the *math*;
* validity is fused into the online softmax exactly like
  ``kernels/decode_attention``: paged placement is position-indexed
  (logical page j, offset o IS absolute position ``j*page_size + o``), so a
  key is attendable iff its page is mapped and ``pos <= cur_pos`` — no
  per-token ``pos`` array needed.

Grid = (B, Hkv, max_pages): each cell owns one (slot, kv-head) pair; the
logical-page axis is innermost and carries the (m, l, acc) online-softmax
scratch across steps.  All ``group`` q-heads sharing a kv head ride in one
cell and reuse the streamed page ``group`` times (the GQA
arithmetic-intensity win, as in the dense decode kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import NEG_INF


def _paged_dec_kernel(
    gather_ref, cur_ref,                      # scalar prefetch (SMEM)
    q_ref, k_ref, v_ref, o_ref,               # blocks (VMEM)
    m_ref, l_ref, acc_ref,                     # scratch (VMEM)
    *, page_size: int, n_pages: int, max_pages: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                # (group, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (ps, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(q.shape[-1]))             # (group, ps)

    # validity fused into the running max/denominator: page mapped
    # (gather == n_pages means the trash redirect) AND absolute position
    # (== flat index, by paged placement) not beyond the current token
    pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (q.shape[0], page_size), 1)
    mapped = gather_ref[b, j] < n_pages
    valid = jnp.logical_and(mapped, pos <= cur_ref[b])
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                # (group, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    scale = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * scale + p.sum(axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * scale + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )

    @pl.when(j == max_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention_kernel(
    q, k_pool, v_pool, gather, cur_pos, *, interpret: bool = False,
):
    """q: (B, Hkv, group, dh); k_pool/v_pool: (n_pages + 1, ps, Hkv, dh);
    gather: (B, max_pages) int32 physical page per logical page, already
    sanitized (unmapped -> n_pages, the trash page); cur_pos: (B,) int32.
    Returns (B, Hkv, group, dh)."""
    B, Hkv, group, dh = q.shape
    n_pages = k_pool.shape[0] - 1
    page_size = k_pool.shape[1]
    max_pages = gather.shape[1]

    grid = (B, Hkv, max_pages)
    kern = functools.partial(
        _paged_dec_kernel, page_size=page_size, n_pages=n_pages,
        max_pages=max_pages,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, dh),
                         lambda b, h, j, g_ref, c_ref: (b, h, 0, 0)),
            # the page walk: physical page id from the prefetched table
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, h, j, g_ref, c_ref: (g_ref[b, j], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, h, j, g_ref, c_ref: (g_ref[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh),
                               lambda b, h, j, g_ref, c_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),       # m
            pltpu.VMEM((group, 1), jnp.float32),       # l
            pltpu.VMEM((group, dh), jnp.float32),      # acc
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, dh), q.dtype),
        interpret=interpret,
    )(gather, cur_pos, q, k_pool, v_pool)


def _paged_verify_kernel(
    gather_ref, cur_ref,                      # scalar prefetch (SMEM)
    q_ref, k_ref, v_ref, o_ref,               # blocks (VMEM)
    m_ref, l_ref, acc_ref,                     # scratch (VMEM)
    *, page_size: int, n_pages: int, max_pages: int, group: int,
):
    """Multi-query (draft-verify) twin of :func:`_paged_dec_kernel`.

    The q block carries all ``W * group`` query rows of one (slot, kv-head)
    cell — window position ``w = row // group``, q-head ``row % group`` —
    so one streamed page is reused ``W * group`` times.  The only change
    from the single-query kernel is that validity is **per query row**:
    query ``w`` sits at absolute position ``cur_pos[b] + w`` and may attend
    keys at positions ``<= cur_pos[b] + w`` — which includes the window's
    own K/V written by the caller before the kernel runs (within-window
    causality falls out of the same position check, no extra mask).
    """
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                # (W*group, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (ps, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(q.shape[-1]))             # (W*group, ps)

    rows = q.shape[0]
    pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (rows, page_size), 1)
    qpos = cur_ref[b] + jax.lax.broadcasted_iota(
        jnp.int32, (rows, page_size), 0) // group
    mapped = gather_ref[b, j] < n_pages
    valid = jnp.logical_and(mapped, pos <= qpos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                # (W*group, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    scale = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * scale + p.sum(axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * scale + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )

    @pl.when(j == max_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_verify_attention_kernel(
    q, k_pool, v_pool, gather, cur_pos, *, group: int,
    interpret: bool = False,
):
    """q: (B, Hkv, W*group, dh) — window-major query rows per kv head;
    k_pool/v_pool, gather, cur_pos as in
    :func:`paged_decode_attention_kernel`.  Returns (B, Hkv, W*group, dh)."""
    B, Hkv, wg, dh = q.shape
    n_pages = k_pool.shape[0] - 1
    page_size = k_pool.shape[1]
    max_pages = gather.shape[1]

    grid = (B, Hkv, max_pages)
    kern = functools.partial(
        _paged_verify_kernel, page_size=page_size, n_pages=n_pages,
        max_pages=max_pages, group=group,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, wg, dh),
                         lambda b, h, j, g_ref, c_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, h, j, g_ref, c_ref: (g_ref[b, j], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, h, j, g_ref, c_ref: (g_ref[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, wg, dh),
                               lambda b, h, j, g_ref, c_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((wg, 1), jnp.float32),          # m
            pltpu.VMEM((wg, 1), jnp.float32),          # l
            pltpu.VMEM((wg, dh), jnp.float32),         # acc
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, wg, dh), q.dtype),
        interpret=interpret,
    )(gather, cur_pos, q, k_pool, v_pool)
