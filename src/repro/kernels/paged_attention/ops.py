"""jit'd public wrapper for paged decode attention (model layout adapter).

``paged_decode_attention`` is what
``models.attention.paged_decode_attention(impl="pallas")`` calls: the raw
page table (-1 = unmapped) is sanitized to trash-page redirects on the way
in — the only per-call host-side work; the (B, max_pages*page_size) gather
of the XLA path is never materialized.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import default_interpret
from .kernel import (
    paged_decode_attention_kernel,
    paged_verify_attention_kernel,
)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(
    q, k_pool, v_pool, page_table, cur_pos, *,
    interpret: Optional[bool] = None,
):
    """q: (B, H, dh); k_pool/v_pool: (n_pages + 1, page_size, Hkv, dh) with
    the trash page at index ``n_pages``; page_table: (B, max_pages) int32,
    -1 = unmapped; cur_pos: (B,) int32.  Returns (B, H, dh)."""
    interpret = default_interpret() if interpret is None else interpret
    B, H, dh = q.shape
    Hkv = k_pool.shape[2]
    group = H // Hkv
    n_pages = k_pool.shape[0] - 1
    gather = jnp.where(page_table >= 0, page_table, n_pages).astype(jnp.int32)
    out = paged_decode_attention_kernel(
        q.reshape(B, Hkv, group, dh), k_pool, v_pool, gather,
        cur_pos.astype(jnp.int32), interpret=interpret,
    )
    return out.reshape(B, H, dh)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_verify_attention(
    q, k_pool, v_pool, page_table, cur_pos, *,
    interpret: Optional[bool] = None,
):
    """Multi-query verify leg (draft-and-verify window).  q: (B, W, H, dh)
    — W query tokens per slot at absolute positions ``cur_pos + [0, W)``,
    K/V (including the window's own) already written into the pool by the
    caller; pools/page_table/cur_pos as in :func:`paged_decode_attention`.
    Returns (B, W, H, dh)."""
    interpret = default_interpret() if interpret is None else interpret
    B, W, H, dh = q.shape
    Hkv = k_pool.shape[2]
    group = H // Hkv
    n_pages = k_pool.shape[0] - 1
    gather = jnp.where(page_table >= 0, page_table, n_pages).astype(jnp.int32)
    # window-major rows per kv head: row = w * group + q-head-in-group, so
    # the kernel recovers the query position as cur_pos + row // group
    qr = q.reshape(B, W, Hkv, group, dh).transpose(0, 2, 1, 3, 4)
    out = paged_verify_attention_kernel(
        qr.reshape(B, Hkv, W * group, dh), k_pool, v_pool, gather,
        cur_pos.astype(jnp.int32), group=group, interpret=interpret,
    )
    out = out.reshape(B, Hkv, W, group, dh).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, W, H, dh)
