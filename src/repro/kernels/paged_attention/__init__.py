"""Paged decode attention: flash-decoding against a shared KV page pool."""
