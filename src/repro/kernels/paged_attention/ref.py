"""Pure-jnp oracle: the materialized-gather paged attention of the XLA path
(``models.attention._paged_attn_xla`` semantics, pool layout in/out)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import NEG_INF


def paged_decode_attention_ref(q, k_pool, v_pool, page_table, cur_pos):
    """Same signature as ``ops.paged_decode_attention``: gather the slot's
    pages into a flat (B, max_pages*ps, Hkv, dh) view, mask to mapped pages
    and positions <= cur_pos, masked softmax."""
    B, H, dh = q.shape
    n_pages = k_pool.shape[0] - 1
    ps = k_pool.shape[1]
    Hkv = k_pool.shape[2]
    group = H // Hkv
    maxp = page_table.shape[1]
    L = maxp * ps

    gather = jnp.where(page_table >= 0, page_table, n_pages)
    kg = k_pool[gather].reshape(B, L, Hkv, dh)
    vg = v_pool[gather].reshape(B, L, Hkv, dh)
    pos = jnp.arange(L, dtype=jnp.int32)
    valid = (page_table >= 0)[:, pos // ps] & (pos[None, :] <= cur_pos[:, None])

    qg = (q.reshape(B, Hkv, group, dh) / jnp.sqrt(jnp.float32(dh))).astype(q.dtype)
    s = jnp.einsum("bgid,bkgd->bgik", qg, kg,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgik,bkgd->bgid", w.astype(vg.dtype), vg,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, dh).astype(q.dtype)


def paged_verify_attention_ref(q, k_pool, v_pool, page_table, cur_pos):
    """Multi-query twin of :func:`paged_decode_attention_ref`: query ``w``
    sits at absolute position ``cur_pos + w`` and attends mapped keys at
    positions ``<= cur_pos + w``.  Same signature/layout as
    ``ops.paged_verify_attention``."""
    B, W, H, dh = q.shape
    n_pages = k_pool.shape[0] - 1
    ps = k_pool.shape[1]
    Hkv = k_pool.shape[2]
    group = H // Hkv
    maxp = page_table.shape[1]
    L = maxp * ps

    gather = jnp.where(page_table >= 0, page_table, n_pages)
    kg = k_pool[gather].reshape(B, L, Hkv, dh)
    vg = v_pool[gather].reshape(B, L, Hkv, dh)
    pos = jnp.arange(L, dtype=jnp.int32)
    q_pos = cur_pos[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]
    valid = ((page_table >= 0)[:, pos // ps][:, None, :]
             & (pos[None, None, :] <= q_pos[:, :, None]))          # (B, W, L)

    qg = (q.reshape(B, W, Hkv, group, dh)
          / jnp.sqrt(jnp.float32(dh))).astype(q.dtype)
    s = jnp.einsum("bwgid,bkgd->bwgik", qg, kg,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bwgik,bkgd->bwgid", w.astype(vg.dtype), vg,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, W, H, dh).astype(q.dtype)
