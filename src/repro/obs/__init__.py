"""repro.obs — the unified telemetry plane.

Three dependency-free pillars shared by every layer of the stack:

* :class:`MetricsRegistry` — typed counters/gauges/log-bucketed histograms
  with per-tenant labels; ``BatcherStats`` fields and the executor's SLO
  counters are thin views over it.
* :class:`Tracer` — structured spans + instants on an injectable clock,
  exported as Chrome-trace/Perfetto JSON (``NULL_TRACER`` = disabled,
  zero-cost).
* :class:`Telemetry` — the bundle a layer accepts as one ``telemetry=``
  kwarg instead of three.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .metrics import Counter, Gauge, Histogram, MetricsRegistry, percentile
from .trace import NULL_TRACER, Tracer


@dataclass
class Telemetry:
    """One handle threading metrics + tracing through a component.

    ``tenant`` labels every instrument the component records (per-tenant
    tracks in the trace, per-tenant labels in the registry); ``None``
    means unlabeled/shared.
    """

    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    tracer: Tracer = NULL_TRACER
    tenant: Optional[str] = None

    @property
    def track(self) -> str:
        return self.tenant if self.tenant is not None else "main"


__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "Telemetry",
    "Tracer",
    "percentile",
]
