"""Typed metrics: counters, gauges, and log-bucketed histograms.

One registry per process (or per test) unifies what used to live in
ad-hoc dicts scattered across the stack — ``BatcherStats`` fields,
``ServingExecutor``'s SLO counters, the hypervisor's request latencies —
under stable dotted names with an optional per-tenant label:

    reg = MetricsRegistry()
    reg.counter("serving.chunks", tenant="gold").inc()
    reg.histogram("slo.latency_s", tenant="gold").record(0.012)
    reg.histogram("slo.latency_s", tenant="gold").quantile(0.99)

Everything here is dependency-free and O(1) per record:

* :class:`Counter` / :class:`Gauge` are a single mutable ``value`` slot —
  cheap enough that ``BatcherStats`` fields can be thin *views* over them
  (the legacy field stays, the registry owns the number).
* :class:`Histogram` is log-bucketed: ``record`` is one ``log`` + one dict
  increment; quantiles come back with bounded relative error (the bucket
  growth factor, ~8% at the default base) — exact enough for p50/p95/p99
  SLO reporting without keeping every sample.
* :func:`percentile` is the *exact* sorted-list quantile the benches use
  on small sample sets (the one shared implementation — bench-local
  copies were deduplicated onto it).
"""

from __future__ import annotations

import json
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


def percentile(values: Sequence[float], q: float) -> float:
    """Exact empirical quantile of ``values`` (nearest-rank, the semantics
    the benches have always used): ``nan`` on an empty sample, else the
    element at floor(q * n) clamped into range."""
    if not values:
        return float("nan")
    vals = sorted(values)
    idx = min(int(q * len(vals)), len(vals) - 1)
    return vals[idx]


class Counter:
    """Monotonic (by convention) integer counter.  ``value`` is plain
    mutable state so field-view wrappers can both read and assign it."""

    __slots__ = ("value",)

    def __init__(self, value: int = 0) -> None:
        self.value = value

    def inc(self, n: int = 1) -> None:
        self.value += n

    def __repr__(self) -> str:
        return f"Counter({self.value})"


class Gauge:
    """Last-write-wins scalar (pages in use, queue depth, ...)."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0) -> None:
        self.value = value

    def set(self, v: float) -> None:
        self.value = v

    def __repr__(self) -> str:
        return f"Gauge({self.value})"


class Histogram:
    """Log-bucketed histogram with O(1) record and bounded-error quantiles.

    Positive samples land in geometric buckets ``base**i <= v < base**(i+1)``
    (a dict of int -> count, so the bucket range is unbounded); zero and
    negative samples share a dedicated bucket.  ``quantile`` walks the
    cumulative counts and returns the geometric midpoint of the rank's
    bucket, clamped to the observed min/max — relative error is bounded by
    the bucket width (~8% at the default base), which is exact enough for
    percentile SLO attainment without retaining samples.
    """

    __slots__ = ("_base", "_log_base", "_buckets", "_zero", "count",
                 "total", "min", "max")

    def __init__(self, base: float = 1.08) -> None:
        assert base > 1.0
        self._base = base
        self._log_base = math.log(base)
        self._buckets: Dict[int, int] = {}
        self._zero = 0                      # samples <= 0
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= 0.0:
            self._zero += 1
            return
        idx = int(math.floor(math.log(v) / self._log_base))
        self._buckets[idx] = self._buckets.get(idx, 0) + 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (0 <= q <= 1); ``nan`` when empty."""
        if self.count == 0:
            return float("nan")
        rank = min(int(q * self.count), self.count - 1)
        if rank < self._zero:
            return min(self.min, 0.0)
        seen = self._zero
        for idx in sorted(self._buckets):
            seen += self._buckets[idx]
            if rank < seen:
                mid = self._base ** (idx + 0.5)
                return max(self.min, min(self.max, mid))
        return self.max          # unreachable unless counts drifted

    def quantiles(self, qs: Iterable[float] = (0.5, 0.95, 0.99),
                  ) -> Dict[str, float]:
        """The standard SLO percentile bundle: ``{"p50": ..., "p99": ...}``."""
        return {f"p{round(q * 100) if q * 100 == int(q * 100) else q * 100:g}":
                self.quantile(q) for q in qs}

    def __repr__(self) -> str:
        return (f"Histogram(n={self.count}, mean={self.mean:.4g}, "
                f"p99={self.quantile(0.99):.4g})" if self.count
                else "Histogram(n=0)")


class MetricsRegistry:
    """Process-local registry of named, per-tenant-labeled instruments.

    ``counter/gauge/histogram`` get-or-create, so call sites never need a
    registration phase; the key is ``(name, tenant)`` with ``tenant=None``
    meaning unlabeled.  ``snapshot`` returns a JSON-able dict for artifact
    upload and the bench gates.
    """

    def __init__(self) -> None:
        self._counters: Dict[Tuple[str, Optional[str]], Counter] = {}
        self._gauges: Dict[Tuple[str, Optional[str]], Gauge] = {}
        self._histograms: Dict[Tuple[str, Optional[str]], Histogram] = {}

    # -- get-or-create ---------------------------------------------------
    def counter(self, name: str, tenant: Optional[str] = None) -> Counter:
        key = (name, tenant)
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, tenant: Optional[str] = None) -> Gauge:
        key = (name, tenant)
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, tenant: Optional[str] = None,
                  *, base: float = 1.08) -> Histogram:
        key = (name, tenant)
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram(base=base)
        return h

    # -- queries ---------------------------------------------------------
    def labels(self, name: str) -> List[Optional[str]]:
        """Every tenant label recorded under ``name`` (any instrument)."""
        out = []
        for table in (self._counters, self._gauges, self._histograms):
            for (n, tenant) in table:
                if n == name and tenant not in out:
                    out.append(tenant)
        return out

    def snapshot(self) -> Dict[str, Dict]:
        """JSON-able dump: counters/gauges by ``name{tenant}``, histograms
        as count/mean/min/max plus the p50/p95/p99 bundle."""

        def label(key: Tuple[str, Optional[str]]) -> str:
            name, tenant = key
            return name if tenant is None else f"{name}{{{tenant}}}"

        out: Dict[str, Dict] = {
            "counters": {label(k): c.value
                         for k, c in sorted(self._counters.items(),
                                            key=lambda kv: label(kv[0]))},
            "gauges": {label(k): g.value
                       for k, g in sorted(self._gauges.items(),
                                          key=lambda kv: label(kv[0]))},
            "histograms": {},
        }
        for k, h in sorted(self._histograms.items(),
                           key=lambda kv: label(kv[0])):
            out["histograms"][label(k)] = {
                "count": h.count,
                "mean": h.mean if h.count else None,
                "min": h.min if h.count else None,
                "max": h.max if h.count else None,
                **({q: v for q, v in h.quantiles().items()} if h.count
                   else {"p50": None, "p95": None, "p99": None}),
            }
        return out

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2)
        return path
