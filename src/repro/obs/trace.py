"""Structured tracing: spans + instants, exported as Chrome-trace JSON.

A :class:`Tracer` collects *complete spans* (name, track, start, duration)
and *instant events* from the hypervisor event loop, the serving executor,
and the batcher round loop, then exports them in the Chrome trace-event
format that both ``chrome://tracing`` and https://ui.perfetto.dev open
directly.  Tracks (one per tenant, plus ``hypervisor``/``batcher``/...)
become named rows in the timeline.

Design constraints, in order:

* **Zero-cost when disabled.** Every record method checks ``enabled``
  before touching the clock; ``span(...)`` returns a shared no-op context
  manager.  ``NULL_TRACER`` is the canonical disabled instance — layers
  default to it so instrumented code never branches on ``tracer is None``.
* **Injectable clock.** The tracer never calls ``time`` directly unless
  you let it; pass the same ``clock=`` the batcher/executor use and the
  sim's ``at=`` stamps, the batcher's wall-clock, and the tracer's spans
  share one timeline.  Events store raw clock *seconds*; export
  normalizes to the earliest timestamp and converts to microseconds, so
  sim-time (small floats near 0) and ``time.monotonic`` (large floats)
  both render sensibly — just don't mix the two in one tracer.
* **Bounded memory.** ``max_events`` caps retention; once full, new
  events are counted in ``dropped`` but not stored, so a runaway run
  can't eat the host (and committed sample traces stay small).
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional


class _NullSpan:
    """Shared no-op context manager for disabled tracers."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: object) -> None:
        return None


_NULL_SPAN = _NullSpan()


class _Span:
    """Live span context manager: stamps the clock on enter/exit."""

    __slots__ = ("_tracer", "name", "track", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, track: str,
                 args: Optional[Dict[str, Any]]) -> None:
        self._tracer = tracer
        self.name = name
        self.track = track
        self.args = args
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc: object) -> None:
        t1 = self._tracer._clock()
        self._tracer.complete(self.name, self.track, self._t0,
                              t1 - self._t0, self.args)


class Tracer:
    """Collects spans/instants on an injectable clock; exports Chrome JSON."""

    def __init__(self, *, clock=None, enabled: bool = True,
                 max_events: int = 100_000) -> None:
        self.enabled = enabled
        self._clock = clock if clock is not None else time.monotonic
        self.max_events = max_events
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0

    # -- recording -------------------------------------------------------
    def _push(self, ev: Dict[str, Any]) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def instant(self, name: str, track: str = "main", *,
                ts: Optional[float] = None,
                args: Optional[Dict[str, Any]] = None) -> None:
        """Point-in-time event.  ``ts`` overrides the clock (sim time)."""
        if not self.enabled:
            return
        self._push({"ph": "i", "name": name, "track": track,
                    "ts": self._clock() if ts is None else ts,
                    "args": args})

    def complete(self, name: str, track: str, ts: float, dur: float,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """Explicit span from pre-measured stamps (e.g. sim-time ranges)."""
        if not self.enabled:
            return
        self._push({"ph": "X", "name": name, "track": track,
                    "ts": ts, "dur": max(dur, 0.0), "args": args})

    def span(self, name: str, track: str = "main", *,
             args: Optional[Dict[str, Any]] = None):
        """Context manager measuring the enclosed block on the clock."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, track, args)

    # -- export ----------------------------------------------------------
    def tracks(self) -> List[str]:
        out: List[str] = []
        for ev in self.events:
            if ev["track"] not in out:
                out.append(ev["track"])
        return out

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (object form).  Timestamps are shifted
        so the earliest event is t=0 and scaled seconds -> microseconds;
        each track becomes a named tid with a ``thread_name`` metadata
        record so Perfetto labels the rows."""
        t0 = min((ev["ts"] for ev in self.events), default=0.0)
        tids = {track: i for i, track in enumerate(self.tracks())}
        out: List[Dict[str, Any]] = []
        for track, tid in tids.items():
            out.append({"ph": "M", "name": "thread_name", "pid": 1,
                        "tid": tid, "args": {"name": track}})
        for ev in self.events:
            rec: Dict[str, Any] = {
                "ph": ev["ph"], "name": ev["name"], "pid": 1,
                "tid": tids[ev["track"]],
                "ts": (ev["ts"] - t0) * 1e6,
            }
            if ev["ph"] == "X":
                rec["dur"] = ev["dur"] * 1e6
            if ev["ph"] == "i":
                rec["s"] = "t"          # instant scope: thread
            if ev.get("args"):
                rec["args"] = ev["args"]
            out.append(rec)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


NULL_TRACER = Tracer(enabled=False, clock=lambda: 0.0, max_events=0)
