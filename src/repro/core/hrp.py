"""Multi-core Hardware Resource Pool (HRP) — paper §4.2.2.

The HRP divides the single large accelerator into ``n_cores`` basic shareable
units and leases disjoint subsets to tenants.  Isolation invariants enforced
here (they are *the* public-cloud requirement of the paper):

* **Physical isolation** — leases never overlap; a tenant can only ever touch
  its own cores.  On the TPU adaptation a lease maps to a disjoint sub-mesh.
* **Bandwidth isolation** — every core owns a fixed off-chip port
  (128-bit DDR slice in the paper; a chip's own HBM on TPU); the pool checks
  that the per-DDR-group port-bit budget is never oversubscribed
  (``sum(core ports) <= 512 bit`` per DDR bank, §4.2.2).
* **KV-page quota** — a second, memory-side lease dimension: the pool can
  own ``n_kv_pages`` cache pages (the serving layer's paged-KV pool,
  ``repro.serving.kv_cache``), leased per tenant as a *count* (pages are
  fungible — placement is device state).  Like the DDR port budget, the sum
  of kv leases must never exceed the pool, and only tenants holding a core
  lease may hold pages (memory without compute is a leak).
* **Failure isolation** — each core belongs to a *fault domain* (its DDR
  group: shared bank, shared blast radius).  A failed core
  (``mark_failed``) is unplaceable — excluded from ``free_cores`` and every
  placement path — until ``mark_recovered``.  ``check_health`` asserts no
  live lease contains a failed core; the hypervisor displaces the owning
  tenant *in the same event* that delivers the failure, so the invariant
  holds at every event boundary.

The pool is pure bookkeeping — deliberately no JAX here; the serving glue
(`repro.serving.tenancy`) turns leases into `jax.sharding.Mesh` slices.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence


class HRPError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class Lease:
    tenant: str
    cores: tuple  # tuple[int, ...]

    @property
    def n_cores(self) -> int:
        return len(self.cores)


class ResourcePool:
    """Disjoint-lease manager over ``n_cores`` basic shareable units."""

    def __init__(
        self,
        n_cores: int = 16,
        *,
        cores_per_ddr: int = 4,
        ddr_port_bits: int = 512,
        core_port_bits: int = 128,
        n_kv_pages: int = 0,
    ) -> None:
        if cores_per_ddr * core_port_bits > ddr_port_bits:
            raise HRPError(
                "port budget violated at construction: "
                f"{cores_per_ddr} cores x {core_port_bits}b > {ddr_port_bits}b/DDR"
            )
        self.n_cores = n_cores
        self.cores_per_ddr = cores_per_ddr
        self.ddr_port_bits = ddr_port_bits
        self.core_port_bits = core_port_bits
        self.n_kv_pages = n_kv_pages
        self._leases: Dict[str, Lease] = {}
        self._owner: List[Optional[str]] = [None] * n_cores
        self._kv_leases: Dict[str, int] = {}
        self._shared_kv: Dict[str, int] = {}
        self._failed: set = set()   # core indices marked unplaceable

    # -- queries ------------------------------------------------------------
    @property
    def leases(self) -> Dict[str, Lease]:
        return dict(self._leases)

    @property
    def kv_leases(self) -> Dict[str, int]:
        return dict(self._kv_leases)

    def free_cores(self) -> List[int]:
        """Unleased AND healthy: failed cores are never placeable."""
        return [i for i, o in enumerate(self._owner)
                if o is None and i not in self._failed]

    def owner_of(self, core: int) -> Optional[str]:
        return self._owner[core]

    @property
    def n_healthy(self) -> int:
        """Cores the pool can actually place (total minus failed)."""
        return self.n_cores - len(self._failed)

    def failed_cores(self) -> List[int]:
        return sorted(self._failed)

    def fault_domain(self, core: int) -> int:
        """The core's fault domain id — its DDR group (shared bank, shared
        blast radius)."""
        return core // self.cores_per_ddr

    def domain_cores(self, domain: int) -> List[int]:
        lo = domain * self.cores_per_ddr
        return list(range(lo, min(lo + self.cores_per_ddr, self.n_cores)))

    def free_kv_pages(self) -> int:
        return self.n_kv_pages - sum(self._kv_leases.values())

    def lease_of(self, tenant: str) -> Optional[Lease]:
        return self._leases.get(tenant)

    def kv_lease_of(self, tenant: str) -> int:
        return self._kv_leases.get(tenant, 0)

    @property
    def shared_kv(self) -> Dict[str, int]:
        return dict(self._shared_kv)

    def shared_kv_of(self, tenant: str) -> int:
        return self._shared_kv.get(tenant, 0)

    def note_shared_kv(self, tenant: str, pages: int) -> None:
        """Record how many of ``tenant``'s leased kv pages currently back its
        **shared prefix cache** (billed once to the tenant's namespace,
        reused by every request that hits).  Pure bookkeeping fed by the
        serving layer; policies read it from ``PolicyContext.shared_kv_pages``
        so a rebalance knows a tenant's lease cannot usefully drop below its
        pinned shared set without an eviction pass first (the batcher's
        ``set_page_limit`` evicts cache entries before live requests fault).
        ``0`` clears the entry."""
        if pages < 0:
            raise HRPError(f"negative shared kv for {tenant}: {pages}")
        if pages and tenant not in self._leases:
            raise HRPError(
                f"tenant {tenant} holds no core lease for shared kv pages")
        if pages > self.n_kv_pages:
            # fail at the write site, not at some later unrelated event's
            # invariant sweep: a pool with no kv budget can't bill pages
            raise HRPError(
                f"shared kv for {tenant} exceeds the pool: {pages} > "
                f"{self.n_kv_pages}")
        if pages:
            self._shared_kv[tenant] = int(pages)
        else:
            self._shared_kv.pop(tenant, None)

    # -- kv-page leases (memory dimension; counts, not placements) -----------
    def set_kv_lease(self, tenant: str, pages: int) -> None:
        """Set ``tenant``'s kv-page lease to ``pages`` (0 releases it).  The
        tenant must hold a core lease, and the pool total must fit — the
        §4.2.2-style budget rule applied to cache memory."""
        if pages < 0:
            raise HRPError(f"negative kv lease for {tenant}: {pages}")
        if pages and tenant not in self._leases:
            raise HRPError(f"tenant {tenant} holds no core lease for kv pages")
        others = sum(p for t, p in self._kv_leases.items() if t != tenant)
        if others + pages > self.n_kv_pages:
            raise HRPError(
                f"kv pool oversubscribed: {others} held + {pages} for "
                f"{tenant} > {self.n_kv_pages}"
            )
        if pages:
            self._kv_leases[tenant] = pages
        else:
            self._kv_leases.pop(tenant, None)

    # -- invariants ----------------------------------------------------------
    def check_isolation(self) -> None:
        """Leases must be pairwise disjoint and owner table consistent."""
        seen: Dict[int, str] = {}
        for t, lease in self._leases.items():
            for c in lease.cores:
                if c in seen:
                    raise HRPError(f"core {c} leased to both {seen[c]} and {t}")
                if self._owner[c] != t:
                    raise HRPError(f"owner table drift at core {c}")
                seen[c] = t
        for c, o in enumerate(self._owner):
            if o is not None and c not in seen:
                raise HRPError(f"owner table claims {c} -> {o} without a lease")

    def check_bandwidth(self) -> None:
        """Per-DDR-group port-bit budget (§4.2.2 hardware restriction)."""
        n_groups = (self.n_cores + self.cores_per_ddr - 1) // self.cores_per_ddr
        for g in range(n_groups):
            lo, hi = g * self.cores_per_ddr, min((g + 1) * self.cores_per_ddr, self.n_cores)
            bits = sum(
                self.core_port_bits for c in range(lo, hi) if self._owner[c] is not None
            )
            if bits > self.ddr_port_bits:
                raise HRPError(f"DDR group {g} oversubscribed: {bits}b")

    def check_kv_quota(self) -> None:
        """KV-page leases must fit the pool, be non-negative, and only be
        held by tenants that also hold cores (the memory-dimension analogue
        of the per-DDR-group port budget).  Shared (prefix-cache) pages are
        part of the owning tenant's lease, billed once: they must belong to
        a leased tenant and fit the pool in total.  A tenant's shared set
        *may* transiently exceed a freshly-shrunk lease — that is exactly
        the drain window in which the serving layer must evict cache
        entries before live requests fault (``set_page_limit``) — so the
        check bounds shared pages by the pool, not the per-tenant lease."""
        total = 0
        for t, p in self._kv_leases.items():
            if p < 0:
                raise HRPError(f"negative kv lease: {t} -> {p}")
            if t not in self._leases:
                raise HRPError(f"kv lease without a core lease: {t}")
            total += p
        if total > self.n_kv_pages:
            raise HRPError(
                f"kv pool oversubscribed: {total} > {self.n_kv_pages}")
        shared_total = 0
        for t, p in self._shared_kv.items():
            if p < 0:
                raise HRPError(f"negative shared kv: {t} -> {p}")
            if t not in self._leases:
                raise HRPError(f"shared kv without a core lease: {t}")
            shared_total += p
        if shared_total > self.n_kv_pages:
            raise HRPError(
                f"shared kv exceeds the pool: {shared_total} > "
                f"{self.n_kv_pages}")

    # -- failure isolation ----------------------------------------------------
    def mark_failed(self, core: int) -> Optional[str]:
        """Mark ``core`` unplaceable and return its current owner (the
        tenant the hypervisor must displace), or ``None`` if it was free.
        Idempotent; does NOT touch the lease — releasing/re-placing the
        owner is the hypervisor's job, in the same event."""
        if not 0 <= core < self.n_cores:
            raise HRPError(f"core {core} out of range [0, {self.n_cores})")
        self._failed.add(core)
        return self._owner[core]

    def mark_recovered(self, core: int) -> None:
        """Return a repaired core to the placeable set (idempotent)."""
        if not 0 <= core < self.n_cores:
            raise HRPError(f"core {core} out of range [0, {self.n_cores})")
        self._failed.discard(core)

    def check_health(self) -> None:
        """No live lease may contain a failed core — a tenant scheduled onto
        dead hardware is a silent outage.  The hypervisor displaces the
        owner inside the FAILURE event, so this holds at event boundaries."""
        for t, lease in self._leases.items():
            bad = sorted(set(lease.cores) & self._failed)
            if bad:
                raise HRPError(
                    f"tenant {t} leases failed core(s) {bad} "
                    f"(fault domain(s) {sorted({self.fault_domain(c) for c in bad})})")

    # -- placement ------------------------------------------------------------
    def _groups(self) -> List[range]:
        g = self.cores_per_ddr
        return [range(lo, min(lo + g, self.n_cores)) for lo in range(0, self.n_cores, g)]

    def _select_cores(self, n: int, *, tenant: Optional[str] = None) -> List[int]:
        """Pick ``n`` free cores, DDR-group-aware: whole free groups first
        (dedicated banks for the tenant), then groups the tenant already
        partially holds, then best-fit partial groups (fewest free cores —
        keeps remaining whole groups intact), and only then break a fresh
        group.  Caller has verified ``n`` cores are free."""
        groups = self._groups()
        free = {gi: [c for c in grp
                     if self._owner[c] is None and c not in self._failed]
                for gi, grp in enumerate(groups)}
        chosen: List[int] = []
        need = n

        def take(gi: int, k: int) -> None:
            nonlocal need
            grabbed, free[gi] = free[gi][:k], free[gi][k:]
            chosen.extend(grabbed)
            need -= len(grabbed)

        # 1) whole free DDR groups while a full group's worth is still needed
        for gi, grp in enumerate(groups):
            if need >= len(grp) and len(free[gi]) == len(grp):
                take(gi, len(grp))
            if need == 0:
                return chosen
        # 2) extend groups the tenant already partially holds
        if tenant is not None:
            for gi, grp in enumerate(groups):
                if free[gi] and any(self._owner[c] == tenant for c in grp):
                    take(gi, need)
                if need == 0:
                    return chosen
        # 3) best-fit partial groups: fewest free cores first
        partial = sorted(
            (gi for gi, grp in enumerate(groups) if 0 < len(free[gi]) < len(grp)),
            key=lambda gi: (len(free[gi]), gi),
        )
        for gi in partial:
            take(gi, need)
            if need == 0:
                return chosen
        # 4) break a whole free group (lowest index)
        for gi in range(len(groups)):
            if free[gi]:
                take(gi, need)
            if need == 0:
                return chosen
        raise HRPError(f"internal: could not place {n} cores")  # pragma: no cover

    def _shrink_keep(self, cur: Sequence[int], n: int) -> List[int]:
        """Choose which ``n`` of ``cur`` to retain on a shrink: drop cores
        from the groups where the tenant holds the fewest first (consolidates
        the lease onto whole dedicated banks), highest index first within a
        group."""
        g = self.cores_per_ddr
        held: Dict[int, int] = {}
        for c in cur:
            held[c // g] = held.get(c // g, 0) + 1
        drop_order = sorted(cur, key=lambda c: (held[c // g], -c))
        dropped = set(drop_order[: len(cur) - n])
        return sorted(c for c in cur if c not in dropped)

    # -- lifecycle ------------------------------------------------------------
    def alloc(self, tenant: str, n: int) -> Lease:
        if tenant in self._leases:
            raise HRPError(f"tenant {tenant} already holds a lease; use resize()")
        free = self.free_cores()
        if n > len(free):
            raise HRPError(f"want {n} cores, only {len(free)} free")
        # prefer whole DDR groups: keeps tenants' traffic on dedicated banks
        cores = tuple(sorted(self._select_cores(n, tenant=tenant)))
        for c in cores:
            self._owner[c] = tenant
        lease = Lease(tenant, cores)
        self._leases[tenant] = lease
        self.check_isolation()
        self.check_bandwidth()
        return lease

    def release(self, tenant: str) -> None:
        lease = self._leases.pop(tenant, None)
        if lease is None:
            raise HRPError(f"tenant {tenant} holds no lease")
        for c in lease.cores:
            self._owner[c] = None
        self._kv_leases.pop(tenant, None)
        self._shared_kv.pop(tenant, None)

    def resize(self, tenant: str, n: int) -> Lease:
        """Grow/shrink a lease in place — the private-cloud reconfiguration
        primitive.  Retains as many of the tenant's current cores as possible
        (minimizes instruction/context migration)."""
        lease = self._leases.get(tenant)
        if lease is None:
            return self.alloc(tenant, n)
        cur = list(lease.cores)
        if n < len(cur):
            keep = self._shrink_keep(cur, n)
            for c in set(cur) - set(keep):
                self._owner[c] = None
            new = Lease(tenant, tuple(keep))
        elif n > len(cur):
            free = self.free_cores()
            need = n - len(cur)
            if need > len(free):
                raise HRPError(f"resize wants {need} extra cores, only {len(free)} free")
            extra = self._select_cores(need, tenant=tenant)
            for c in extra:
                self._owner[c] = tenant
            new = Lease(tenant, tuple(sorted(cur + extra)))
        else:
            new = lease
        self._leases[tenant] = new
        self.check_isolation()
        self.check_bandwidth()
        return new
