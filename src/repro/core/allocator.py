"""Workload-balanced IFP allocator — paper §5.2.2 (Eqs. 4-6).

Given the latencies ``T(i)`` of a layer's N IFPs and K allocated cores, find
``Alloc(i, k)`` minimizing the makespan ``max_k sum_i Alloc(i,k) T(i)`` with
every IFP assigned to exactly one core.

Two solvers, both on the ~1 ms dynamic-compilation path:

* :func:`allocate_contiguous_dp` — exact DP over *contiguous* chunks (the
  classic linear-partition problem, O(N^2 K)).  Contiguity is what the
  hardware wants anyway: each core receives one concatenated instruction
  sequence, and contiguous same-layer tiles enable the on-chip reuse dedupe.
* :func:`allocate_lpt` — longest-processing-time greedy (non-contiguous),
  a 4/3-approximation, used as a cross-check and for very large N.

``allocate`` runs the DP and returns per-core index lists.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple


def allocate_contiguous_dp(
    times: Sequence[float], k: int, *, run_overhead: float = 0.0
) -> Tuple[List[List[int]], float]:
    """Exact minimal-makespan partition of ``times`` into <= k contiguous runs.

    ``run_overhead`` is a fixed cost added once per non-empty run — it models
    the one cold load each core pays before on-chip reuse kicks in for the
    rest of its contiguous tile run (shared weights under WIDTH tiling,
    replicated input under OC tiling), so ``times`` should then be the
    *cached* per-IFP latencies.

    Returns (per-core index lists, makespan).  Cores beyond ``len(times)``
    receive empty lists.
    """
    n = len(times)
    if n == 0:
        return [[] for _ in range(k)], 0.0
    k_eff = min(k, n)
    prefix = [0.0]
    for t in times:
        prefix.append(prefix[-1] + t)

    INF = float("inf")
    # dp[j][i] = best makespan splitting the first i items into j runs
    dp = [[INF] * (n + 1) for _ in range(k_eff + 1)]
    cut = [[0] * (n + 1) for _ in range(k_eff + 1)]
    dp[0][0] = 0.0
    for j in range(1, k_eff + 1):
        for i in range(j, n + 1):
            # last run is (p, i]; sweep p from high to low, prune when the
            # last-run sum already exceeds the best found (it only grows).
            best, best_p = INF, j - 1
            for p in range(i - 1, j - 2, -1):
                last = prefix[i] - prefix[p] + run_overhead
                if last >= best:
                    break  # larger p won't help; smaller p only grows `last`
                cand = max(dp[j - 1][p], last)
                if cand < best:
                    best, best_p = cand, p
            dp[j][i] = best
            cut[j][i] = best_p
    # backtrack
    bounds = [n]
    i = n
    for j in range(k_eff, 0, -1):
        i = cut[j][i]
        bounds.append(i)
    bounds.reverse()
    runs = [list(range(bounds[j], bounds[j + 1])) for j in range(k_eff)]
    runs += [[] for _ in range(k - k_eff)]
    return runs, dp[k_eff][n]


def partition_candidates(
    times: Sequence[float], *, run_overhead: float = 0.0
) -> Tuple[List[float], List[float]]:
    """(prefix sums, sorted candidate makespans) for the binary-search solver.
    Depends only on the latency LUT, so the static compiler precomputes it —
    the dynamic path then binary-searches in O(N log N)."""
    prefix = [0.0]
    for t in times:
        prefix.append(prefix[-1] + t)
    n = len(times)
    cands = sorted(
        {prefix[j] - prefix[i] + run_overhead for i in range(n) for j in range(i + 1, n + 1)}
    )
    return prefix, cands


def allocate_contiguous_bs(
    times: Sequence[float], k: int, *, run_overhead: float = 0.0,
    precomputed: Optional[Tuple[List[float], List[float]]] = None,
) -> Tuple[List[List[int]], float]:
    """Exact contiguous partition via binary search over candidate makespans.

    The optimal makespan equals some contiguous run sum (+ overhead), i.e. one
    of the O(N²) prefix-sum differences.  Binary-search those candidates with
    a greedy O(N) feasibility check (pack greedily; feasible iff ≤ k runs).
    O(N² + N² log N) with tiny constants — ~40× faster than the O(N²K) DP on
    the dynamic-compilation path, and verified equal-makespan against the DP
    in tests (hypothesis property).
    """
    n = len(times)
    if n == 0:
        return [[] for _ in range(k)], 0.0
    k_eff = min(k, n)
    if precomputed is not None:
        prefix, cands = precomputed
    else:
        prefix, cands = partition_candidates(times, run_overhead=run_overhead)
    if k_eff >= n:
        # one tile per core: assignment is the identity
        runs = [[i] for i in range(n)] + [[] for _ in range(k - n)]
        return runs, max(times) + run_overhead

    def runs_needed(cap: float) -> int:
        """Greedy: max-length runs with sum+overhead <= cap."""
        runs, i, eps = 0, 0, cap * 1e-12
        while i < n:
            runs += 1
            if runs > k_eff:
                return runs
            start = prefix[i]
            j = i
            while j < n and (prefix[j + 1] - start) + run_overhead <= cap + eps:
                j += 1
            if j == i:       # single item exceeds cap -> infeasible
                return k_eff + 1
            i = j
        return runs

    lo, hi = 0, len(cands) - 1
    while lo < hi:
        mid = (lo + hi) // 2
        if runs_needed(cands[mid]) <= k_eff:
            hi = mid
        else:
            lo = mid + 1
    cap = cands[lo]

    # reconstruct: greedy packing, but never strand more items than cores left
    runs: List[List[int]] = []
    i, eps = 0, cap * 1e-12
    while i < n:
        start = prefix[i]
        j = i
        while j < n and (prefix[j + 1] - start) + run_overhead <= cap + eps:
            j += 1
        j = max(j, i + 1)
        # leave at least one item per remaining core
        remaining_cores = k_eff - len(runs) - 1
        j = min(j, n - remaining_cores)
        runs.append(list(range(i, j)))
        i = j
    runs += [[] for _ in range(k - len(runs))]
    makespan = max(
        (prefix[r[-1] + 1] - prefix[r[0]] + run_overhead) for r in runs if r
    )
    return runs, makespan


def allocate_lpt(times: Sequence[float], k: int) -> Tuple[List[List[int]], float]:
    """Longest-processing-time greedy onto k cores (non-contiguous)."""
    import heapq

    order = sorted(range(len(times)), key=lambda i: -times[i])
    heap = [(0.0, c) for c in range(k)]
    heapq.heapify(heap)
    assign: List[List[int]] = [[] for _ in range(k)]
    for i in order:
        load, c = heapq.heappop(heap)
        assign[c].append(i)
        heapq.heappush(heap, (load + times[i], c))
    makespan = max((sum(times[i] for i in a) for a in assign), default=0.0)
    for a in assign:
        a.sort()
    return assign, makespan


def allocate_weighted(
    times: Sequence[float], speeds: Sequence[float]
) -> Tuple[List[List[int]], float]:
    """LPT onto heterogeneous cores: item i on core c costs ``times[i] /
    speeds[c]``.  Used by straggler mitigation (a slow core has speed < 1)."""
    import heapq

    k = len(speeds)
    order = sorted(range(len(times)), key=lambda i: -times[i])
    heap = [(0.0, c) for c in range(k)]
    heapq.heapify(heap)
    assign: List[List[int]] = [[] for _ in range(k)]
    loads = [0.0] * k
    for i in order:
        # pick the core minimizing its finish time after taking item i
        best_c, best_t = 0, float("inf")
        for load, c in heap:
            t = loads[c] + times[i] / max(speeds[c], 1e-9)
            if t < best_t:
                best_c, best_t = c, t
        assign[best_c].append(i)
        loads[best_c] = best_t
        heap = [(loads[c], c) for c in range(k)]
    for a in assign:
        a.sort()
    return assign, max(loads) if loads else 0.0


def allocate(
    times: Sequence[float], k: int, *, run_overhead: float = 0.0,
    precomputed: Optional[Tuple[List[float], List[float]]] = None,
) -> Tuple[List[List[int]], float]:
    """Workload-balanced allocation (Eq. 4-6): exact contiguous partition via
    the binary-search solver (equal makespan to the O(N²K) DP, much faster —
    this sits on the ~1 ms dynamic-compilation path).  When no per-run reuse
    is at stake (run_overhead == 0), an LPT cross-check is used in case
    contiguity binds."""
    runs_bs, ms_bs = allocate_contiguous_bs(
        times, k, run_overhead=run_overhead, precomputed=precomputed
    )
    if len(times) > k and run_overhead == 0.0:
        runs_lpt, ms_lpt = allocate_lpt(times, k)
        if ms_lpt < ms_bs * (1.0 - 1e-9):
            return runs_lpt, ms_lpt
    return runs_bs, ms_bs
