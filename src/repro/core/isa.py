"""Instruction IR for the virtualized ISA-based accelerator.

The paper's core ISA is {System, Load, Save, Convinit, Conv, Poolinit, Pool}
mapping onto four functional units (LOAD, SAVE, CONV, MISC), with dependency
fields on every instruction so the per-core scheduler (the second-level IDM)
can overlap data movement with compute.

We keep exactly that structure, generalized so the same IR also describes
transformer layers on the TPU adaptation:

* opcodes COMPUTE-class: CONV, POOL, MATMUL, ATTN, SSM, MISC      (unit CONV/MISC)
* opcodes MOVE-class:    LOAD, SAVE                               (unit LOAD/SAVE)
* opcodes CTRL-class:    CONVINIT, SYSTEM (sync/end)              (unit CTRL)

Every instruction carries:
  * ``deps``  — instruction ids it must wait for (data/hardware deps),
  * ``flops`` / ``nbytes`` — cost terms consumed by the latency simulator,
  * ``shape`` — (pixels, c_in, c_out) extent for the Eq.-2 quantization,
  * ``core``  — core index assigned by the dynamic compiler (-1 = unassigned),
  * ``tag``   — free-form metadata (layer index, tile index, tenant, ...).

The IR is deliberately plain-Python (no JAX) — the dynamic compiler must
re-allocate instruction packages in ~1 ms, so everything on that path is
lists/ints.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple


class Op(enum.Enum):
    LOAD = "load"
    SAVE = "save"
    CONV = "conv"
    POOL = "pool"
    MATMUL = "matmul"
    ATTN = "attn"
    SSM = "ssm"
    MISC = "misc"
    CONVINIT = "convinit"
    SYSTEM = "system"   # sync / end-of-task


class Unit(enum.Enum):
    LOAD = "LOAD"
    SAVE = "SAVE"
    CONV = "CONV"    # the main compute array (PE array / MXU)
    MISC = "MISC"    # pooling / elementwise / softmax-ish
    CTRL = "CTRL"


OP_UNIT: Dict[Op, Unit] = {
    Op.LOAD: Unit.LOAD,
    Op.SAVE: Unit.SAVE,
    Op.CONV: Unit.CONV,
    Op.MATMUL: Unit.CONV,
    Op.ATTN: Unit.CONV,
    Op.SSM: Unit.CONV,
    Op.POOL: Unit.MISC,
    Op.MISC: Unit.MISC,
    Op.CONVINIT: Unit.CTRL,
    Op.SYSTEM: Unit.CTRL,
}


@dataclasses.dataclass
class Instr:
    """One instruction. ``flops`` for COMPUTE-class, ``nbytes`` for MOVE-class."""

    iid: int
    op: Op
    flops: float = 0.0
    nbytes: float = 0.0
    shape: Optional[Tuple[int, int, int]] = None   # (pixels, c_in, c_out)
    deps: List[int] = dataclasses.field(default_factory=list)
    core: int = -1
    tag: dict = dataclasses.field(default_factory=dict)

    @property
    def unit(self) -> Unit:
        return OP_UNIT[self.op]

    @property
    def is_sync(self) -> bool:
        return self.op is Op.SYSTEM and self.tag.get("sync", False)


class Program:
    """An append-only instruction container with dependency bookkeeping.

    Used both for whole-layer programs and for instruction frame packages
    (IFPs).  Instruction ids are indices into ``instrs``.
    """

    def __init__(self) -> None:
        self.instrs: List[Instr] = []

    # -- builders -----------------------------------------------------------
    def emit(
        self,
        op: Op,
        *,
        flops: float = 0.0,
        nbytes: float = 0.0,
        shape: Optional[Tuple[int, int, int]] = None,
        deps: Optional[List[int]] = None,
        **tag,
    ) -> int:
        iid = len(self.instrs)
        self.instrs.append(
            Instr(iid=iid, op=op, flops=flops, nbytes=nbytes, shape=shape,
                  deps=list(deps or []), tag=tag)
        )
        return iid

    def load(self, nbytes: float, deps=None, **tag) -> int:
        return self.emit(Op.LOAD, nbytes=nbytes, deps=deps, **tag)

    def save(self, nbytes: float, deps=None, **tag) -> int:
        return self.emit(Op.SAVE, nbytes=nbytes, deps=deps, **tag)

    def sync(self, deps=None, **tag) -> int:
        return self.emit(Op.SYSTEM, deps=deps, sync=True, **tag)

    # -- utilities ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.instrs)

    def __iter__(self):
        return iter(self.instrs)

    @property
    def total_flops(self) -> float:
        return sum(i.flops for i in self.instrs)

    @property
    def total_bytes(self) -> float:
        return sum(i.nbytes for i in self.instrs)

    def validate(self) -> None:
        """Deps must point backwards (the IR is in issue order per unit)."""
        for ins in self.instrs:
            for d in ins.deps:
                if not (0 <= d < ins.iid):
                    raise ValueError(
                        f"instr {ins.iid} ({ins.op}) has invalid dep {d}"
                    )

    def relabel(self, offset: int) -> "Program":
        """Copy with all ids/deps shifted by ``offset`` (for concatenation)."""
        out = Program()
        for ins in self.instrs:
            out.instrs.append(
                Instr(
                    iid=ins.iid + offset,
                    op=ins.op,
                    flops=ins.flops,
                    nbytes=ins.nbytes,
                    shape=ins.shape,
                    deps=[d + offset for d in ins.deps],
                    core=ins.core,
                    tag=dict(ins.tag),
                )
            )
        return out


class Chain:
    """Zero-copy sequence of Programs run back-to-back on one core.

    The dynamic compiler concatenates cached IFP artifacts by *reference*
    (the ~1 ms online path — paper Table 2); dependency ids stay local to
    each program, and the per-unit in-order issue provides the
    serialization across programs, exactly like appended instruction files.
    """

    __slots__ = ("programs",)

    def __init__(self, programs=None) -> None:
        self.programs: List[Program] = list(programs or [])

    def append(self, prog: Program) -> None:
        self.programs.append(prog)

    def __len__(self) -> int:
        return sum(len(p) for p in self.programs)

    def __iter__(self):
        for p in self.programs:
            yield from p

    @property
    def total_flops(self) -> float:
        return sum(p.total_flops for p in self.programs)

    @property
    def total_bytes(self) -> float:
        return sum(p.total_bytes for p in self.programs)

    def materialize(self) -> Program:
        """Flatten to a single Program (tests / debugging only)."""
        return concat(self.programs)


def _sync_prog() -> Program:
    p = Program()
    p.sync()
    return p


#: shared per-layer synchronization `System` instruction (paper §5.2.2)
SYNC_PROGRAM = _sync_prog()


def concat(programs: List[Program]) -> Program:
    """Concatenate programs, rewriting instruction ids; later programs get an
    implicit dependency on nothing (the per-unit in-order issue provides the
    serialization, exactly like appending instruction files)."""
    out = Program()
    off = 0
    for p in programs:
        shifted = p.relabel(off)
        out.instrs.extend(shifted.instrs)
        off += len(p)
    return out
