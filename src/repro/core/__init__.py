"""Core library: the paper's FPGA-virtualization technique in portable form.

Pipeline:  workload (layer table)
        -> StaticCompiler   (offline: tiled IFPs + latency LUT)     §5.2.1
        -> DynamicCompiler  (online ~ms: workload-balanced realloc) §5.2.2
        -> VirtualEngine    (HRP leases + two-level IDM + barriers) §4
        -> Hypervisor       (global event loop + realloc policies)  §4.1

The Hypervisor layer is the scheduling core everything else rides on: one
time-ordered event queue (`repro.core.events`) of tenant arrivals,
departures, request completions, reconfiguration signals and straggler
probes, consumed by `repro.core.hypervisor.Hypervisor`, which owns the
`ResourcePool` and asks a pluggable reallocation policy (``even_split``,
``weighted_by_workload``, ``priority``, or the ``no_realloc`` baseline) how
to divide the pool on every event.  Tenants that cannot get their floor
park in a FIFO admission wait-queue.  Decisions are executed by whichever
backend is attached: the discrete-event ``VirtualEngine`` (simulation), a
bookkeeping-only ``PoolExecutor`` (analytic sweeps), or the JAX serving
adapter (`repro.serving.tenancy.ServingExecutor`), where a resize decision
becomes a ``TwoStageCompiler.reconfigure`` call.  HRP isolation invariants
are re-checked after every handled event.
"""

from .allocator import allocate, allocate_contiguous_dp, allocate_lpt, allocate_weighted
from .dispatch import (
    ContextSwitchController,
    InstructionRouter,
    MultiCoreSyncController,
    SwitchMode,
)
from .dynamic_compiler import DynamicCompiler, Schedule
from .events import (
    Event,
    EventKind,
    EventQueue,
    PoissonTraffic,
    RequestRecord,
    TraceTraffic,
    emit_requests,
)
from .faults import FaultInjector, FaultKind, FaultSpec
from .hrp import HRPError, Lease, ResourcePool
from .hwmodel import (
    HardwareModel,
    fpga_core,
    fpga_large_core,
    fpga_small_core,
    tpu_v5e_chip,
)
from .hypervisor import (
    POLICIES,
    Hypervisor,
    PolicyContext,
    PoolExecutor,
    TenantSpec,
    kv_pages_proportional,
    latency_slo,
    queueing_latency,
    resolve_policy,
    slo_demand,
)
from .ifp import IFP, Strategy, dedupe_onchip, make_layer_ifps
from .isa import Chain, Instr, Op, Program, SYNC_PROGRAM, Unit, concat
from .latency_sim import roofline_terms, simulate, simulate_layer_barrier
from .static_compiler import StaticArtifact, StaticCompiler, compile_monolithic
from .vengine import ReconfigRequest, TenantMetrics, VirtualEngine
from .workloads import CNN_WORKLOADS, Layer, lm_layer_table, workload_stats

__all__ = [
    "allocate", "allocate_contiguous_dp", "allocate_lpt", "allocate_weighted",
    "ContextSwitchController", "InstructionRouter", "MultiCoreSyncController",
    "SwitchMode", "DynamicCompiler", "Schedule", "Event", "EventKind",
    "EventQueue", "PoissonTraffic", "RequestRecord", "TraceTraffic",
    "emit_requests", "FaultInjector", "FaultKind", "FaultSpec",
    "HRPError", "Lease",
    "ResourcePool", "HardwareModel", "fpga_core", "fpga_large_core",
    "fpga_small_core", "tpu_v5e_chip", "POLICIES", "Hypervisor",
    "PolicyContext", "PoolExecutor", "TenantSpec", "kv_pages_proportional",
    "latency_slo", "queueing_latency", "resolve_policy", "slo_demand",
    "IFP", "Strategy", "dedupe_onchip",
    "make_layer_ifps", "Chain", "Instr", "Op", "Program", "SYNC_PROGRAM",
    "Unit", "concat",
    "roofline_terms", "simulate", "simulate_layer_barrier", "StaticArtifact",
    "StaticCompiler", "compile_monolithic", "ReconfigRequest", "TenantMetrics",
    "VirtualEngine", "CNN_WORKLOADS", "Layer", "lm_layer_table",
    "workload_stats",
]
