"""Core library: the paper's FPGA-virtualization technique in portable form.

Pipeline:  workload (layer table)
        -> StaticCompiler   (offline: tiled IFPs + latency LUT)     §5.2.1
        -> DynamicCompiler  (online ~ms: workload-balanced realloc) §5.2.2
        -> VirtualEngine    (HRP leases + two-level IDM + barriers) §4
"""

from .allocator import allocate, allocate_contiguous_dp, allocate_lpt, allocate_weighted
from .dispatch import (
    ContextSwitchController,
    InstructionRouter,
    MultiCoreSyncController,
    SwitchMode,
)
from .dynamic_compiler import DynamicCompiler, Schedule
from .hrp import HRPError, Lease, ResourcePool
from .hwmodel import (
    HardwareModel,
    fpga_core,
    fpga_large_core,
    fpga_small_core,
    tpu_v5e_chip,
)
from .ifp import IFP, Strategy, dedupe_onchip, make_layer_ifps
from .isa import Chain, Instr, Op, Program, SYNC_PROGRAM, Unit, concat
from .latency_sim import roofline_terms, simulate, simulate_layer_barrier
from .static_compiler import StaticArtifact, StaticCompiler, compile_monolithic
from .vengine import ReconfigRequest, TenantMetrics, VirtualEngine
from .workloads import CNN_WORKLOADS, Layer, lm_layer_table, workload_stats

__all__ = [
    "allocate", "allocate_contiguous_dp", "allocate_lpt", "allocate_weighted",
    "ContextSwitchController", "InstructionRouter", "MultiCoreSyncController",
    "SwitchMode", "DynamicCompiler", "Schedule", "HRPError", "Lease",
    "ResourcePool", "HardwareModel", "fpga_core", "fpga_large_core",
    "fpga_small_core", "tpu_v5e_chip", "IFP", "Strategy", "dedupe_onchip",
    "make_layer_ifps", "Chain", "Instr", "Op", "Program", "SYNC_PROGRAM",
    "Unit", "concat",
    "roofline_terms", "simulate", "simulate_layer_barrier", "StaticArtifact",
    "StaticCompiler", "compile_monolithic", "ReconfigRequest", "TenantMetrics",
    "VirtualEngine", "CNN_WORKLOADS", "Layer", "lm_layer_table",
    "workload_stats",
]
