"""Workload descriptions: per-layer shape tables.

The faithful-reproduction benchmarks use the paper's four CNNs at 224x224
(VGG16, ResNet50, Inception v3, MobileNet v1) exactly as in §6.1.  The layer
tables below drive the static compiler -> IFP tiling -> latency simulator.
Angel-Eye runs 8-bit fixed point, so activation/weight dtypes default to 1 B.

The TPU-side LM stack converts a model config into the same ``Layer`` IR via
:func:`lm_layer_table`, which is what lets the paper's per-layer
{width | output-channel} tiling choice act as a {data- | tensor-}parallel
sharding selector for transformers.
"""

from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class Layer:
    """A conv/matmul-like layer.  ``w`` is the width-tiling dim (pixels for
    CNNs, tokens for LMs); ``c_out`` is the output-channel-tiling dim."""

    name: str
    h: int
    w: int
    c_in: int
    c_out: int
    kh: int = 1
    kw: int = 1
    stride: int = 1
    groups: int = 1
    abytes: int = 1     # activation bytes/elem
    wbytes: int = 1     # weight bytes/elem
    # for LM pseudo-layers whose "weights" are a KV cache / SSM state:
    extra_in_bytes: float = 0.0

    # -- cost terms ---------------------------------------------------------
    @property
    def flops(self) -> float:
        return 2.0 * self.h * self.w * self.c_out * (self.c_in // self.groups) * self.kh * self.kw

    @property
    def weight_nbytes(self) -> float:
        return float(self.c_out * (self.c_in // self.groups) * self.kh * self.kw * self.wbytes)

    def input_nbytes(self, w_cols: int | None = None, c_in: int | None = None) -> float:
        """Bytes of input feature map needed to produce ``w_cols`` output
        columns (with halo for kw>1) over ``c_in`` input channels."""
        w_cols = self.w if w_cols is None else w_cols
        c_in = self.c_in if c_in is None else c_in
        h_in = self.h * self.stride + max(self.kh - self.stride, 0)
        w_in = w_cols * self.stride + max(self.kw - self.stride, 0)
        return float(h_in * w_in * c_in * self.abytes) + self.extra_in_bytes

    @property
    def output_nbytes(self) -> float:
        return float(self.h * self.w * self.c_out * self.abytes)

    @property
    def is_depthwise(self) -> bool:
        return self.groups > 1 and self.groups == self.c_in == self.c_out


Workload = List[Layer]


# ---------------------------------------------------------------------------
# Paper CNNs (224 x 224 input, batch 1, int8)
# ---------------------------------------------------------------------------


def vgg16() -> Workload:
    layers: Workload = []
    cfg = [(64, 2, 224), (128, 2, 112), (256, 3, 56), (512, 3, 28), (512, 3, 14)]
    c_in = 3
    for b, (c, n, hw) in enumerate(cfg):
        for i in range(n):
            layers.append(Layer(f"conv{b+1}_{i+1}", hw, hw, c_in, c, 3, 3))
            c_in = c
    layers.append(Layer("fc6", 1, 1, 512 * 7 * 7, 4096))
    layers.append(Layer("fc7", 1, 1, 4096, 4096))
    layers.append(Layer("fc8", 1, 1, 4096, 1000))
    return layers


def resnet50() -> Workload:
    L: Workload = [Layer("conv1", 112, 112, 3, 64, 7, 7, stride=2)]
    stages = [  # (n_blocks, mid, out, hw)
        (3, 64, 256, 56),
        (4, 128, 512, 28),
        (6, 256, 1024, 14),
        (3, 512, 2048, 7),
    ]
    c_in = 64
    for s, (n, mid, out, hw) in enumerate(stages):
        for b in range(n):
            stride = 2 if (b == 0 and s > 0) else 1
            pre = f"res{s+2}{chr(ord('a')+b)}"
            L.append(Layer(f"{pre}_1x1a", hw, hw, c_in, mid, 1, 1, stride=stride))
            L.append(Layer(f"{pre}_3x3", hw, hw, mid, mid, 3, 3))
            L.append(Layer(f"{pre}_1x1b", hw, hw, mid, out, 1, 1))
            if b == 0:
                L.append(Layer(f"{pre}_proj", hw, hw, c_in, out, 1, 1, stride=stride))
            c_in = out
    L.append(Layer("fc", 1, 1, 2048, 1000))
    return L


def inception_v3() -> Workload:
    """Inception v3 branch convolutions (299x299 input).  Branches within a
    module are independent layers — natural fodder for multi-core tiling."""
    L: Workload = [
        Layer("stem_c1", 149, 149, 3, 32, 3, 3, stride=2),
        Layer("stem_c2", 147, 147, 32, 32, 3, 3),
        Layer("stem_c3", 147, 147, 32, 64, 3, 3),
        Layer("stem_c4", 73, 73, 64, 80, 1, 1),
        Layer("stem_c5", 71, 71, 80, 192, 3, 3),
    ]

    def inception_a(tag: str, c_in: int, pool_c: int) -> None:
        hw = 35
        L.append(Layer(f"{tag}_b1_1x1", hw, hw, c_in, 64))
        L.append(Layer(f"{tag}_b2_1x1", hw, hw, c_in, 48))
        L.append(Layer(f"{tag}_b2_5x5", hw, hw, 48, 64, 5, 5))
        L.append(Layer(f"{tag}_b3_1x1", hw, hw, c_in, 64))
        L.append(Layer(f"{tag}_b3_3x3a", hw, hw, 64, 96, 3, 3))
        L.append(Layer(f"{tag}_b3_3x3b", hw, hw, 96, 96, 3, 3))
        L.append(Layer(f"{tag}_pool_1x1", hw, hw, c_in, pool_c))

    inception_a("mixed0", 192, 32)
    inception_a("mixed1", 256, 64)
    inception_a("mixed2", 288, 64)

    # reduction A
    L.append(Layer("mixed3_b1_3x3", 17, 17, 288, 384, 3, 3, stride=2))
    L.append(Layer("mixed3_b2_1x1", 35, 35, 288, 64))
    L.append(Layer("mixed3_b2_3x3a", 35, 35, 64, 96, 3, 3))
    L.append(Layer("mixed3_b2_3x3b", 17, 17, 96, 96, 3, 3, stride=2))

    def inception_b(tag: str, c7: int) -> None:
        hw, c_in = 17, 768
        L.append(Layer(f"{tag}_b1_1x1", hw, hw, c_in, 192))
        L.append(Layer(f"{tag}_b2_1x1", hw, hw, c_in, c7))
        L.append(Layer(f"{tag}_b2_1x7", hw, hw, c7, c7, 1, 7))
        L.append(Layer(f"{tag}_b2_7x1", hw, hw, c7, 192, 7, 1))
        L.append(Layer(f"{tag}_b3_1x1", hw, hw, c_in, c7))
        L.append(Layer(f"{tag}_b3_7x1a", hw, hw, c7, c7, 7, 1))
        L.append(Layer(f"{tag}_b3_1x7a", hw, hw, c7, c7, 1, 7))
        L.append(Layer(f"{tag}_b3_7x1b", hw, hw, c7, c7, 7, 1))
        L.append(Layer(f"{tag}_b3_1x7b", hw, hw, c7, 192, 1, 7))
        L.append(Layer(f"{tag}_pool_1x1", hw, hw, c_in, 192))

    inception_b("mixed4", 128)
    inception_b("mixed5", 160)
    inception_b("mixed6", 160)
    inception_b("mixed7", 192)

    # reduction B
    L.append(Layer("mixed8_b1_1x1", 17, 17, 768, 192))
    L.append(Layer("mixed8_b1_3x3", 8, 8, 192, 320, 3, 3, stride=2))
    L.append(Layer("mixed8_b2_1x1", 17, 17, 768, 192))
    L.append(Layer("mixed8_b2_1x7", 17, 17, 192, 192, 1, 7))
    L.append(Layer("mixed8_b2_7x1", 17, 17, 192, 192, 7, 1))
    L.append(Layer("mixed8_b2_3x3", 8, 8, 192, 192, 3, 3, stride=2))

    def inception_c(tag: str, c_in: int) -> None:
        hw = 8
        L.append(Layer(f"{tag}_b1_1x1", hw, hw, c_in, 320))
        L.append(Layer(f"{tag}_b2_1x1", hw, hw, c_in, 384))
        L.append(Layer(f"{tag}_b2_1x3", hw, hw, 384, 384, 1, 3))
        L.append(Layer(f"{tag}_b2_3x1", hw, hw, 384, 384, 3, 1))
        L.append(Layer(f"{tag}_b3_1x1", hw, hw, c_in, 448))
        L.append(Layer(f"{tag}_b3_3x3", hw, hw, 448, 384, 3, 3))
        L.append(Layer(f"{tag}_b3_1x3", hw, hw, 384, 384, 1, 3))
        L.append(Layer(f"{tag}_b3_3x1", hw, hw, 384, 384, 3, 1))
        L.append(Layer(f"{tag}_pool_1x1", hw, hw, c_in, 192))

    inception_c("mixed9", 1280)
    inception_c("mixed10", 2048)
    L.append(Layer("fc", 1, 1, 2048, 1000))
    return L


def mobilenet_v1() -> Workload:
    L: Workload = [Layer("conv1", 112, 112, 3, 32, 3, 3, stride=2)]
    # (c_out of pointwise, output hw, stride of depthwise)
    cfg = [
        (64, 112, 1), (128, 56, 2), (128, 56, 1), (256, 28, 2), (256, 28, 1),
        (512, 14, 2), (512, 14, 1), (512, 14, 1), (512, 14, 1), (512, 14, 1),
        (512, 14, 1), (1024, 7, 2), (1024, 7, 1),
    ]
    c_in = 32
    for i, (c, hw, s) in enumerate(cfg):
        L.append(Layer(f"dw{i+1}", hw, hw, c_in, c_in, 3, 3, stride=s, groups=c_in))
        L.append(Layer(f"pw{i+1}", hw, hw, c_in, c, 1, 1))
        c_in = c
    L.append(Layer("fc", 1, 1, 1024, 1000))
    return L


CNN_WORKLOADS = {
    "vgg16": vgg16,
    "resnet50": resnet50,
    "inception_v3": inception_v3,
    "mobilenet": mobilenet_v1,
}


# ---------------------------------------------------------------------------
# LM decoder layers -> Layer IR (for the TPU-side virtualization engine)
# ---------------------------------------------------------------------------


def lm_layer_table(
    *,
    n_layers: int,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    d_ff: int,
    vocab: int,
    seq: int,
    batch: int = 1,
    moe_experts: int = 0,
    moe_topk: int = 0,
    abytes: int = 2,
    wbytes: int = 2,
    decode: bool = False,
) -> Workload:
    """Transformer decoder as a Layer table (tokens on the width axis).

    ``decode=True`` prices one new token per sequence against a KV cache of
    ``seq`` (the cache read shows up as ``extra_in_bytes`` of the attention
    pseudo-layer — the "weights" analogue that output-channel tiling shards).
    """
    d_head = d_model // n_heads
    tokens = batch * (1 if decode else seq)
    kv_ctx = seq
    L: Workload = []
    for i in range(n_layers):
        L.append(Layer(f"l{i}_qkv", 1, tokens, d_model,
                       (n_heads + 2 * n_kv_heads) * d_head,
                       abytes=abytes, wbytes=wbytes))
        # attention as a pseudo-layer: flops = 4*tokens*ctx*d per (shared) head
        attn_flops_cols = 2 * kv_ctx * d_head * n_heads * 2  # qk + av
        kv_bytes = 2 * kv_ctx * n_kv_heads * d_head * abytes
        L.append(Layer(f"l{i}_attn", 1, tokens, attn_flops_cols // 2, 1,
                       abytes=abytes, wbytes=0, extra_in_bytes=kv_bytes))
        L.append(Layer(f"l{i}_out", 1, tokens, n_heads * d_head, d_model,
                       abytes=abytes, wbytes=wbytes))
        if moe_experts:
            # active experts only (top-k routed); each is up+gate+down
            for e in range(moe_topk):
                L.append(Layer(f"l{i}_moe{e}_up", 1, tokens, d_model, 2 * d_ff,
                               abytes=abytes, wbytes=wbytes))
                L.append(Layer(f"l{i}_moe{e}_down", 1, tokens, d_ff, d_model,
                               abytes=abytes, wbytes=wbytes))
        else:
            L.append(Layer(f"l{i}_up", 1, tokens, d_model, 2 * d_ff,
                           abytes=abytes, wbytes=wbytes))
            L.append(Layer(f"l{i}_down", 1, tokens, d_ff, d_model,
                           abytes=abytes, wbytes=wbytes))
    L.append(Layer("lm_head", 1, tokens, d_model, vocab, abytes=abytes, wbytes=wbytes))
    return L


def workload_stats(layers: Workload) -> dict:
    return {
        "layers": len(layers),
        "gflops": sum(l.flops for l in layers) / 1e9,
        "weight_mb": sum(l.weight_nbytes for l in layers) / 1e6,
        "act_mb": sum(l.output_nbytes for l in layers) / 1e6,
    }
