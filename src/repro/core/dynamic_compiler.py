"""Dynamic compiler — paper §5.2.2 (online reconfiguration stage, ~1 ms).

On every hardware re-allocation (a tenant's core count changes), the dynamic
compiler — *without* touching the expensive static artifacts — per layer:

1. fetches the latency LUTs of both tiling strategies from the cache,
2. runs the workload-balanced allocator (Eq. 4-6) for the allocated core
   count under each strategy,
3. picks the strategy with the smaller estimated makespan,
4. concatenates the chosen IFPs per core (dropping on-chip-reusable loads)
   and appends the synchronization ``System`` instruction,

and repeats until all layers are emitted.  The output is a
:class:`Schedule` — per-core, per-layer instruction programs plus metadata —
and the measured wall-clock of this function is the paper's
``T_recompile`` (Table 2).
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple

from .allocator import allocate, allocate_weighted
from .hwmodel import HardwareModel
from .ifp import Strategy
from .isa import Chain, SYNC_PROGRAM
from .latency_sim import simulate_layer_barrier
from .static_compiler import StaticArtifact


@dataclasses.dataclass
class LayerPlan:
    strategy: Strategy
    assignment: List[List[int]]     # per-core IFP index lists
    est_makespan: float


@dataclasses.dataclass
class Schedule:
    """Dynamic-compilation output for one tenant.

    ``per_core_layers[c][l]`` is a :class:`~repro.core.isa.Chain` of cached
    IFP programs: the first tile of a contiguous run is the cold artifact
    (pays the shared load), the rest are the on-chip-cached artifacts — the
    zero-copy analogue of concatenating cached instruction files."""

    core_ids: List[int]                       # physical core indices (HRP lease)
    per_core_layers: List[List[Chain]]        # [local core][layer] -> chain
    plans: List[LayerPlan]
    compile_seconds: float                    # T_recompile
    instr_count: int
    from_cache: bool = False                  # schedule reused from the LRU

    @property
    def n_cores(self) -> int:
        return len(self.core_ids)

    @property
    def transfer_bytes(self) -> float:
        """Instruction-file size: the paper ships binary instruction words;
        we charge 16 B per instruction (128-bit words)."""
        return 16.0 * self.instr_count

    def estimated_latency(self, hw: HardwareModel) -> float:
        return simulate_layer_barrier(self.per_core_layers, hw)


class DynamicCompiler:
    """Online stage of the two-stage static-dynamic compilation.

    Schedules are memoized in an LRU keyed on ``(len(core_ids), fastpath,
    rounded core_speeds)``: the plan depends only on the core *count* (and
    relative speeds), not on which physical cores the HRP granted, so a
    Hypervisor reconfiguring a tenant back to a previously seen size reuses
    the schedule at lookup cost — T_recompile collapses to ~µs on hits
    (reported through :meth:`context_switch_cost`).
    """

    def __init__(self, artifact: StaticArtifact, *, cache_size: int = 32) -> None:
        self.artifact = artifact
        self._schedule_cache: "OrderedDict[Tuple, Schedule]" = OrderedDict()
        self._cache_size = cache_size
        self.cache_hits = 0
        self.cache_misses = 0

    def compile(
        self,
        core_ids: Sequence[int],
        *,
        single_core_fastpath: bool = True,
        core_speeds: Sequence[float] | None = None,
    ) -> Schedule:
        """Generate (or reuse) the per-core instruction schedule for
        ``core_ids``.

        ``single_core_fastpath`` implements the §6.3.3 optimization: when a
        tenant holds exactly one core, emit the monolithic untiled per-layer
        programs (no tiling overhead) instead of 16 concatenated tiles.

        ``core_speeds`` (straggler mitigation): relative speed per core; when
        given, allocation uses the heterogeneous-LPT solver so slow cores
        receive proportionally fewer IFPs.
        """
        t0 = time.perf_counter()
        key = (
            len(core_ids), bool(single_core_fastpath),
            None if core_speeds is None
            else tuple(round(float(s), 3) for s in core_speeds),
        )
        hit = self._schedule_cache.get(key)
        if hit is not None:
            self._schedule_cache.move_to_end(key)
            self.cache_hits += 1
            # same plan, new physical cores; T_recompile = the lookup
            return dataclasses.replace(
                hit, core_ids=list(core_ids),
                compile_seconds=time.perf_counter() - t0, from_cache=True,
            )
        sched = self._compile_uncached(
            core_ids, single_core_fastpath=single_core_fastpath,
            core_speeds=core_speeds, t0=t0,
        )
        self.cache_misses += 1
        self._schedule_cache[key] = sched
        if len(self._schedule_cache) > self._cache_size:
            self._schedule_cache.popitem(last=False)
        return sched

    def _compile_uncached(
        self,
        core_ids: Sequence[int],
        *,
        single_core_fastpath: bool,
        core_speeds: Sequence[float] | None,
        t0: float,
    ) -> Schedule:
        k = len(core_ids)
        art = self.artifact
        n_layers = len(art.workload)
        per_core: List[List[Chain]] = [[] for _ in range(k)]
        plans: List[LayerPlan] = []

        if single_core_fastpath and k == 1 and art.mono:
            # §6.3.3 optimization: a tenant holding exactly one core gets the
            # original untiled instruction files — no tiling overhead at all.
            for li in range(n_layers):
                per_core[0].append(Chain([art.mono[li], SYNC_PROGRAM]))
                plans.append(
                    LayerPlan(
                        strategy=Strategy.WIDTH,
                        assignment=[[0]],
                        est_makespan=art.mono_latency[li],
                    )
                )
            dt = time.perf_counter() - t0
            n_instr = sum(len(c) for layers in per_core for c in layers)
            return Schedule(
                core_ids=list(core_ids),
                per_core_layers=per_core,
                plans=plans,
                compile_seconds=dt,
                instr_count=n_instr,
            )

        for li in range(n_layers):
            best_plan: LayerPlan | None = None
            for strategy in (Strategy.WIDTH, Strategy.OC):
                lut = art.lut(li, strategy)
                if core_speeds is not None:
                    runs, makespan = allocate_weighted(lut.cold, core_speeds)
                else:
                    runs, makespan = allocate(
                        lut.cached, k, run_overhead=lut.run_overhead,
                        precomputed=lut.precomputed,
                    )
                plan = LayerPlan(strategy=strategy, assignment=runs, est_makespan=makespan)
                if best_plan is None or makespan < best_plan.est_makespan:
                    best_plan = plan
            assert best_plan is not None
            plans.append(best_plan)

            # chain the cached artifacts: first tile of a contiguous run is
            # the cold program (pays the shared load once per core), the rest
            # run with the shared tensor already on-chip.  Zero instruction
            # rewriting — this is what keeps T_recompile at ~1 ms.
            lut = art.lut(li, best_plan.strategy)
            for c in range(k):
                idxs = best_plan.assignment[c] if c < len(best_plan.assignment) else []
                chain = Chain()
                for j, i in enumerate(idxs):
                    ifp = lut.ifps[i]
                    chain.append(
                        ifp.program if j == 0 else (ifp.program_cached or ifp.program)
                    )
                # layer-wise multi-core synchronization (§5.2.2): every core,
                # busy or not, runs the sync System instruction of this layer.
                chain.append(SYNC_PROGRAM)
                per_core[c].append(chain)

        dt = time.perf_counter() - t0
        n_instr = sum(len(c) for layers in per_core for c in layers)
        return Schedule(
            core_ids=list(core_ids),
            per_core_layers=per_core,
            plans=plans,
            compile_seconds=dt,
            instr_count=n_instr,
        )

    # ------------------------------------------------------------------
    def context_switch_cost(self, schedule: Schedule, hw: HardwareModel) -> Dict[str, float]:
        """Paper Eq. 7: T_context = T_recompile + T_transfer.

        Transfer is priced at PCIe-class bandwidth (the paper measures
        0.03-0.20 ms for instruction files over the host link)."""
        pcie_bw = 8e9  # bytes/s, PCIe3 x8 effective
        t_transfer = schedule.transfer_bytes / pcie_bw
        return {
            "t_recompile": schedule.compile_seconds,
            "t_transfer": t_transfer,
            "t_context": schedule.compile_seconds + t_transfer,
            "cache_hit": 1.0 if schedule.from_cache else 0.0,
            "cache_hits": float(self.cache_hits),
        }
