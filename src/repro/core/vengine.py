"""Virtualized multi-tenant execution engine (discrete-event).

Ties the whole paper together: the HRP leases cores to tenants, the two-stage
compiler produces per-core schedules, the two-level IDM controllers manage
context switches and layer barriers, and the latency simulator supplies
per-layer core times.  Because leases are disjoint and every core owns its
off-chip port, tenants' timelines are independent — each tenant's clock
advances separately, which *is* the isolation property (a small optional
DDR-group crosstalk factor models the arbiter of §4.2.2 when tenants share a
bank, bounded well under the paper's 1% deviation).

Since the Hypervisor refactor the engine is an **executor** for the global
event loop (:class:`repro.core.hypervisor.Hypervisor`): the hypervisor pops
time-ordered arrival/departure/reconfig/probe events, calls :meth:`advance`
to bring every tenant's clock to the event's timestamp, and carries policy
decisions out through :meth:`exec_admit` / :meth:`exec_resize` /
:meth:`exec_remove`.  :meth:`run` is the degenerate case — a ``no_realloc``
hypervisor with an empty event queue — and reproduces the seed engine's
per-tenant independent clocks exactly.

Supports:
  * closed-loop inference (each tenant re-issues back-to-back requests),
  * **open-loop inference** (requests arrive on their own seeded clock via
    :class:`~repro.core.events.PoissonTraffic` / ``TraceTraffic``; a tenant
    with an empty inbox idles instead of re-issuing, and every served
    request's arrival→start→completion times are stamped on its shared
    :class:`~repro.core.events.RequestRecord` — the latency-SLO substrate),
  * hypervisor reconfiguration at a global time (task- or layer-level switch,
    with measured dynamic-recompile + transfer cost added to the timeline),
  * dynamic tenant arrival/departure with policy-driven pool rebalancing,
  * **preemptive eviction** (``exec_evict``: the displaced tenant pays one
    context switch, its queued requests park and follow it back in on
    re-admission, and its metrics survive in ``history``),
  * straggler injection (per-core slowdown) and mitigation (weighted
    re-allocation of the remaining layers via the dynamic compiler), either
    inline per layer or via hypervisor-scheduled straggler probes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from .dispatch import ContextSwitchController, MultiCoreSyncController, SwitchMode
from .dynamic_compiler import DynamicCompiler, Schedule
from .events import RequestRecord
from .hwmodel import HardwareModel
from .hrp import ResourcePool
from .hypervisor import Hypervisor, TenantSpec
from .latency_sim import simulate
from .static_compiler import StaticArtifact


@dataclasses.dataclass
class ReconfigRequest:
    t_request: float
    n_cores: int
    mode: SwitchMode = SwitchMode.LAYER_LEVEL


@dataclasses.dataclass
class TenantMetrics:
    completions: List[float] = dataclasses.field(default_factory=list)
    ctx_switches: int = 0
    ctx_overhead: float = 0.0
    rebalances: int = 0
    # open-loop request accounting
    arrivals: int = 0
    requests: List[RequestRecord] = dataclasses.field(default_factory=list)
    evictions: int = 0
    dropped: int = 0          # shed before start: deadline already passed

    def throughput(self, horizon: float) -> float:
        return len(self.completions) / horizon if horizon > 0 else 0.0

    @property
    def latencies(self) -> List[float]:
        return [r.latency for r in self.requests if r.latency is not None]

    def slo_attainment(self) -> Optional[float]:
        """Fraction of *offered* requests served within their SLO.  Unserved
        arrivals count against attainment; None when no requests arrived."""
        if self.arrivals == 0:
            return None
        return sum(1 for r in self.requests if r.slo_met) / self.arrivals


@dataclasses.dataclass
class _Tenant:
    name: str
    artifact: StaticArtifact
    dyn: DynamicCompiler
    schedule: Schedule
    clock: float = 0.0
    layer_idx: int = 0
    inference_id: int = 0
    pending: List[ReconfigRequest] = dataclasses.field(default_factory=list)
    metrics: TenantMetrics = dataclasses.field(default_factory=TenantMetrics)
    # open-loop request state: once any request is submitted the tenant stops
    # re-issuing back-to-back inferences and only serves its inbox
    open_loop: bool = False
    inbox: List[RequestRecord] = dataclasses.field(default_factory=list)
    current_req: Optional[RequestRecord] = None
    # speeds the last probe-driven rebalance compiled for (avoids recompiling
    # the same weighted schedule on every probe tick)
    probe_speeds: Optional[List[float]] = None
    # simulate() results per (hw name, layer) for the *current* schedule —
    # schedules and their chains are immutable, so per-layer times are too;
    # the cache is cleared whenever the schedule is replaced (id()-based keys
    # would risk stale hits after CPython address reuse and grow unboundedly
    # under policy-driven recompile churn).
    _layer_cache: Dict[Tuple[str, int], Dict[int, float]] = dataclasses.field(
        default_factory=dict
    )


class VirtualEngine:
    def __init__(
        self,
        pool: ResourcePool,
        hw_unit: HardwareModel,
        *,
        ddr_crosstalk: float = 0.004,
        straggler_threshold: float = 1.5,
        mitigate_stragglers: bool = False,
    ) -> None:
        self.pool = pool
        self.hw = hw_unit
        self.ddr_crosstalk = ddr_crosstalk
        self.straggler_threshold = straggler_threshold
        self.mitigate_stragglers = mitigate_stragglers
        self.sync = MultiCoreSyncController()
        self.ctx = ContextSwitchController()
        self.tenants: Dict[str, _Tenant] = {}
        self.core_slowdown: Dict[int, float] = {}
        # metrics of departed tenants survive removal (event-driven runs);
        # a re-admitted (previously evicted) tenant resumes its old record
        self.history: Dict[str, TenantMetrics] = {}
        # queued open-loop requests of evicted tenants, re-attached on
        # re-admission (preemption must not drop offered load)
        self._parked_requests: Dict[str, List[RequestRecord]] = {}
        # invoked with each finished RequestRecord (the hypervisor wires this
        # to COMPLETION-event scheduling)
        self.completion_sink: Optional[Callable[[RequestRecord], None]] = None
        # latency_slo demand model caches: per-artifact DynamicCompiler (also
        # keeps the artifact alive so the id() key cannot be reused) and the
        # estimated single-inference latency per (artifact, core count)
        self._est_dyn: Dict[int, DynamicCompiler] = {}
        self._lat_cache: Dict[Tuple[int, int], float] = {}
        # latest deferred (task-level) hypervisor decision per tenant, so a
        # newer policy decision supersedes a not-yet-applied one
        self._deferred_hv: Dict[str, ReconfigRequest] = {}
        self._horizon = float("inf")
        self._max_inferences: Optional[int] = None

    # -- admission ------------------------------------------------------------
    def admit(self, name: str, artifact: StaticArtifact, n_cores: int,
              *, at: float = 0.0) -> None:
        lease = self.pool.alloc(name, n_cores)
        dyn = DynamicCompiler(artifact)
        schedule = dyn.compile(lease.cores)
        self.sync.configure(name, set(lease.cores))
        metrics = self.history.pop(name, None) or TenantMetrics()
        tenant = _Tenant(name, artifact, dyn, schedule, clock=at, metrics=metrics)
        parked = self._parked_requests.pop(name, None)
        if parked:
            tenant.inbox = parked
            tenant.open_loop = True
        self.tenants[name] = tenant

    def remove(self, name: str) -> None:
        tenant = self.tenants.pop(name)
        self.history[name] = tenant.metrics
        self._deferred_hv.pop(name, None)
        self.pool.release(name)
        self.sync.deconfigure(name)

    def request_resize(
        self, name: str, n_cores: int, *, at: float = 0.0,
        mode: SwitchMode = SwitchMode.LAYER_LEVEL,
    ) -> None:
        self.tenants[name].pending.append(ReconfigRequest(at, n_cores, mode))
        self.tenants[name].pending.sort(key=lambda r: r.t_request)
        self.ctx.request_switch(name, mode)

    def submit_request(self, name: str, record: RequestRecord) -> None:
        """Queue one open-loop request; the tenant stops closed-loop
        re-issuing the moment its first request arrives."""
        tenant = self.tenants[name]
        tenant.open_loop = True
        tenant.metrics.arrivals += 1
        tenant.inbox.append(record)

    def metrics(self) -> Dict[str, TenantMetrics]:
        out = dict(self.history)
        out.update({n: t.metrics for n, t in self.tenants.items()})
        return out

    @staticmethod
    def _set_schedule(tenant: _Tenant, schedule: Schedule) -> None:
        tenant.schedule = schedule
        tenant._layer_cache.clear()

    # -- hypervisor executor protocol ------------------------------------------
    def begin(self, horizon: float) -> None:
        self._horizon = horizon

    def exec_admit(self, spec: TenantSpec, n_cores: int, at: float) -> None:
        self.admit(spec.name, spec.artifact, n_cores, at=at)
        if spec.open_loop:
            self.tenants[spec.name].open_loop = True

    def _drop_deferred(self, tenant: _Tenant) -> None:
        stale = self._deferred_hv.pop(tenant.name, None)
        if stale is not None and stale in tenant.pending:
            tenant.pending.remove(stale)

    def exec_resize(self, name: str, n_cores: int, at: float,
                    mode: SwitchMode = SwitchMode.LAYER_LEVEL) -> None:
        """Apply a hypervisor reallocation decision.  ``advance`` has already
        brought the tenant to a layer boundary at clock >= ``at``, so a
        layer-level switch applies synchronously (context = layer index,
        §4.2.1).  Under task-level mode only *grows* wait for the task
        boundary (parked as a pending request, superseding any earlier
        deferred decision); **shrinks always preempt at the layer boundary**
        — a deferred shrink would leave the pool over-committed against the
        admissions and grows the same policy decision granted, which is the
        bounded-latency argument for the layer-level switch in §4.2.1."""
        tenant = self.tenants[name]
        lease = self.pool.lease_of(name)
        if lease is not None and lease.n_cores == n_cores:
            self._drop_deferred(tenant)  # target already met: decision stale
            return
        if lease is not None and n_cores < lease.n_cores:
            mode = SwitchMode.LAYER_LEVEL
        self.ctx.request_switch(name, mode)
        n_layers = len(tenant.artifact.workload)
        ctx = self.ctx.boundary(name, tenant.layer_idx, n_layers, tenant.inference_id)
        if ctx is None and mode is SwitchMode.TASK_LEVEL:
            self._drop_deferred(tenant)
            req = ReconfigRequest(at, n_cores, mode)
            self._deferred_hv[name] = req
            tenant.pending.append(req)
            tenant.pending.sort(key=lambda r: r.t_request)
            return
        self._drop_deferred(tenant)
        lease = self.pool.resize(name, n_cores)
        self.sync.configure(name, set(lease.cores))
        schedule = tenant.dyn.compile(lease.cores)
        cost = tenant.dyn.context_switch_cost(schedule, self.hw)
        tenant.clock = max(tenant.clock, at) + cost["t_context"]
        self._set_schedule(tenant, schedule)
        tenant.probe_speeds = None
        tenant.metrics.ctx_switches += 1
        tenant.metrics.ctx_overhead += cost["t_context"]
        if ctx is not None:
            tenant.layer_idx = ctx.layer_idx  # resume from recorded context

    def exec_remove(self, name: str, at: float) -> None:
        self.remove(name)

    def exec_request(self, name: str, record: RequestRecord, at: float) -> None:
        self.submit_request(name, record)

    def exec_evict(self, name: str, at: float) -> None:
        """Preemptive eviction: unlike a voluntary departure the tenant pays
        one context switch (its state must be drained off the cores, Eq. 7)
        before the lease is revoked; the charge lands in its metrics — which
        survive in ``history`` — and its queued/in-flight requests park until
        re-admission (an aborted in-flight request restarts from layer 0)."""
        tenant = self.tenants[name]
        cost = tenant.dyn.context_switch_cost(tenant.schedule, self.hw)
        tenant.clock = max(tenant.clock, at) + cost["t_context"]
        tenant.metrics.ctx_switches += 1
        tenant.metrics.ctx_overhead += cost["t_context"]
        tenant.metrics.evictions += 1
        if tenant.current_req is not None:
            tenant.current_req.t_start = None
            tenant.inbox.insert(0, tenant.current_req)
            tenant.current_req = None
        tenant.layer_idx = 0
        if tenant.inbox:
            self._parked_requests[name] = tenant.inbox
        self.remove(name)

    def exec_fault(self, fault, at: float) -> None:
        """A :class:`~repro.core.faults.FaultSpec` fires.  ``CORE_SLOW``
        degrades the core by its factor — *visible to straggler probes*
        (the detection path: a probed tenant rebalances its remaining
        layers off the sick core).  ``CORE_DEATH`` needs no engine-side
        state: the hypervisor displaces the owner through ``exec_evict`` in
        the same event, and a failed free core is simply unplaceable."""
        from .faults import FaultKind
        if fault.kind is FaultKind.CORE_SLOW and fault.core is not None:
            self.core_slowdown[fault.core] = max(
                self.core_slowdown.get(fault.core, 1.0), fault.factor)

    def exec_recover(self, fault, at: float) -> None:
        """The fault's repair lands: clear the slowdown so the next probe
        sees a healthy core again (probe_speeds memo invalidates naturally
        — speeds change, so the weighted schedule recompiles)."""
        from .faults import FaultKind
        if fault.kind is FaultKind.CORE_SLOW and fault.core is not None:
            self.core_slowdown.pop(fault.core, None)

    def estimate_latency(self, spec: TenantSpec, n_cores: int) -> float:
        """Estimated single-inference latency of ``spec`` on ``n_cores``
        cores — the ``latency_slo`` policy's demand model.  Crosstalk-free
        and placement-independent (schedule latency depends only on the core
        count), memoized per (artifact, count); repeated policy decisions
        are dictionary lookups."""
        if n_cores < 1:
            return float("inf")
        artifact = spec.artifact
        key = (id(artifact), n_cores)
        cached = self._lat_cache.get(key)
        if cached is None:
            dyn = self._est_dyn.get(id(artifact))
            if dyn is None:
                resident = self.tenants.get(spec.name)
                dyn = (resident.dyn if resident is not None
                       and resident.artifact is artifact
                       else DynamicCompiler(artifact))
                self._est_dyn[id(artifact)] = dyn
            cached = dyn.compile(list(range(n_cores))).estimated_latency(self.hw)
            self._lat_cache[key] = cached
        return cached

    def probe(self, at: float) -> int:
        """Pool-wide straggler probe (hypervisor-scheduled): re-balance any
        tenant whose lease contains a core slower than ``straggler_threshold``
        x the median, via the weighted dynamic compiler."""
        rebalanced = 0
        for tenant in self.tenants.values():
            metric = {c: self.core_slowdown.get(c, 1.0)
                      for c in tenant.schedule.core_ids}
            if self._rebalance_if_straggling(tenant, metric):
                rebalanced += 1
        return rebalanced

    # -- straggler detection / weighted rebalance (shared by the inline
    # per-layer path and the hypervisor probe path) ---------------------------
    def _rebalance_if_straggling(self, tenant: _Tenant,
                                 metric: Dict[int, float]) -> bool:
        """``metric`` is any per-core load signal (per-layer times inline,
        slowdown factors for probes); when one core exceeds threshold x
        median, recompile with weights so it receives proportionally less
        work.  Skips when already balanced for the current speeds."""
        if len(metric) < 2:
            return False
        values = sorted(metric.values())
        median = values[len(values) // 2]
        if median <= 0 or max(values) <= self.straggler_threshold * median:
            return False
        speeds = [1.0 / self.core_slowdown.get(c, 1.0)
                  for c in tenant.schedule.core_ids]
        if tenant.probe_speeds == speeds:
            return False
        self._set_schedule(
            tenant, tenant.dyn.compile(tenant.schedule.core_ids, core_speeds=speeds)
        )
        tenant.probe_speeds = speeds
        tenant.metrics.rebalances += 1
        return True

    # -- crosstalk -------------------------------------------------------------
    def _tenant_hw(self, tenant: _Tenant) -> HardwareModel:
        """Effective per-core hardware for this tenant: tiny bandwidth loss on
        DDR groups shared with other (active) tenants — §4.2.2 arbiter model."""
        if self.ddr_crosstalk <= 0:
            return self.hw
        lease = self.pool.lease_of(tenant.name)
        if lease is None:
            return self.hw
        g = self.pool.cores_per_ddr
        shared = 0
        for c in lease.cores:
            group = range((c // g) * g, min((c // g + 1) * g, self.pool.n_cores))
            if any(self.pool._owner[x] not in (None, tenant.name) for x in group):
                shared += 1
        frac = shared / max(len(lease.cores), 1)
        return self.hw.with_bandwidth(1.0 - self.ddr_crosstalk * frac)

    # -- one layer step ----------------------------------------------------------
    def _layer_time(self, tenant: _Tenant) -> Tuple[float, Dict[int, float]]:
        hw = self._tenant_hw(tenant)
        li = tenant.layer_idx
        key = (hw.name, li)
        base = tenant._layer_cache.get(key)
        if base is None:
            base = {}
            for local, phys in enumerate(tenant.schedule.core_ids):
                prog = tenant.schedule.per_core_layers[local][li]
                if len(prog) == 0:
                    continue
                base[phys] = simulate(prog, hw)
            tenant._layer_cache[key] = base
        per_core = {
            phys: dt * self.core_slowdown.get(phys, 1.0) for phys, dt in base.items()
        }
        t_layer = (max(per_core.values()) if per_core else 0.0) + hw.sync_latency
        return t_layer, per_core

    def _maybe_mitigate(self, tenant: _Tenant, per_core: Dict[int, float]) -> None:
        if self.mitigate_stragglers:
            self._rebalance_if_straggling(tenant, per_core)

    def _apply_reconfig(self, tenant: _Tenant, req: ReconfigRequest) -> None:
        n_layers = len(tenant.artifact.workload)
        ctx = self.ctx.boundary(
            tenant.name, tenant.layer_idx, n_layers, tenant.inference_id
        )
        if ctx is None and req.mode is SwitchMode.TASK_LEVEL:
            return  # not at task end yet; retry at the next boundary
        lease = self.pool.resize(tenant.name, req.n_cores)
        self.sync.configure(tenant.name, set(lease.cores))
        schedule = tenant.dyn.compile(lease.cores)
        cost = tenant.dyn.context_switch_cost(schedule, self.hw)
        tenant.clock += cost["t_context"]
        self._set_schedule(tenant, schedule)
        tenant.probe_speeds = None
        tenant.metrics.ctx_switches += 1
        tenant.metrics.ctx_overhead += cost["t_context"]
        tenant.pending.remove(req)
        if self._deferred_hv.get(tenant.name) is req:
            del self._deferred_hv[tenant.name]
        if ctx is not None:
            tenant.layer_idx = ctx.layer_idx  # resume from recorded context

    # -- simulation ----------------------------------------------------------------
    def advance(self, until: float) -> None:
        """Advance every tenant's clock to global time ``until`` (layer by
        layer; completions are recorded against the run horizon set by
        :meth:`begin`).  The hypervisor calls this between events."""
        for tenant in list(self.tenants.values()):
            self._advance_tenant(tenant, until)

    def _start_next_request(self, tenant: _Tenant, until: float) -> bool:
        """Dequeue the open-loop tenant's next request, skipping the idle gap
        (its clock jumps to the arrival — idle cores don't do work).  Returns
        False when the inbox is empty: the tenant idles, but still honours
        any due reconfiguration at this (trivially task-level) boundary."""
        while tenant.inbox:
            req = tenant.inbox.pop(0)
            start = max(tenant.clock, req.t_arrival)
            if req.deadline is not None and start > req.deadline:
                # drop policy: the deadline already passed before the
                # request could even start — serving it would burn core
                # time on an answer nobody is waiting for.  The record
                # keeps t_complete=None (counts against attainment) and is
                # stamped dropped so owners can tell shed from starved.
                req.dropped = True
                tenant.metrics.dropped += 1
                tenant.metrics.requests.append(req)
                continue
            req.t_start = start
            tenant.clock = req.t_start
            tenant.current_req = req
            # a request is a whole inference: discard any half-run
            # closed-loop layers left from before the tenant went open-loop
            tenant.layer_idx = 0
            return True
        for req in list(tenant.pending):
            if req.t_request <= until:
                tenant.clock = max(tenant.clock, req.t_request)
                self._apply_reconfig(tenant, req)
                break
        return False

    def _finish_request(self, tenant: _Tenant) -> None:
        req = tenant.current_req
        if req is None:
            return
        req.t_complete = tenant.clock
        tenant.current_req = None
        # same horizon guard as `completions`: a request whose last layer
        # overshoots the run horizon is stamped (the record is ground
        # truth for its owner) but stays out of this run's metrics and
        # COMPLETION events — throughput and attainment count the same set
        if tenant.clock <= self._horizon:
            tenant.metrics.requests.append(req)
            if self.completion_sink is not None:
                self.completion_sink(req)

    def _advance_tenant(self, tenant: _Tenant, until: float) -> None:
        n_layers = len(tenant.artifact.workload)
        while tenant.clock < until:
            if (
                self._max_inferences is not None
                and len(tenant.metrics.completions) >= self._max_inferences
            ):
                break
            if tenant.open_loop and tenant.current_req is None:
                if not self._start_next_request(tenant, until):
                    break
            t_layer, per_core = self._layer_time(tenant)
            tenant.clock += t_layer
            tenant.layer_idx += 1
            if tenant.layer_idx >= n_layers:
                tenant.inference_id += 1
                if tenant.clock <= self._horizon:
                    tenant.metrics.completions.append(tenant.clock)
                self._finish_request(tenant)
            self._maybe_mitigate(tenant, per_core)
            # layer boundary: honour any due reconfiguration request
            # (while layer_idx may still equal n_layers => task boundary)
            for req in list(tenant.pending):
                if req.t_request <= tenant.clock:
                    self._apply_reconfig(tenant, req)
                    break
            if tenant.layer_idx >= n_layers:
                tenant.layer_idx = 0

    def run(
        self, horizon: float, *, max_inferences: Optional[int] = None,
        hypervisor: Optional[Hypervisor] = None,
    ) -> Dict[str, TenantMetrics]:
        """Advance every tenant's clock to ``horizon`` (seconds).

        Runs as the degenerate case of the hypervisor's global event loop: a
        ``no_realloc`` policy over an empty event queue reproduces the seed
        engine's independent per-tenant clocks.  Pass a ``hypervisor`` (built
        with ``executor=self``) to honour its queued arrival/departure/
        reconfiguration events instead.
        """
        self._max_inferences = max_inferences
        hv = hypervisor if hypervisor is not None else Hypervisor(
            self.pool, policy="no_realloc", executor=self,
        )
        hv.run(horizon)
        return self.metrics()

    # -- convenience -----------------------------------------------------------------
    def single_inference_latency(self, name: str) -> float:
        tenant = self.tenants[name]
        total = 0.0
        n_layers = len(tenant.artifact.workload)
        hw = self._tenant_hw(tenant)
        for li in range(n_layers):
            t_layer = 0.0
            for local, _ in enumerate(tenant.schedule.core_ids):
                prog = tenant.schedule.per_core_layers[local][li]
                if len(prog):
                    t_layer = max(t_layer, simulate(prog, hw))
            total += t_layer + hw.sync_latency
        return total
