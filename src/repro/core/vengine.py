"""Virtualized multi-tenant execution engine (discrete-event).

Ties the whole paper together: the HRP leases cores to tenants, the two-stage
compiler produces per-core schedules, the two-level IDM controllers manage
context switches and layer barriers, and the latency simulator supplies
per-layer core times.  Because leases are disjoint and every core owns its
off-chip port, tenants' timelines are independent — the engine simulates each
tenant's clock separately, which *is* the isolation property (a small optional
DDR-group crosstalk factor models the arbiter of §4.2.2 when tenants share a
bank, bounded well under the paper's 1% deviation).

Supports:
  * closed-loop inference (each tenant re-issues back-to-back requests),
  * hypervisor reconfiguration at a global time (task- or layer-level switch,
    with measured dynamic-recompile + transfer cost added to the timeline),
  * straggler injection (per-core slowdown) and mitigation (weighted
    re-allocation of the remaining layers via the dynamic compiler).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .dispatch import ContextSwitchController, MultiCoreSyncController, SwitchMode
from .dynamic_compiler import DynamicCompiler, Schedule
from .hwmodel import HardwareModel
from .hrp import ResourcePool
from .latency_sim import simulate
from .static_compiler import StaticArtifact


@dataclasses.dataclass
class ReconfigRequest:
    t_request: float
    n_cores: int
    mode: SwitchMode = SwitchMode.LAYER_LEVEL


@dataclasses.dataclass
class TenantMetrics:
    completions: List[float] = dataclasses.field(default_factory=list)
    ctx_switches: int = 0
    ctx_overhead: float = 0.0
    rebalances: int = 0

    def throughput(self, horizon: float) -> float:
        return len(self.completions) / horizon if horizon > 0 else 0.0


@dataclasses.dataclass
class _Tenant:
    name: str
    artifact: StaticArtifact
    dyn: DynamicCompiler
    schedule: Schedule
    clock: float = 0.0
    layer_idx: int = 0
    inference_id: int = 0
    pending: List[ReconfigRequest] = dataclasses.field(default_factory=list)
    metrics: TenantMetrics = dataclasses.field(default_factory=TenantMetrics)
    # simulate() results per (schedule identity, hw name, layer) — schedules
    # and their chains are immutable, so per-layer times are too.
    _layer_cache: Dict[Tuple[int, str, int], Dict[int, float]] = dataclasses.field(
        default_factory=dict
    )


class VirtualEngine:
    def __init__(
        self,
        pool: ResourcePool,
        hw_unit: HardwareModel,
        *,
        ddr_crosstalk: float = 0.004,
        straggler_threshold: float = 1.5,
        mitigate_stragglers: bool = False,
    ) -> None:
        self.pool = pool
        self.hw = hw_unit
        self.ddr_crosstalk = ddr_crosstalk
        self.straggler_threshold = straggler_threshold
        self.mitigate_stragglers = mitigate_stragglers
        self.sync = MultiCoreSyncController()
        self.ctx = ContextSwitchController()
        self.tenants: Dict[str, _Tenant] = {}
        self.core_slowdown: Dict[int, float] = {}

    # -- admission ------------------------------------------------------------
    def admit(self, name: str, artifact: StaticArtifact, n_cores: int) -> None:
        lease = self.pool.alloc(name, n_cores)
        dyn = DynamicCompiler(artifact)
        schedule = dyn.compile(lease.cores)
        self.sync.configure(name, set(lease.cores))
        self.tenants[name] = _Tenant(name, artifact, dyn, schedule)

    def remove(self, name: str) -> None:
        self.pool.release(name)
        self.sync.deconfigure(name)
        del self.tenants[name]

    def request_resize(
        self, name: str, n_cores: int, *, at: float = 0.0,
        mode: SwitchMode = SwitchMode.LAYER_LEVEL,
    ) -> None:
        self.tenants[name].pending.append(ReconfigRequest(at, n_cores, mode))
        self.tenants[name].pending.sort(key=lambda r: r.t_request)
        self.ctx.request_switch(name, mode)

    # -- crosstalk -------------------------------------------------------------
    def _tenant_hw(self, tenant: _Tenant) -> HardwareModel:
        """Effective per-core hardware for this tenant: tiny bandwidth loss on
        DDR groups shared with other (active) tenants — §4.2.2 arbiter model."""
        if self.ddr_crosstalk <= 0:
            return self.hw
        lease = self.pool.lease_of(tenant.name)
        if lease is None:
            return self.hw
        g = self.pool.cores_per_ddr
        shared = 0
        for c in lease.cores:
            group = range((c // g) * g, min((c // g + 1) * g, self.pool.n_cores))
            if any(self.pool._owner[x] not in (None, tenant.name) for x in group):
                shared += 1
        frac = shared / max(len(lease.cores), 1)
        return self.hw.with_bandwidth(1.0 - self.ddr_crosstalk * frac)

    # -- one layer step ----------------------------------------------------------
    def _layer_time(self, tenant: _Tenant) -> Tuple[float, Dict[int, float]]:
        hw = self._tenant_hw(tenant)
        li = tenant.layer_idx
        key = (id(tenant.schedule), hw.name, li)
        base = tenant._layer_cache.get(key)
        if base is None:
            base = {}
            for local, phys in enumerate(tenant.schedule.core_ids):
                prog = tenant.schedule.per_core_layers[local][li]
                if len(prog) == 0:
                    continue
                base[phys] = simulate(prog, hw)
            tenant._layer_cache[key] = base
        per_core = {
            phys: dt * self.core_slowdown.get(phys, 1.0) for phys, dt in base.items()
        }
        t_layer = (max(per_core.values()) if per_core else 0.0) + hw.sync_latency
        return t_layer, per_core

    def _maybe_mitigate(self, tenant: _Tenant, per_core: Dict[int, float]) -> None:
        if not self.mitigate_stragglers or len(per_core) < 2:
            return
        times = sorted(per_core.values())
        median = times[len(times) // 2]
        slow = [c for c, t in per_core.items() if t > self.straggler_threshold * median]
        if not slow:
            return
        speeds = [1.0 / self.core_slowdown.get(c, 1.0) for c in tenant.schedule.core_ids]
        tenant.schedule = tenant.dyn.compile(
            tenant.schedule.core_ids, core_speeds=speeds
        )
        tenant.metrics.rebalances += 1

    def _apply_reconfig(self, tenant: _Tenant, req: ReconfigRequest) -> None:
        n_layers = len(tenant.artifact.workload)
        ctx = self.ctx.boundary(
            tenant.name, tenant.layer_idx, n_layers, tenant.inference_id
        )
        if ctx is None and req.mode is SwitchMode.TASK_LEVEL:
            return  # not at task end yet; retry at the next boundary
        lease = self.pool.resize(tenant.name, req.n_cores)
        self.sync.configure(tenant.name, set(lease.cores))
        schedule = tenant.dyn.compile(lease.cores)
        cost = tenant.dyn.context_switch_cost(schedule, self.hw)
        tenant.clock += cost["t_context"]
        tenant.schedule = schedule
        tenant.metrics.ctx_switches += 1
        tenant.metrics.ctx_overhead += cost["t_context"]
        tenant.pending.remove(req)
        if ctx is not None:
            tenant.layer_idx = ctx.layer_idx  # resume from recorded context

    # -- main loop ----------------------------------------------------------------
    def run(self, horizon: float, *, max_inferences: Optional[int] = None) -> Dict[str, TenantMetrics]:
        """Advance every tenant's clock to ``horizon`` (seconds)."""
        for tenant in self.tenants.values():
            n_layers = len(tenant.artifact.workload)
            while tenant.clock < horizon:
                if max_inferences is not None and len(tenant.metrics.completions) >= max_inferences:
                    break
                t_layer, per_core = self._layer_time(tenant)
                tenant.clock += t_layer
                tenant.layer_idx += 1
                if tenant.layer_idx >= n_layers:
                    tenant.inference_id += 1
                    if tenant.clock <= horizon:
                        tenant.metrics.completions.append(tenant.clock)
                self._maybe_mitigate(tenant, per_core)
                # layer boundary: honour any due reconfiguration request
                # (while layer_idx may still equal n_layers => task boundary)
                for req in list(tenant.pending):
                    if req.t_request <= tenant.clock:
                        self._apply_reconfig(tenant, req)
                        break
                if tenant.layer_idx >= n_layers:
                    tenant.layer_idx = 0
        return {n: t.metrics for n, t in self.tenants.items()}

    # -- convenience -----------------------------------------------------------------
    def single_inference_latency(self, name: str) -> float:
        tenant = self.tenants[name]
        total = 0.0
        n_layers = len(tenant.artifact.workload)
        hw = self._tenant_hw(tenant)
        for li in range(n_layers):
            t_layer = 0.0
            for local, _ in enumerate(tenant.schedule.core_ids):
                prog = tenant.schedule.per_core_layers[local][li]
                if len(prog):
                    t_layer = max(t_layer, simulate(prog, hw))
            total += t_layer + hw.sync_latency
        return total
