"""Latency simulator — paper §5.2.1 (Eqs. 2-3) generalized.

The simulator executes an instruction :class:`~repro.core.isa.Program` on one
core of a :class:`~repro.core.hwmodel.HardwareModel`.  Per the paper:

* *Conv* latency follows Eq. 2 — work divided by the core's parallelism, with
  ceil-quantization of each work dimension to the (PP, ICP, OCP) compute tile
  (that quantization is what makes a 16x512 pool beat a 1x8192 core).
* *Load/Save* latency follows Eq. 3 — bytes over effective bandwidth.
* Instructions are issued **in order per functional unit** (LOAD, SAVE, CONV,
  MISC run concurrently, like the four modules of the accelerator), and an
  instruction starts only when its dependencies have retired.  This is the
  directed-acyclic-graph traversal of §5.2.1, implemented as list scheduling,
  and it is what gives load/compute overlap its effect on the estimate.

The same simulator prices IFPs for the latency LUT (static compilation), whole
per-core schedules (dynamic compilation), and the multi-core layer-barrier
execution used by the virtualized engine.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

from .hwmodel import HardwareModel
from .isa import Instr, Program, Unit


def instr_duration(ins: Instr, hw: HardwareModel) -> float:
    if ins.unit is Unit.LOAD or ins.unit is Unit.SAVE:
        return hw.memory_time(ins.nbytes)
    if ins.unit is Unit.CONV or ins.unit is Unit.MISC:
        return hw.compute_time(ins.flops, ins.shape)
    # CTRL: CONVINIT register writes / SYSTEM bookkeeping
    return hw.instr_overhead


def simulate(program, hw: HardwareModel, *, start: float = 0.0) -> float:
    """Return the makespan (seconds) of ``program`` on one core.

    List scheduling: each functional unit is a serial queue; instruction start
    time = max(unit available, all deps retired).  Accepts a single
    :class:`~repro.core.isa.Program` or a :class:`~repro.core.isa.Chain`
    (dependency ids are local to each chained program).
    """
    from .isa import Chain

    chain = program.programs if isinstance(program, Chain) else [program]
    unit_free: Dict[Unit, float] = {u: start for u in Unit}
    makespan = start
    for prog in chain:
        end_at: List[float] = [start] * len(prog)
        for ins in prog:
            unit = ins.unit
            t0 = unit_free[unit]
            for d in ins.deps:
                t0 = max(t0, end_at[d])
            t1 = t0 + instr_duration(ins, hw)
            unit_free[unit] = t1
            end_at[ins.iid] = t1
            if t1 > makespan:
                makespan = t1
    return makespan - start


def simulate_with_times(program: Program, hw: HardwareModel) -> List[float]:
    """Like :func:`simulate` but returns per-instruction retire times."""
    unit_free: Dict[Unit, float] = {u: 0.0 for u in Unit}
    end_at: List[float] = [0.0] * len(program)
    for ins in program:
        t0 = unit_free[ins.unit]
        for d in ins.deps:
            t0 = max(t0, end_at[d])
        t1 = t0 + instr_duration(ins, hw)
        unit_free[ins.unit] = t1
        end_at[ins.iid] = t1
    return end_at


def simulate_layer_barrier(
    per_core_layer_programs: Sequence[Sequence[Program]],
    hw: HardwareModel,
    *,
    core_slowdown: Dict[int, float] | None = None,
) -> float:
    """Multi-core, layer-synchronized execution time (paper §5.2.2).

    ``per_core_layer_programs[k][l]`` is core ``k``'s instruction program for
    layer ``l`` (possibly empty).  After each layer every participating core
    raises ``sync_local``; the first-level IDM's sync controller releases
    ``sync_global`` once all have, adding ``hw.sync_latency`` per layer.

    ``core_slowdown`` maps core index -> multiplicative slowdown (straggler
    injection for the mitigation benchmarks).
    """
    if not per_core_layer_programs:
        return 0.0
    n_layers = max(len(c) for c in per_core_layer_programs)
    t = 0.0
    slow = core_slowdown or {}
    for l in range(n_layers):
        t_layer = 0.0
        for k, core_progs in enumerate(per_core_layer_programs):
            if l < len(core_progs) and len(core_progs[l]) > 0:
                dt = simulate(core_progs[l], hw)
                t_layer = max(t_layer, dt * slow.get(k, 1.0))
        t += t_layer + hw.sync_latency
    return t


def roofline_terms(program: Program, hw: HardwareModel) -> dict:
    """Aggregate compute/memory terms of a program on one core (no DAG)."""
    flops = program.total_flops
    nbytes = program.total_bytes
    return {
        "flops": flops,
        "bytes": nbytes,
        "t_compute": flops / hw.flops_per_sec,
        "t_memory": nbytes / (hw.mem_bw * hw.bw_eff),
        "intensity": flops / max(nbytes, 1.0),
    }
