"""Global event model for the hypervisor's discrete-event loop.

The paper's hypervisor (§4.1) multiplexes one physical accelerator among many
tenants whose tasks arrive and leave at millisecond granularity.  We model
that as a single time-ordered queue of :class:`Event` records — tenant
arrivals, departures, per-request arrivals and completions, explicit
reconfiguration signals, and straggler probes — consumed by
:class:`repro.core.hypervisor.Hypervisor`.

Determinism rules (they make event-driven runs reproducible and testable):

* events pop in non-decreasing ``time`` order;
* at equal time, kinds pop in a **documented fixed priority** (the
  ``_KIND_RANK`` table): failures first (every other same-instant event
  must already see the shrunk pool — a chaos replay is only byte-stable if
  a failure and a completion at the same timestamp always order the same
  way), then departures (a simultaneous arrival sees the cores a departing
  tenant frees), completions, explicit reconfiguration signals, recoveries
  (repaired cores become placeable before same-instant arrivals ask),
  tenant arrivals, request arrivals after the tenant arrival that may
  carry them, probes last;
* remaining ties break by insertion order (``seq``), never by dict/hash order.

**Open-loop traffic.**  The seed engine re-issued each tenant's next
inference the moment the previous one finished (closed loop) — fine for
throughput figures, useless for latency SLOs, where *offered load* must be
independent of how fast the system drains it.  :class:`PoissonTraffic` and
:class:`TraceTraffic` generate seeded, reproducible arrival-time streams;
:func:`emit_requests` turns one into ``REQUEST`` events carrying
:class:`RequestRecord` instances whose ``t_start``/``t_complete`` fields the
executor stamps as the request moves through the system.  Same seed →
byte-identical event stream.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import random
from typing import Any, Dict, Iterable, List, Optional, Sequence


class EventKind(enum.Enum):
    """What happened at ``Event.time`` (ordered by handling priority)."""

    FAILURE = "failure"          # a fault fires (core death/slowdown/corruption)
    DEPARTURE = "departure"      # tenant leaves; its lease is released
    COMPLETION = "completion"    # a tenant request finished (accounting hook)
    RECONFIG = "reconfig"        # explicit resize signal for one tenant
    RECOVERY = "recovery"        # a fault repairs / a displaced tenant retries
    ARRIVAL = "arrival"          # tenant asks for admission
    REQUEST = "request"          # one inference request arrives for a tenant
    PROBE = "probe"              # pool-wide straggler probe

    @property
    def rank(self) -> int:
        return _KIND_RANK[self]


#: Same-timestamp handling priority (see the module docstring).  FAILURE
#: outranks everything — a same-instant completion/reconfig/arrival must see
#: the post-fault pool; RECOVERY sits after bookkeeping kinds but before
#: ARRIVAL so repaired cores are placeable for simultaneous admissions.
#: Remaining ties break by ``Event.seq`` (insertion order).
_KIND_RANK = {
    EventKind.FAILURE: 0,
    EventKind.DEPARTURE: 1,
    EventKind.COMPLETION: 2,
    EventKind.RECONFIG: 3,
    EventKind.RECOVERY: 4,
    EventKind.ARRIVAL: 5,
    EventKind.REQUEST: 6,
    EventKind.PROBE: 7,
}


@dataclasses.dataclass
class Event:
    """One point on the global timeline.

    ``payload`` carries kind-specific data: the :class:`TenantSpec` for an
    arrival, the target core count for a reconfiguration signal, free-form
    accounting fields for completions.
    """

    time: float
    kind: EventKind
    tenant: Optional[str] = None
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)
    seq: int = -1   # assigned by the queue; insertion-order tie-break

    def __repr__(self) -> str:  # compact, for traces in test failures
        who = f" {self.tenant}" if self.tenant else ""
        return f"<{self.kind.value}{who} @ {self.time:g}>"


class EventQueue:
    """Min-heap of events ordered by (time, kind rank, insertion seq)."""

    def __init__(self) -> None:
        self._heap: List[tuple] = []
        self._count = itertools.count()

    def push(self, event: Event) -> Event:
        event.seq = next(self._count)
        heapq.heappush(self._heap, (event.time, event.kind.rank, event.seq, event))
        return event

    def schedule(self, kind: EventKind, time: float, *, tenant: Optional[str] = None,
                 **payload: Any) -> Event:
        return self.push(Event(time=time, kind=kind, tenant=tenant, payload=payload))

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[-1]

    def peek(self) -> Optional[Event]:
        return self._heap[0][-1] if self._heap else None

    def next_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


# ---------------------------------------------------------------------------
# open-loop request traffic
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RequestRecord:
    """One inference request's lifecycle, stamped as it moves through the
    system: arrival (offered), start (dequeued onto cores), completion.

    The record object is shared between the traffic source, the event
    payload, and the executor — whoever created the stream can compute SLO
    attainment afterwards without collecting anything from the engine.  A
    request that was never served keeps ``t_complete is None`` and counts
    against attainment (the open-loop contract: offered load doesn't shrink
    because the system is slow).

    ``deadline`` is a hard useless-after time (absolute, same clock as
    ``t_arrival``): an executor that would only *start* the request after
    its deadline sheds it instead of serving it hopelessly late —
    ``dropped`` marks that outcome (``t_complete`` stays None, so the drop
    still counts against attainment)."""

    tenant: str
    rid: int
    t_arrival: float
    slo: Optional[float] = None        # per-request latency target (seconds)
    deadline: Optional[float] = None   # absolute shed-after time
    t_start: Optional[float] = None
    t_complete: Optional[float] = None
    dropped: bool = False

    @property
    def latency(self) -> Optional[float]:
        if self.t_complete is None:
            return None
        return self.t_complete - self.t_arrival

    @property
    def slo_met(self) -> bool:
        """Served within the target.  Unserved or target-less requests are
        *not* met (a request without an SLO never counts as attained; filter
        them out of the denominator if that is what you want)."""
        lat = self.latency
        return lat is not None and self.slo is not None and lat <= self.slo


class PoissonTraffic:
    """Seeded open-loop Poisson arrival process (exponential inter-arrivals).

    Determinism contract: ``PoissonTraffic(rate, seed=s).times(h)`` returns
    the identical list on every call and every platform — the stream is
    drawn from a private ``random.Random(seed)`` re-seeded per call."""

    def __init__(self, rate: float, *, seed: int = 0, start: float = 0.0) -> None:
        if rate <= 0:
            raise ValueError(f"Poisson rate must be positive, got {rate}")
        self.rate = rate
        self.seed = seed
        self.start = start

    def times(self, horizon: float) -> List[float]:
        rng = random.Random(self.seed)
        out: List[float] = []
        t = self.start
        while True:
            t += rng.expovariate(self.rate)
            if t > horizon:
                return out
            out.append(t)


class TraceTraffic:
    """Replay a fixed arrival-time trace (already-sorted or not)."""

    def __init__(self, times: Sequence[float]) -> None:
        self._times = sorted(float(t) for t in times)

    def times(self, horizon: float) -> List[float]:
        return [t for t in self._times if t <= horizon]


def emit_requests(
    queue: EventQueue,
    tenant: str,
    traffic: Any,
    horizon: float,
    *,
    slo: Optional[float] = None,
    start_rid: int = 0,
    deadline_after: Optional[float] = None,
) -> List[RequestRecord]:
    """Schedule one ``REQUEST`` event per arrival of ``traffic`` (anything
    with a ``times(horizon)`` method, or a plain iterable of times) and
    return the shared :class:`RequestRecord` list for later SLO accounting.
    ``deadline_after`` stamps each record's ``deadline`` at arrival +
    that many seconds (the drop-policy knob)."""
    times: Iterable[float]
    if hasattr(traffic, "times"):
        times = traffic.times(horizon)
    else:
        times = [t for t in sorted(traffic) if t <= horizon]
    records = []
    for i, t in enumerate(times):
        rec = RequestRecord(
            tenant=tenant, rid=start_rid + i, t_arrival=t, slo=slo,
            deadline=(t + deadline_after if deadline_after is not None
                      else None))
        queue.schedule(EventKind.REQUEST, t, tenant=tenant, record=rec)
        records.append(rec)
    return records
