"""Global event model for the hypervisor's discrete-event loop.

The paper's hypervisor (§4.1) multiplexes one physical accelerator among many
tenants whose tasks arrive and leave at millisecond granularity.  We model
that as a single time-ordered queue of :class:`Event` records — tenant
arrivals, departures, request completions, explicit reconfiguration signals,
and straggler probes — consumed by :class:`repro.core.hypervisor.Hypervisor`.

Determinism rules (they make event-driven runs reproducible and testable):

* events pop in non-decreasing ``time`` order;
* at equal time, departures are handled before arrivals (so a simultaneous
  arrival sees the cores a departing tenant frees), completions and explicit
  reconfiguration signals in between, probes last;
* remaining ties break by insertion order (``seq``), never by dict/hash order.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Any, Dict, List, Optional


class EventKind(enum.Enum):
    """What happened at ``Event.time`` (ordered by handling priority)."""

    DEPARTURE = "departure"      # tenant leaves; its lease is released
    COMPLETION = "completion"    # a tenant request finished (accounting hook)
    RECONFIG = "reconfig"        # explicit resize signal for one tenant
    ARRIVAL = "arrival"          # tenant asks for admission
    PROBE = "probe"              # pool-wide straggler probe

    @property
    def rank(self) -> int:
        return _KIND_RANK[self]


_KIND_RANK = {
    EventKind.DEPARTURE: 0,
    EventKind.COMPLETION: 1,
    EventKind.RECONFIG: 2,
    EventKind.ARRIVAL: 3,
    EventKind.PROBE: 4,
}


@dataclasses.dataclass
class Event:
    """One point on the global timeline.

    ``payload`` carries kind-specific data: the :class:`TenantSpec` for an
    arrival, the target core count for a reconfiguration signal, free-form
    accounting fields for completions.
    """

    time: float
    kind: EventKind
    tenant: Optional[str] = None
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)
    seq: int = -1   # assigned by the queue; insertion-order tie-break

    def __repr__(self) -> str:  # compact, for traces in test failures
        who = f" {self.tenant}" if self.tenant else ""
        return f"<{self.kind.value}{who} @ {self.time:g}>"


class EventQueue:
    """Min-heap of events ordered by (time, kind rank, insertion seq)."""

    def __init__(self) -> None:
        self._heap: List[tuple] = []
        self._count = itertools.count()

    def push(self, event: Event) -> Event:
        event.seq = next(self._count)
        heapq.heappush(self._heap, (event.time, event.kind.rank, event.seq, event))
        return event

    def schedule(self, kind: EventKind, time: float, *, tenant: Optional[str] = None,
                 **payload: Any) -> Event:
        return self.push(Event(time=time, kind=kind, tenant=tenant, payload=payload))

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[-1]

    def peek(self) -> Optional[Event]:
        return self._heap[0][-1] if self._heap else None

    def next_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
