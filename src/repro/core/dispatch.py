"""Two-level Instruction Dispatch Module (IDM) — paper §4.2.1.

Level 1 (task-level scheduler, one per accelerator):
  * routes instruction streams to cores by core index,
  * the **context-switch controller** records per-tenant context on a
    reconfiguration signal from the hypervisor — either *task-level* (wait for
    the running inference to finish) or *layer-level* (record the layer index;
    activations already live off-chip because execution is layer-by-layer, so
    the layer index is the entire context),
  * the **multi-core sync controller** aggregates ``sync_local`` from all
    cores of a tenant into one ``sync_global`` per layer.

Level 2 (module-level scheduler, one per core) is the in-order-per-unit
dependency scoreboard — implemented by the latency simulator's list scheduler
(`repro.core.latency_sim.simulate`), which this module drives.

These classes are behavioural models (discrete-event), exercised by the
virtualized engine and unit-tested directly; on the TPU adaptation the same
logic drives schedule swaps of pre-compiled XLA programs.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Set


class SwitchMode(enum.Enum):
    TASK_LEVEL = "task"    # wait for current inference to finish
    LAYER_LEVEL = "layer"  # preempt at the next layer boundary


@dataclasses.dataclass
class Context:
    """What the context-switch controller records.  Layer-by-layer execution
    writes activations back to DDR/HBM, so this is the *whole* context."""

    tenant: str
    layer_idx: int          # next layer to execute
    inference_id: int       # running inference number (for accounting)


class MultiCoreSyncController:
    """Aggregates sync_local -> sync_global per tenant (hypervisor-configured
    core membership).  Pure state machine; raises on foreign cores."""

    def __init__(self) -> None:
        self._members: Dict[str, Set[int]] = {}
        self._arrived: Dict[str, Set[int]] = {}

    def configure(self, tenant: str, cores: Set[int]) -> None:
        self._members[tenant] = set(cores)
        self._arrived[tenant] = set()

    def deconfigure(self, tenant: str) -> None:
        self._members.pop(tenant, None)
        self._arrived.pop(tenant, None)

    def sync_local(self, tenant: str, core: int) -> bool:
        """Core ``core`` raised sync_local.  Returns True when sync_global
        fires (all member cores arrived), resetting the barrier."""
        if core not in self._members.get(tenant, set()):
            raise KeyError(f"core {core} is not a member of tenant {tenant}")
        arrived = self._arrived[tenant]
        arrived.add(core)
        if arrived == self._members[tenant]:
            arrived.clear()
            return True
        return False


class ContextSwitchController:
    """Records/loads per-tenant context around reconfigurations."""

    def __init__(self) -> None:
        self._saved: Dict[str, Context] = {}
        self._pending: Dict[str, SwitchMode] = {}

    def request_switch(self, tenant: str, mode: SwitchMode) -> None:
        self._pending[tenant] = mode

    def pending_mode(self, tenant: str) -> Optional[SwitchMode]:
        return self._pending.get(tenant)

    def boundary(self, tenant: str, layer_idx: int, n_layers: int, inference_id: int) -> Optional[Context]:
        """Called by the engine at every layer boundary.  If a switch is
        pending and the boundary type matches the mode, capture the context
        and clear the request; otherwise return None."""
        mode = self._pending.get(tenant)
        if mode is None:
            return None
        at_task_end = layer_idx >= n_layers
        if mode is SwitchMode.TASK_LEVEL and not at_task_end:
            return None
        ctx = Context(tenant=tenant, layer_idx=0 if at_task_end else layer_idx,
                      inference_id=inference_id)
        self._saved[tenant] = ctx
        del self._pending[tenant]
        return ctx

    def load(self, tenant: str) -> Optional[Context]:
        return self._saved.pop(tenant, None)


class InstructionRouter:
    """First-level IDM instruction decoder: streams indexed by core id.

    On real hardware this fetches from DDR into the on-chip instruction
    memory and forwards by the core-index field; here it validates that a
    schedule only ever references cores inside the tenant's lease."""

    @staticmethod
    def route(schedule_cores: List[int], lease_cores: Set[int]) -> Dict[int, int]:
        mapping: Dict[int, int] = {}
        for local, phys in enumerate(schedule_cores):
            if phys not in lease_cores:
                raise PermissionError(
                    f"schedule targets core {phys} outside lease {sorted(lease_cores)}"
                )
            mapping[local] = phys
        return mapping
