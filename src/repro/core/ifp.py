"""Tiling-based Instruction Frame Packages (IFPs) — paper §5.2.1.

The static compiler tiles every layer's *output* along one of two dimensions:

* ``Strategy.WIDTH``  — same weights, different output columns (pixels for
  CNNs, tokens for LMs).  Multi-core sharing of a width-tiled layer is the
  data-parallel pattern: weights replicated, activations split (+halo).
* ``Strategy.OC``     — same input pixels, different output channels.  This is
  weight parallelism (tensor-parallel pattern): weights split, input
  replicated.  For depthwise layers OC tiling also splits input channels, so
  nothing is replicated.

Each tile becomes one IFP: an independent instruction sequence
(LOAD weights -> {LOAD input chunk -> CONV -> SAVE} x groups) whose latency on
the basic shareable unit is priced by the latency simulator into a LUT.

Weight/input LOADs carry reuse keys: when the dynamic compiler concatenates
several IFPs of the same layer on one core, a LOAD whose key matches the
previous IFP's resident tensor and whose size fits on-chip memory is dropped
(the on-chip weight buffer of Angel-Eye-class designs).  Without this reuse,
width tiling at few cores would be bandwidth-absurd — with it, the paper's
Table 3 behaviour (width wins at few cores, OC at many) emerges naturally.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Tuple

from .isa import Op, Program
from .workloads import Layer


class Strategy(enum.Enum):
    WIDTH = "W"   # width-only tiling (data-parallel analogue)
    OC = "OC"     # output-channel-only tiling (tensor-parallel analogue)


@dataclasses.dataclass
class IFP:
    """One tiling-based instruction frame package."""

    layer_idx: int
    strategy: Strategy
    tile_idx: int
    n_tiles: int
    program: Program
    # latency on the basic shareable unit, filled by the static compiler:
    latency: float = 0.0            # cold: all loads paid
    latency_cached: float = 0.0     # reusable loads dropped (same-layer chain)
    flops: float = 0.0
    # the program as it runs when the *shared* tensor of its (layer,
    # strategy) is already on-chip (weights for WIDTH, input map for OC);
    # filled by the static compiler so the dynamic compiler concatenates
    # cached artifacts instead of rewriting instructions (~ms path).
    program_cached: Optional[Program] = None

    @property
    def key(self) -> Tuple[int, str, int]:
        return (self.layer_idx, self.strategy.value, self.tile_idx)


def _split(total: int, parts: int) -> List[int]:
    """Split ``total`` into at most ``parts`` near-equal positive chunks."""
    parts = max(1, min(parts, total))
    base, rem = divmod(total, parts)
    return [base + (1 if i < rem else 0) for i in range(parts)]


def make_layer_ifps(
    layer: Layer,
    layer_idx: int,
    strategy: Strategy,
    n_tiles: int,
    *,
    load_groups: int = 4,
) -> List[IFP]:
    """Tile one layer into IFPs under the given strategy.

    Returns fewer than ``n_tiles`` IFPs when the tiling dimension is too
    narrow (e.g. a 7-wide feature map cannot be split 16 ways) — the workload
    imbalance this causes at high core counts is part of what the paper's
    optimized per-layer strategy choice avoids.
    """
    ifps: List[IFP] = []
    if strategy is Strategy.WIDTH:
        chunks = _split(layer.w, n_tiles)
        for t, w_cols in enumerate(chunks):
            prog = _tile_program(
                layer, layer_idx, t,
                w_cols=w_cols, c_out=layer.c_out, c_in=layer.c_in,
                weight_frac=1.0, replicate_input=False,
                # weights are identical across WIDTH tiles -> shared/reusable
                weight_key=(layer_idx, "W", "shared"), weight_shared=True,
                input_key=None,  # disjoint input slices (halo aside): no reuse
                load_groups=load_groups,
            )
            ifps.append(IFP(layer_idx, strategy, t, len(chunks), prog))
    else:  # OC
        chunks = _split(layer.c_out, n_tiles)
        depthwise = layer.is_depthwise
        for t, co in enumerate(chunks):
            frac = co / layer.c_out
            c_in_eff = max(1, round(layer.c_in * frac)) if depthwise else layer.c_in
            prog = _tile_program(
                layer, layer_idx, t,
                w_cols=layer.w, c_out=co, c_in=c_in_eff,
                weight_frac=frac, replicate_input=not depthwise,
                # each OC tile owns its own weight slice -> NOT reusable
                weight_key=(layer_idx, "OC", t), weight_shared=False,
                # feature maps STREAM through line buffers (Angel-Eye-class
                # designs hold weights in a dedicated buffer but not whole
                # input maps): consecutive OC tiles re-stream the input.
                # This is why the paper's OC tiling collapses at few cores
                # (Table 3: 4.2 vs 6.8 fps at k=1) — the re-streams serialize.
                input_key=(layer_idx, "OC", "full_in") if not depthwise else None,
                input_shared=False,
                load_groups=load_groups,
            )
            ifps.append(IFP(layer_idx, strategy, t, len(chunks), prog))
    return ifps


def _tile_program(
    layer: Layer,
    layer_idx: int,
    tile_idx: int,
    *,
    w_cols: int,
    c_out: int,
    c_in: int,
    weight_frac: float,
    replicate_input: bool,
    weight_key,
    input_key,
    load_groups: int,
    weight_shared: bool = False,
    input_shared: bool = False,
) -> Program:
    """Emit the instruction sequence of one tile.

    Input loads are split into ``load_groups`` row-chunks so the per-core
    scheduler (second-level IDM) can overlap LOAD of chunk g+1 with CONV of
    chunk g — the reason the ISA carries dependency fields at all.
    """
    prog = Program()
    w_bytes = layer.weight_nbytes * weight_frac
    in_bytes = layer.input_nbytes(w_cols=w_cols, c_in=c_in)
    out_bytes = float(layer.h * w_cols * c_out * layer.abytes)
    flops = 2.0 * layer.h * w_cols * c_out * (c_in if layer.is_depthwise else layer.c_in // layer.groups) \
        * layer.kh * layer.kw / (layer.groups if layer.is_depthwise else 1)
    if layer.is_depthwise:
        # depthwise: each output channel sees 1 input channel
        flops = 2.0 * layer.h * w_cols * c_out * layer.kh * layer.kw

    prog.emit(Op.CONVINIT, layer=layer_idx, tile=tile_idx)
    wload = prog.load(w_bytes, kind="w", key=weight_key, shared=weight_shared,
                      layer=layer_idx, tile=tile_idx)

    groups = max(1, min(load_groups, layer.h))
    pix_rows = _split(layer.h, groups)
    done_rows = 0
    for g, rows in enumerate(pix_rows):
        frac_g = rows / layer.h
        iload = prog.load(
            in_bytes * frac_g,
            kind="in",
            key=input_key,                      # tensor-level identity
            shared=input_shared and input_key is not None,
            layer=layer_idx, tile=tile_idx, group=g,
        )
        # depthwise convs stream one channel per lane: the ICP quantization
        # of the dense PE array doesn't apply (extent 0 = skip that dim)
        q_ci = 0 if layer.is_depthwise else c_in
        conv = prog.emit(
            Op.CONV,
            flops=flops * frac_g,
            shape=(rows * w_cols, q_ci, c_out),
            deps=[wload, iload],
            layer=layer_idx, tile=tile_idx, group=g,
        )
        prog.save(out_bytes * frac_g, deps=[conv], layer=layer_idx, tile=tile_idx, group=g)
        done_rows += rows
    return prog


def dedupe_onchip(
    programs: List[Program],
    vmem_bytes: int,
) -> Program:
    """Concatenate the IFP programs assigned to one core, dropping *shared*
    LOADs whose tensor is already resident from the previous package and fits
    on-chip memory.  This models the on-chip weight/feature buffer:
    consecutive WIDTH tiles of a layer share weights; consecutive OC tiles
    share the (replicated) input feature map.

    Residency is program-granular: after each package, the on-chip buffer
    holds exactly the keyed tensors that package loaded (grouped chunk loads
    of one tensor count toward one residency entry).  This is the reference
    semantics the dynamic compiler's chain construction
    (``[cold, cached, cached, ...]``) must match — asserted in tests.
    """
    out = Program()
    resident: dict = {}   # kind -> set of resident tensor keys
    for p in programs:
        # total bytes per (kind, key) tensor in this package (grouped loads)
        totals: dict = {}
        for ins in p.instrs:
            if ins.op is Op.LOAD and ins.tag.get("key") is not None:
                kk = (ins.tag.get("kind"), ins.tag["key"])
                totals[kk] = totals.get(kk, 0.0) + ins.nbytes
        mapping: dict = {}    # old iid -> new iid | None if dropped
        touched: dict = {}    # kind -> set of keys this package keeps on-chip
        for ins in p.instrs:
            if ins.op is Op.LOAD:
                kind = ins.tag.get("kind")
                key = ins.tag.get("key")
                fits = (
                    key is not None
                    and totals.get((kind, key), float("inf")) <= vmem_bytes
                )
                if fits:
                    touched.setdefault(kind, set()).add(key)
                    if ins.tag.get("shared") and key in resident.get(kind, ()):
                        # hit: tensor resident from the previous package
                        mapping[ins.iid] = None
                        continue
            new_deps = [mapping[d] for d in ins.deps if mapping.get(d) is not None]
            new_iid = len(out.instrs)
            mapping[ins.iid] = new_iid
            out.instrs.append(
                dataclasses.replace(ins, iid=new_iid, deps=new_deps, tag=dict(ins.tag))
            )
        resident = {k: set(v) for k, v in touched.items()}
    return out
