"""Hypervisor — the paper's §4.1 scheduling layer as a global event loop.

The seed reproduction simulated each tenant's clock separately inside
:class:`~repro.core.vengine.VirtualEngine.run`; dynamic arrivals, departures
and pool-wide rebalancing had to be faked outside the engine.  This module
owns that logic: a :class:`Hypervisor` holds the
:class:`~repro.core.hrp.ResourcePool`, consumes a single time-ordered
:class:`~repro.core.events.EventQueue`, and on every event asks a pluggable
**reallocation policy** how the pool should be divided among the tenants that
exist *now*.  Decisions are carried out by an **executor** — the
discrete-event :class:`VirtualEngine` for simulation, a bookkeeping-only
:class:`PoolExecutor` for analytic sweeps, or the JAX serving adapter
(:class:`repro.serving.tenancy.ServingExecutor`) where a resize decision
becomes a ``TwoStageCompiler.reconfigure`` call.

Policies (registered in :data:`POLICIES`):

* ``even_split``           — the paper's Figure-7 elastic scheme: divide the
                             pool evenly among tenants, capped at each
                             tenant's request, leftovers redistributed;
* ``weighted_by_workload`` — cores proportional to per-tenant workload
                             weight (defaults to total FLOPs of the tenant's
                             static artifact);
* ``priority``             — reserve every tenant's floor, then satisfy
                             requests in priority order;
* ``latency_slo``          — admit/resize against per-tenant latency targets:
                             each tenant's **demand** is the fewest cores
                             whose *queue-adjusted* latency — estimated
                             single-inference service time plus the M/D/1
                             mean wait its open-loop arrival rate induces —
                             fits under its SLO with headroom.  Demands are
                             granted in priority order: a higher-priority
                             arrival shrinks lower-priority residents toward
                             their floor, while an equal-or-lower-priority
                             newcomer is admitted all-or-nothing from the
                             capacity residents' SLOs don't need, else it
                             queues (or preempts, see below);
* ``no_realloc``           — baseline: residents keep their leases; newcomers
                             are admitted all-or-nothing from the free pool.
                             This is the seed engine's behaviour — the
                             degenerate one-policy case.

Tenants whose policy share would fall below ``min_cores`` are not admitted;
they park in a **wait queue** and are retried after every departure or
reconfiguration.  ``admission="fifo"`` (default) drains it head-of-line —
deterministic, but a big blocked head stalls everyone behind it;
``admission="backfill"`` walks the whole queue in order each drain, so small
tenants slip past a blocked head (EASY-style backfilling without
reservations — churn can starve the head); ``admission="easy"`` adds the
**reservation**: anyone admitted past a blocked head must leave the head's
floor in free cores, so departures accumulate toward the head's start time
instead of being re-consumed forever.  With ``preemptive=True`` an arrival
that cannot be admitted may **evict** strictly-lower-priority residents —
lowest priority tier first, largest **SLO slack** first within a tier (the
resident with the most latency headroom pays; no-SLO tenants are infinitely
slack), deterministic youngest-arrival/name tie-break — until it fits;
victims are charged a context switch by the executor (``exec_evict``) and
re-queued at the head of the wait queue.

**Second lease dimension — kv pages.**  When the pool is built with
``n_kv_pages > 0``, every admission/rebalance also splits the cache-page
budget (the serving layer's paged-KV pool): the core policy decides compute,
then ``kv_policy`` (default :func:`kv_pages_proportional` — memory follows
compute) maps that decision to per-tenant page leases, honouring
``TenantSpec.min_kv_pages`` floors all-or-nothing exactly like ``min_cores``.
Leases are recorded in the pool (``set_kv_lease``) with shrink-before-grow
ordering, surfaced to executors through the optional
``exec_kv_resize(name, pages, at)`` hook (the serving adapter turns it into
``ContinuousBatcher.set_page_limit``), and re-checked after every event by
``check_kv_quota`` alongside the isolation/bandwidth invariants.

**Open-loop traffic** rides on the same queue: ``REQUEST`` events (from
:class:`~repro.core.events.PoissonTraffic` / ``TraceTraffic`` via
:meth:`Hypervisor.open_traffic`) are routed to the executor's
``exec_request`` for resident tenants and held in a per-tenant backlog for
waiting ones (delivered on admission — offered load is never dropped).  When
the executor finishes a request it reports through ``completion_sink``; the
hypervisor turns that into a ``COMPLETION`` event, so request lifecycles are
visible on the global timeline (``completion_log``).

Executor protocol (duck-typed; every hook is optional except the ``exec_*``
trio when the corresponding event is used):

    begin(horizon)                    -> None   # run() starts
    advance(until)                    -> None   # simulate up to global time
    exec_admit(spec, n_cores, at)     -> None
    exec_resize(name, n_cores, at, mode) -> None
    exec_remove(name, at)             -> None
    exec_evict(name, at)              -> None   # preemption (falls back to
                                                # exec_remove when absent)
    exec_request(name, record, at)    -> None   # open-loop request delivery
    exec_fault(fault, at)             -> None   # a FaultSpec fires
    exec_recover(fault, at)           -> None   # its repair lands
    estimate_latency(spec, n_cores)   -> float  # latency_slo demand model
    completion_sink                   -> attr   # set by the hypervisor to
                                                # receive finished records
    probe(at)                         -> int    # straggler sweep, #rebalances
    metrics()                         -> dict   # returned by run()

**Fault domain handling.**  ``FAILURE`` events (from
:class:`repro.core.faults.FaultInjector`, or :meth:`fail_core` in tests)
deliver :class:`~repro.core.faults.FaultSpec` payloads.  ``CORE_DEATH``
marks the core unplaceable (``ResourcePool.mark_failed``) and **displaces**
the owning tenant in the same event: its lease is released through
``exec_evict`` (generated work survives — the engine parks in-flight
requests, the serving adapter keeps live state) and re-placement on the
healthy remainder is attempted immediately.  When that fails the tenant
parks at the *head* of the wait queue and retries on an
exponential-backoff ``RECOVERY`` timer (``fault_retry_backoff`` seconds,
doubling) until capacity returns.  ``CORE_SLOW``/``KV_CORRUPT`` are
forwarded to the executor (``exec_fault``) — detection is the straggler
probe / serving-guard path, not a placement change.  Repair ``RECOVERY``
events undo the fault (``mark_recovered`` + ``exec_recover``) and re-drain
the wait queue.  ``recovery_log`` records each displaced tenant's
failure→re-placement latency; blast radius is bounded by construction —
only tenants leasing the failed core are ever displaced.

The HRP isolation invariants (`check_isolation`, `check_bandwidth`,
`check_kv_quota`, `check_health`) are re-verified after *every* handled
event — a violated invariant raises immediately at the event that caused
it.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Union

from repro.obs import Telemetry

from .dispatch import SwitchMode
from .events import Event, EventKind, EventQueue, RequestRecord, emit_requests
from .faults import FaultKind, FaultSpec
from .hrp import HRPError, ResourcePool


@dataclasses.dataclass
class TenantSpec:
    """What a tenant asks of the hypervisor (the admission contract).

    ``artifact`` is executor-specific payload: a
    :class:`~repro.core.static_compiler.StaticArtifact` for the simulation
    engine, a program-key string for the serving stack, or ``None`` for
    bookkeeping-only pools.

    ``latency_slo`` and ``arrival_rate`` feed the ``latency_slo`` policy:
    the target single-request latency (seconds; the SLO) and the tenant's
    open-loop offered load (requests/s; 0 = unknown, skips the stability
    check).  ``open_loop=True`` declares the tenant request-driven from
    admission — it idles until its first REQUEST instead of re-issuing
    closed-loop inferences (a tenant also flips open-loop implicitly on its
    first delivered request).

    ``requested_kv_pages`` / ``min_kv_pages`` are the **memory dimension**
    of the lease (the paged-KV pool of ``repro.serving``): how many cache
    pages the tenant wants, and the floor below which it cannot run.
    Admission is all-or-nothing on the floor, exactly like ``min_cores``.
    """

    name: str
    requested_cores: int
    min_cores: int = 1
    priority: float = 1.0
    weight: Optional[float] = None     # None -> derived from artifact workload
    artifact: Any = None
    arrived_at: float = 0.0            # stamped by the hypervisor on admission
    latency_slo: Optional[float] = None
    arrival_rate: float = 0.0
    open_loop: bool = False
    requested_kv_pages: int = 0
    min_kv_pages: int = 0


@dataclasses.dataclass
class PolicyContext:
    """Snapshot a policy decides over: the pool size, the tenants that should
    hold cores after the decision (arrival order preserved; may include a
    not-yet-admitted candidate), the current lease sizes of residents, and —
    when the executor provides one — a latency estimator
    ``latency(spec, n_cores) -> seconds`` for SLO-aware decisions."""

    n_cores: int
    tenants: List[TenantSpec]
    current: Dict[str, int]
    time: float
    latency: Optional[Callable[[TenantSpec, int], float]] = None
    # memory dimension: pool-wide kv-page budget and current kv leases
    n_kv_pages: int = 0
    current_kv: Dict[str, int] = dataclasses.field(default_factory=dict)
    # pages of each tenant's lease that back its shared prefix cache,
    # billed once to the owning namespace (``ResourcePool.note_shared_kv``):
    # a kv split that drops a tenant below this set forces a cache-eviction
    # drain before its live requests can even use the lease, so policies
    # treat it as a soft floor
    shared_kv_pages: Dict[str, int] = dataclasses.field(default_factory=dict)


Policy = Callable[[PolicyContext], Dict[str, int]]


# ---------------------------------------------------------------------------
# reallocation policies
# ---------------------------------------------------------------------------

def _arrival_order(specs: List[TenantSpec]) -> List[TenantSpec]:
    return sorted(specs, key=lambda s: (s.arrived_at, s.name))


def _cap_and_redistribute(order: List[TenantSpec], shares: Dict[str, int],
                          n_cores: int) -> Dict[str, int]:
    """Clamp each share to the tenant's request; hand leftover cores one at a
    time to tenants still below their request (arrival order)."""
    alloc = {s.name: min(shares[s.name], s.requested_cores) for s in order}
    leftover = n_cores - sum(alloc.values())
    progress = True
    while leftover > 0 and progress:
        progress = False
        for s in order:
            if leftover == 0:
                break
            if alloc[s.name] < s.requested_cores:
                alloc[s.name] += 1
                leftover -= 1
                progress = True
    return alloc


def even_split(ctx: PolicyContext) -> Dict[str, int]:
    """Figure-7 elastic scheme: pool // T each, remainder to the earliest
    arrivals, capped at each tenant's request."""
    order = _arrival_order(ctx.tenants)
    if not order:
        return {}
    base, rem = divmod(ctx.n_cores, len(order))
    shares = {s.name: base + (1 if i < rem else 0) for i, s in enumerate(order)}
    return _cap_and_redistribute(order, shares, ctx.n_cores)


def _spec_weight(spec: TenantSpec) -> float:
    if spec.weight is not None:
        return max(spec.weight, 0.0)
    workload = getattr(spec.artifact, "workload", None)
    if workload:
        try:
            return max(sum(layer.flops for layer in workload), 1.0)
        except (AttributeError, TypeError):
            pass
    return 1.0


def weighted_by_workload(ctx: PolicyContext) -> Dict[str, int]:
    """Cores proportional to tenant weight (largest-remainder rounding) on
    top of a one-core floor, capped at each tenant's request."""
    order = _arrival_order(ctx.tenants)
    if not order:
        return {}
    # floors clamped to remaining capacity (arrival order) so shares can
    # never oversubscribe the pool; a tenant clamped below its min_cores is
    # simply not admitted (the hypervisor's floor check parks it)
    floors: Dict[str, int] = {}
    free = ctx.n_cores
    for s in order:
        floors[s.name] = min(max(s.min_cores, 1), s.requested_cores, free)
        free -= floors[s.name]
    spare = ctx.n_cores - sum(floors.values())
    shares = dict(floors)
    if spare > 0:
        weights = {s.name: _spec_weight(s) for s in order}
        total_w = sum(weights.values()) or 1.0
        raw = {s.name: spare * weights[s.name] / total_w for s in order}
        for s in order:
            shares[s.name] += int(raw[s.name])
        left = spare - sum(int(raw[s.name]) for s in order)
        by_remainder = sorted(
            order, key=lambda s: (-(raw[s.name] - int(raw[s.name])),
                                  s.arrived_at, s.name),
        )
        for s in by_remainder[:left]:
            shares[s.name] += 1
    return _cap_and_redistribute(order, shares, ctx.n_cores)


def priority(ctx: PolicyContext) -> Dict[str, int]:
    """Reserve every tenant's floor (arrival order), then satisfy requests in
    descending priority order with what remains."""
    order = _arrival_order(ctx.tenants)
    alloc: Dict[str, int] = {s.name: 0 for s in order}
    free = ctx.n_cores
    for s in order:
        floor = min(max(s.min_cores, 1), s.requested_cores, free)
        alloc[s.name] = floor
        free -= floor
    for s in sorted(order, key=lambda s: (-s.priority, s.arrived_at, s.name)):
        give = min(s.requested_cores - alloc[s.name], free)
        if give > 0:
            alloc[s.name] += give
            free -= give
    return alloc


#: utilisation ceiling for the latency_slo stability check — an open-loop
#: tenant whose offered load would keep its cores busier than this is given
#: more cores (queueing delay explodes as utilisation -> 1).
SLO_RHO_MAX = 0.85
#: service latency must fit under this fraction of the SLO: the slack left
#: over absorbs queueing delay, standing in for a p99 (not mean) target.
SLO_HEADROOM = 0.9


def queueing_latency(service: float, rate: float,
                     rho_max: float = SLO_RHO_MAX) -> float:
    """Expected request latency under open-loop Poisson offered load:
    service time plus the M/D/1 mean wait ``rho/(2(1-rho)) x L`` at
    utilisation ``rho = rate x L``.  Infinite at/beyond ``rho_max`` — an
    unstable (or near-saturated) queue can never meet a latency SLO, no
    matter the service time."""
    if rate <= 0:
        return service
    rho = service * rate
    if rho >= rho_max:
        return float("inf")
    return service * (1.0 + rho / (2.0 * (1.0 - rho)))


def slo_demand(ctx: PolicyContext, spec: TenantSpec, *,
               rho_max: float = SLO_RHO_MAX,
               headroom: float = SLO_HEADROOM) -> int:
    """Fewest cores meeting ``spec``'s latency SLO: the *queue-adjusted*
    latency — single-inference service time ``L(k)`` plus the M/D/1 mean
    wait its open-loop ``arrival_rate`` induces — must fit under
    ``headroom x latency_slo``.  Tenants without an SLO (or without a
    latency model) demand only their floor; when no admissible core count
    satisfies the target, the demand is the full request (best effort)."""
    floor = min(max(spec.min_cores, 1), spec.requested_cores)
    if spec.latency_slo is None or ctx.latency is None:
        return floor
    for k in range(floor, spec.requested_cores + 1):
        est = ctx.latency(spec, k)
        if est is None:
            return floor
        if queueing_latency(est, spec.arrival_rate, rho_max) \
                <= headroom * spec.latency_slo:
            return k
    return spec.requested_cores


def _priority_order(specs: List[TenantSpec]) -> List[TenantSpec]:
    return sorted(specs, key=lambda s: (-s.priority, s.arrived_at, s.name))


def latency_slo(ctx: PolicyContext) -> Dict[str, int]:
    """SLO-aware admission/reallocation.

    1. every resident keeps at least its floor (always feasible — they all
       held their floor before this decision);
    2. one priority-ordered pass (arrival breaks ties, so residents outrank
       same-priority newcomers) tops residents up toward their SLO demand
       and admits newcomers **all-or-nothing at their demand**.  A higher-
       priority arrival therefore *shrinks* lower-priority residents toward
       their floor — graceful degradation — rather than being locked out,
       while an equal-or-lower-priority newcomer can never dig into what a
       resident's SLO needs: if its demand doesn't fit in what's left, it
       gets 0 and parks (the preemptive hypervisor may instead evict a
       lower-priority resident whose *floor* is in the way);
    3. leftover cores go to tenants below their request, priority order —
       the policy is work-conserving.
    """
    order = _arrival_order(ctx.tenants)
    residents = [s for s in order if s.name in ctx.current]
    demands = {s.name: slo_demand(ctx, s) for s in order}
    alloc = {s.name: 0 for s in order}
    free = ctx.n_cores
    for s in _priority_order(residents):
        give = min(max(s.min_cores, 1), s.requested_cores, free)
        alloc[s.name] = give
        free -= give
    for s in _priority_order(order):
        if s.name in ctx.current:
            give = min(demands[s.name] - alloc[s.name], free)
            if give > 0:
                alloc[s.name] += give
                free -= give
        else:
            need = max(demands[s.name], max(s.min_cores, 1))
            if need <= min(free, s.requested_cores):
                alloc[s.name] = need
                free -= need
    for s in _priority_order(order):
        if free == 0:
            break
        if alloc[s.name] > 0 or s.name in ctx.current:
            give = min(s.requested_cores - alloc[s.name], free)
            if give > 0:
                alloc[s.name] += give
                free -= give
    return alloc


def no_realloc(ctx: PolicyContext) -> Dict[str, int]:
    """Baseline (the seed engine's semantics): residents keep their leases —
    except honouring their *own* explicit resize requests — and newcomers are
    admitted all-or-nothing from the free pool."""
    free = ctx.n_cores - sum(ctx.current.values())
    alloc: Dict[str, int] = {}
    for s in _arrival_order(ctx.tenants):
        cur = ctx.current.get(s.name)
        want = s.requested_cores
        if cur is None:                      # newcomer: all-or-nothing
            grant = want if want <= free else 0
        elif want < cur:                     # voluntary shrink
            grant = want
        elif want > cur:                     # voluntary grow, best-effort
            grant = cur + min(want - cur, free)
        else:
            grant = cur
        free -= grant - (cur or 0)
        alloc[s.name] = grant
    return alloc


def kv_pages_proportional(ctx: PolicyContext,
                          core_alloc: Dict[str, int]) -> Dict[str, int]:
    """Default kv-page split: among tenants granted cores, reserve every
    floor (``min_kv_pages``, arrival order), then share the remainder
    proportionally to the *core* grant (largest remainder), capped at each
    tenant's request — memory follows compute unless a policy says
    otherwise.  Tenants asking for no pages get none.

    A tenant's **shared prefix-cache pages** (``ctx.shared_kv_pages``,
    billed once to the owning namespace) raise its floor: granting below
    the pinned shared set would force the serving layer to tear the cache
    down just to re-fault the same contents privately per request — the
    split avoids that unless the pool genuinely cannot cover every floor,
    in which case the shrink lands and the batcher's eviction-before-fault
    discipline (``set_page_limit``) drains the cache first."""
    order = [s for s in _arrival_order(ctx.tenants)
             if core_alloc.get(s.name, 0) > 0 and s.requested_kv_pages > 0]
    if not order or ctx.n_kv_pages <= 0:
        return {s.name: 0 for s in ctx.tenants}
    alloc: Dict[str, int] = {s.name: 0 for s in ctx.tenants}
    free = ctx.n_kv_pages
    for s in order:
        shared = ctx.shared_kv_pages.get(s.name, 0)
        floor = min(max(s.min_kv_pages, shared), s.requested_kv_pages, free)
        alloc[s.name] = floor
        free -= floor
    if free > 0:
        weights = {s.name: core_alloc.get(s.name, 0) for s in order}
        total_w = sum(weights.values()) or 1.0
        raw = {s.name: free * weights[s.name] / total_w for s in order}
        grants = {}
        for s in order:
            grants[s.name] = min(int(raw[s.name]),
                                 s.requested_kv_pages - alloc[s.name])
            alloc[s.name] += grants[s.name]
        left = free - sum(grants.values())
        by_remainder = sorted(
            order, key=lambda s: (-(raw[s.name] - int(raw[s.name])),
                                  s.arrived_at, s.name),
        )
        while left > 0:
            progress = False
            for s in by_remainder:
                if left == 0:
                    break
                if alloc[s.name] < s.requested_kv_pages:
                    alloc[s.name] += 1
                    left -= 1
                    progress = True
            if not progress:
                break
    return alloc


POLICIES: Dict[str, Policy] = {
    "even_split": even_split,
    "weighted_by_workload": weighted_by_workload,
    "priority": priority,
    "latency_slo": latency_slo,
    "no_realloc": no_realloc,
}


def resolve_policy(policy: Union[str, Policy]) -> Policy:
    if callable(policy):
        return policy
    try:
        return POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown reallocation policy {policy!r}; "
            f"choose from {sorted(POLICIES)} or pass a callable"
        ) from None


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------

class PoolExecutor:
    """Bookkeeping-only executor: policy decisions act on the
    :class:`ResourcePool` directly, with no timeline behind them.  Used when
    the hypervisor only *places* tenants and an external runtime executes
    them (e.g. the Figure-7 analytic throughput sweep)."""

    def __init__(self, pool: ResourcePool) -> None:
        self.pool = pool

    def begin(self, horizon: float) -> None:
        pass

    def advance(self, until: float) -> None:
        pass

    def exec_admit(self, spec: TenantSpec, n_cores: int, at: float) -> None:
        self.pool.alloc(spec.name, n_cores)

    def exec_resize(self, name: str, n_cores: int, at: float, mode: SwitchMode) -> None:
        self.pool.resize(name, n_cores)

    def exec_remove(self, name: str, at: float) -> None:
        self.pool.release(name)

    def probe(self, at: float) -> int:
        return 0

    def metrics(self) -> Dict[str, Any]:
        return {}


# ---------------------------------------------------------------------------
# the hypervisor
# ---------------------------------------------------------------------------

class Hypervisor:
    """Global event-driven scheduler over one :class:`ResourcePool`.

    Two usage styles share one code path:

    * **simulated time** — schedule arrivals/departures/reconfigs on the
      queue, then ``run(horizon)``; the executor's ``advance`` is called to
      bring the simulation to each event's timestamp before it is handled;
    * **immediate mode** — call :meth:`admit` / :meth:`depart` /
      :meth:`resize_request` directly (the serving stack, where time is real
      and the loop is an ordered decision log).

    ``on_event(hypervisor, event)`` is invoked after every handled event —
    a hook for traces and invariant assertions in tests.
    """

    def __init__(
        self,
        pool: Optional[ResourcePool] = None,
        *,
        policy: Union[str, Policy] = "even_split",
        executor: Any = None,
        probe_interval: Optional[float] = None,
        switch_mode: SwitchMode = SwitchMode.LAYER_LEVEL,
        admission: str = "fifo",
        preemptive: bool = False,
        kv_policy: Optional[Callable[[PolicyContext, Dict[str, int]],
                                     Dict[str, int]]] = None,
        fault_retry_backoff: float = 0.05,
        on_event: Optional[Callable[["Hypervisor", Event], None]] = None,
        telemetry: Optional[Telemetry] = None,
    ) -> None:
        if pool is None:
            if executor is None or not hasattr(executor, "pool"):
                raise ValueError("pass a ResourcePool or an executor exposing .pool")
            pool = executor.pool
        if admission not in ("fifo", "backfill", "easy"):
            raise ValueError(
                f"unknown admission order {admission!r}; "
                "use 'fifo', 'backfill' or 'easy'"
            )
        self.pool = pool
        self.policy = resolve_policy(policy)
        self.executor = executor if executor is not None else PoolExecutor(pool)
        self.queue = EventQueue()
        self.specs: Dict[str, TenantSpec] = {}
        self.waiting: List[TenantSpec] = []
        self.probe_interval = probe_interval
        self.switch_mode = switch_mode
        self.admission = admission
        self.preemptive = preemptive
        self.kv_policy = kv_policy if kv_policy is not None \
            else kv_pages_proportional
        self.on_event = on_event
        # telemetry: every handled event becomes a trace instant on the
        # "hypervisor" track (stamped with *event* time, so a sim run and a
        # real-time run both render), a per-kind counter in the registry,
        # and — for completions — a per-tenant latency histogram
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._tracer = self.telemetry.tracer
        self._reg = self.telemetry.registry
        self.clock = 0.0
        self.trace: List[Event] = []
        # open-loop request plumbing: finished records (COMPLETION events),
        # requests that arrived while their tenant waited for admission, and
        # preemption accounting
        self.completion_log: List[RequestRecord] = []
        self.preemptions: List[str] = []
        self._request_backlog: Dict[str, List[RequestRecord]] = {}
        self._rid = itertools.count()
        # fault-domain bookkeeping: delivered faults, per-displaced-tenant
        # failure timestamps, recovery records, and retry backoff state
        self.fault_log: List[FaultSpec] = []
        self.recovery_log: List[Dict[str, Any]] = []
        self.fault_retry_backoff = fault_retry_backoff
        self._displaced_at: Dict[str, float] = {}
        self._retry_backoff: Dict[str, float] = {}
        if hasattr(self.executor, "completion_sink"):
            self.executor.completion_sink = self._request_completed

    @staticmethod
    def _validate(spec: TenantSpec) -> None:
        if spec.requested_cores < 1:
            raise ValueError(
                f"tenant {spec.name!r} requests {spec.requested_cores} cores; "
                "a tenant needs at least 1"
            )

    # -- scheduling ---------------------------------------------------------
    def schedule_arrival(self, spec: TenantSpec, *, at: float = 0.0) -> Event:
        self._validate(spec)
        return self.queue.schedule(EventKind.ARRIVAL, at, tenant=spec.name, spec=spec)

    def schedule_departure(self, name: str, *, at: float) -> Event:
        return self.queue.schedule(EventKind.DEPARTURE, at, tenant=name)

    def schedule_reconfig(self, name: str, n_cores: int, *, at: float,
                          mode: Optional[SwitchMode] = None) -> Event:
        return self.queue.schedule(
            EventKind.RECONFIG, at, tenant=name, n_cores=n_cores, mode=mode,
        )

    def schedule_completion(self, name: str, *, at: float, **payload: Any) -> Event:
        return self.queue.schedule(EventKind.COMPLETION, at, tenant=name, **payload)

    def schedule_probe(self, *, at: float) -> Event:
        return self.queue.schedule(EventKind.PROBE, at)

    def schedule_fault(self, fault: FaultSpec, *,
                       recovery: bool = True) -> Event:
        """Schedule one fault on the timeline (plus its repair ``RECOVERY``
        when the fault carries a ``duration``).  Bulk injection goes through
        :meth:`repro.core.faults.FaultInjector.inject` on ``self.queue``."""
        ev = self.queue.schedule(EventKind.FAILURE, fault.time, fault=fault)
        if recovery and fault.duration is not None:
            self.queue.schedule(EventKind.RECOVERY,
                                fault.time + fault.duration, fault=fault)
        return ev

    def schedule_request(self, name: str, *, at: float,
                         record: Optional[RequestRecord] = None,
                         slo: Optional[float] = None) -> RequestRecord:
        """Schedule one open-loop request for ``name``; returns the (shared)
        record that will be stamped as the request moves through the system."""
        if record is None:
            record = RequestRecord(tenant=name, rid=next(self._rid),
                                   t_arrival=at, slo=slo)
        self.queue.schedule(EventKind.REQUEST, at, tenant=name, record=record)
        return record

    def open_traffic(self, name: str, traffic: Any, horizon: float, *,
                     slo: Optional[float] = None,
                     deadline_after: Optional[float] = None,
                     ) -> List[RequestRecord]:
        """Attach a seeded open-loop arrival stream
        (:class:`~repro.core.events.PoissonTraffic`, ``TraceTraffic``, or a
        plain iterable of times) to tenant ``name`` and return its records
        for SLO accounting after :meth:`run`.  ``deadline_after`` stamps
        each request with a drop deadline (arrival + seconds): the executor
        sheds requests it would only start past their deadline."""
        return emit_requests(self.queue, name, traffic, horizon, slo=slo,
                             deadline_after=deadline_after)

    def _request_completed(self, record: RequestRecord) -> None:
        # executor callback -> COMPLETION event, so request lifecycles are
        # ordered on (and visible in) the global timeline
        self.queue.schedule(EventKind.COMPLETION, record.t_complete,
                            tenant=record.tenant, record=record)

    # -- immediate mode -----------------------------------------------------
    def admit(self, spec: TenantSpec, *, at: Optional[float] = None) -> bool:
        """Try to admit ``spec`` now; on failure it parks in the wait queue.
        Returns True when the tenant holds a lease on return."""
        self._validate(spec)
        t = self.clock if at is None else at
        ev = Event(time=t, kind=EventKind.ARRIVAL, tenant=spec.name,
                   payload={"spec": spec})
        self._handle(ev, t)
        self._post_event(ev)
        return spec.name in self.specs

    def depart(self, name: str, *, at: Optional[float] = None) -> None:
        t = self.clock if at is None else at
        ev = Event(time=t, kind=EventKind.DEPARTURE, tenant=name)
        self._handle(ev, t)
        self._post_event(ev)

    def resize_request(self, name: str, n_cores: int, *,
                       at: Optional[float] = None) -> None:
        t = self.clock if at is None else at
        ev = Event(time=t, kind=EventKind.RECONFIG, tenant=name,
                   payload={"n_cores": n_cores, "mode": None})
        self._handle(ev, t)
        self._post_event(ev)

    def fail_core(self, core: int, *, at: Optional[float] = None,
                  duration: Optional[float] = None) -> FaultSpec:
        """Immediate-mode core death (tests / live serving): handle the
        FAILURE now; schedule the repair only if ``duration`` is given."""
        t = self.clock if at is None else at
        fault = FaultSpec(time=t, kind=FaultKind.CORE_DEATH, fid=-1,
                          core=core, duration=duration)
        ev = Event(time=t, kind=EventKind.FAILURE, payload={"fault": fault})
        self._handle(ev, t)
        self._post_event(ev)
        if duration is not None:
            self.queue.schedule(EventKind.RECOVERY, t + duration, fault=fault)
        return fault

    def recover_core(self, core: int, *, at: Optional[float] = None) -> None:
        """Immediate-mode repair of a core failed via :meth:`fail_core`."""
        t = self.clock if at is None else at
        fault = FaultSpec(time=t, kind=FaultKind.CORE_DEATH, fid=-1, core=core)
        ev = Event(time=t, kind=EventKind.RECOVERY, payload={"fault": fault})
        self._handle(ev, t)
        self._post_event(ev)

    # -- queries ------------------------------------------------------------
    def allocation(self) -> Dict[str, int]:
        return {t: lease.n_cores for t, lease in self.pool.leases.items()}

    def kv_allocation(self) -> Dict[str, int]:
        return dict(self.pool.kv_leases)

    def waiting_tenants(self) -> List[str]:
        return [s.name for s in self.waiting]

    # -- the loop -----------------------------------------------------------
    def run(self, horizon: float) -> Dict[str, Any]:
        """Handle every queued event with ``time <= horizon`` in order,
        advancing the executor's simulation between events, then advance to
        ``horizon``.  Returns ``executor.metrics()`` when available.

        The outer loop repeats because advancing can *generate* events: an
        executor finishing open-loop requests reports them through
        ``completion_sink``, and those COMPLETION events (stamped at their
        completion times, possibly before the clock) must still be handled
        within the horizon."""
        if hasattr(self.executor, "begin"):
            self.executor.begin(horizon)
        if self.probe_interval:
            t = self.clock + self.probe_interval
            while t <= horizon + 1e-12:
                self.schedule_probe(at=t)
                t += self.probe_interval
        while True:
            while self.queue and self.queue.next_time() <= horizon:
                ev = self.queue.pop()
                t = max(ev.time, self.clock)
                self.executor.advance(t)
                self.clock = t
                self._handle(ev, t)
                self._post_event(ev)
            self.executor.advance(horizon)
            self.clock = max(self.clock, horizon)
            if not (self.queue and self.queue.next_time() <= horizon):
                break
        if hasattr(self.executor, "metrics"):
            return self.executor.metrics()
        return {}

    # -- event handling -----------------------------------------------------
    def _post_event(self, ev: Event) -> None:
        self.pool.check_isolation()
        self.pool.check_bandwidth()
        self.pool.check_kv_quota()
        self.pool.check_health()
        self.trace.append(ev)
        if self._tracer.enabled:
            track = ev.tenant if ev.tenant is not None else "hypervisor"
            self._tracer.instant(ev.kind.value, track, ts=ev.time,
                                 args={"tenant": ev.tenant})
        self._reg.counter(f"hypervisor.events.{ev.kind.value}").inc()
        if self.on_event is not None:
            self.on_event(self, ev)

    def _handle(self, ev: Event, t: float) -> None:
        if ev.kind is EventKind.ARRIVAL:
            spec: TenantSpec = ev.payload["spec"]
            if spec.name in self.specs:
                # re-submission of a resident: an updated contract, not a
                # second lease (pool.alloc would reject the duplicate name)
                resident = self.specs[spec.name]
                resident.requested_cores = spec.requested_cores
                resident.min_cores = spec.min_cores
                resident.priority = spec.priority
                resident.weight = spec.weight
                resident.requested_kv_pages = spec.requested_kv_pages
                resident.min_kv_pages = spec.min_kv_pages
                if not self._drain_waiting(t):
                    self._rebalance(t)
                return
            # a re-submitted waiter replaces its stale queue entry
            self.waiting = [w for w in self.waiting if w.name != spec.name]
            spec.arrived_at = t
            # FIFO fairness: an arrival never jumps a non-empty wait queue
            # (backfill allows it — that is the point; EASY allows it only
            # around the head's reservation); preemption is the one
            # exception, since it outranks the queue by priority
            jumped = self.admission == "fifo" and bool(self.waiting)
            if not (not jumped and self._try_admit(
                spec, t, reserve=self._head_reservation())
            ) and not (
                self.preemptive and self._try_preempt(spec, t, try_free=jumped)
            ):
                self.waiting.append(spec)
        elif ev.kind is EventKind.DEPARTURE:
            name = ev.tenant
            if name in self.specs:
                del self.specs[name]
                self.executor.exec_remove(name, t)
                # admitting a waiter re-applies the policy over the full new
                # tenant set, so residents are resized exactly once; only
                # rebalance separately when nobody could be admitted
                if not self._drain_waiting(t):
                    self._rebalance(t)
            else:
                self.waiting = [w for w in self.waiting if w.name != name]
                self._displaced_at.pop(name, None)
                self._retry_backoff.pop(name, None)
        elif ev.kind is EventKind.RECONFIG:
            name = ev.tenant
            if name in self.specs:
                n = ev.payload.get("n_cores")
                if n is not None:
                    self.specs[name].requested_cores = n
                mode = ev.payload.get("mode")
                if not self._drain_waiting(t, mode=mode):
                    self._rebalance(t, mode=mode)
        elif ev.kind is EventKind.PROBE:
            self.executor.probe(t)
        elif ev.kind is EventKind.REQUEST:
            record: RequestRecord = ev.payload["record"]
            if ev.tenant in self.specs and hasattr(self.executor, "exec_request"):
                self.executor.exec_request(ev.tenant, record, t)
            else:
                # tenant still waiting for admission (or untracked): hold the
                # request; it is delivered the moment the tenant is admitted
                self._request_backlog.setdefault(ev.tenant, []).append(record)
        elif ev.kind is EventKind.COMPLETION:
            rec = ev.payload.get("record")
            if rec is not None:
                self.completion_log.append(rec)
                lat = getattr(rec, "latency", None)
                if lat is not None:
                    self._reg.histogram(
                        "hypervisor.request_latency_s",
                        rec.tenant).record(lat)
        elif ev.kind is EventKind.FAILURE:
            self._handle_failure(ev.payload["fault"], t)
        elif ev.kind is EventKind.RECOVERY:
            self._handle_recovery(ev, t)

    # -- fault handling -----------------------------------------------------
    def _handle_failure(self, fault: FaultSpec, t: float) -> None:
        """Deliver one fault.  ``CORE_DEATH`` shrinks the placeable pool and
        displaces the owning tenant inside this very event, so the
        ``check_health`` invariant holds at the event boundary; the blast
        radius is exactly the tenants leasing the failed core — nobody else
        is resized or touched here."""
        self.fault_log.append(fault)
        if fault.kind is FaultKind.CORE_DEATH:
            owner = self.pool.mark_failed(fault.core)
            if hasattr(self.executor, "exec_fault"):
                self.executor.exec_fault(fault, t)
            if owner is not None and owner in self.specs:
                self._displace(owner, t)
        else:
            # CORE_SLOW / KV_CORRUPT: no placement change — detection is the
            # straggler-probe / serving-guard path inside the executor
            if hasattr(self.executor, "exec_fault"):
                self.executor.exec_fault(fault, t)

    def _displace(self, name: str, t: float) -> None:
        """Pull a tenant off failed hardware: release its lease through the
        eviction path (generated work survives — parked requests / kept
        live state) and re-place it on the healthy remainder.  Unlike a
        preemption this is not charged to ``preemptions`` — the tenant did
        nothing wrong.  On failure it parks at the *head* of the wait queue
        with an exponential-backoff retry timer."""
        spec = self.specs.pop(name)
        if hasattr(self.executor, "exec_evict"):
            self.executor.exec_evict(name, t)
        else:
            self.executor.exec_remove(name, t)
        self._displaced_at.setdefault(name, t)
        if not self._try_admit(spec, t):
            self.waiting.insert(0, spec)
            self._schedule_retry(name, t)

    def _schedule_retry(self, name: str, t: float) -> None:
        backoff = self._retry_backoff.get(name, self.fault_retry_backoff)
        self.queue.schedule(EventKind.RECOVERY, t + backoff,
                            tenant=name, retry=True)
        self._retry_backoff[name] = backoff * 2.0

    def _handle_recovery(self, ev: Event, t: float) -> None:
        if ev.payload.get("retry"):
            # backoff retry for a displaced tenant still waiting
            name = ev.tenant
            if name in self.specs or name not in self._displaced_at:
                return                      # already re-placed (or departed)
            self._drain_waiting(t)
            if name not in self.specs and \
                    any(w.name == name for w in self.waiting):
                self._schedule_retry(name, t)
            return
        fault: FaultSpec = ev.payload["fault"]
        if hasattr(self.executor, "exec_recover"):
            self.executor.exec_recover(fault, t)
        if fault.kind is FaultKind.CORE_DEATH and fault.core is not None:
            self.pool.mark_recovered(fault.core)
            # repaired capacity goes straight back to work
            if not self._drain_waiting(t):
                self._rebalance(t)

    def _current(self) -> Dict[str, int]:
        return {
            name: lease.n_cores
            for name, lease in self.pool.leases.items()
            if name in self.specs
        }

    def _policy_ctx(self, tenants: List[TenantSpec], t: float) -> PolicyContext:
        # policies plan over the HEALTHY pool: a decision that targets a
        # failed core would bounce off placement anyway — better to degrade
        # the split than to fail the apply
        return PolicyContext(
            self.pool.n_healthy, tenants, self._current(), t,
            latency=getattr(self.executor, "estimate_latency", None),
            n_kv_pages=self.pool.n_kv_pages,
            current_kv={n: p for n, p in self.pool.kv_leases.items()
                        if n in self.specs},
            shared_kv_pages={n: p for n, p in self.pool.shared_kv.items()
                             if n in self.specs},
        )

    def _flush_backlog(self, name: str, t: float) -> None:
        backlog = self._request_backlog.pop(name, None)
        if backlog and hasattr(self.executor, "exec_request"):
            for record in backlog:
                self.executor.exec_request(name, record, t)

    def _try_admit(self, spec: TenantSpec, t: float,
                   mode: Optional[SwitchMode] = None,
                   reserve: tuple = (0, 0)) -> bool:
        reserve_cores, reserve_kv = reserve
        candidates = list(self.specs.values()) + [spec]
        ctx = self._policy_ctx(candidates, t)
        targets = self.policy(ctx)
        floor = max(spec.min_cores, 1)
        if targets.get(spec.name, 0) < floor:
            return False
        for s in self.specs.values():
            if targets.get(s.name, 0) < max(s.min_cores, 1):
                return False  # admitting would starve a resident below floor
        if reserve_cores > 0 and \
                self.pool.n_cores - sum(targets.values()) < reserve_cores:
            return False  # EASY reservation: the wait-queue head's cores
        # memory dimension: both the newcomer and every resident must keep
        # their kv-page floor under the proposed split
        kv_targets = self.kv_policy(ctx, targets)
        if kv_targets.get(spec.name, 0) < spec.min_kv_pages:
            return False
        for s in self.specs.values():
            if kv_targets.get(s.name, 0) < s.min_kv_pages:
                return False
        if reserve_kv > 0 and \
                self.pool.n_kv_pages - sum(kv_targets.values()) < reserve_kv:
            return False  # EASY reservation: the head's kv-page floor
        self._apply(targets, t, admit={spec.name: spec}, mode=mode,
                    kv_targets=kv_targets)
        self.specs[spec.name] = spec
        self._flush_backlog(spec.name, t)
        if spec.name in self._displaced_at:
            # a fault-displaced tenant is back on cores: stamp its recovery
            t0 = self._displaced_at.pop(spec.name)
            self._retry_backoff.pop(spec.name, None)
            self.recovery_log.append({
                "tenant": spec.name, "failed_at": t0, "recovered_at": t,
                "recovery_latency": t - t0,
            })
            # the displaced→re-admitted window as one span on the tenant's
            # track, in event time (matches the instants _post_event emits)
            self._tracer.complete("recovery", spec.name, t0, t - t0)
            self._reg.histogram("hypervisor.recovery_latency_s",
                                spec.name).record(t - t0)
        return True

    def _evict(self, victim: TenantSpec, t: float) -> None:
        """Revoke a resident's lease for a higher-priority arrival.  The
        executor charges the context-switch cost (``exec_evict``) and parks
        the victim's queued requests; its spec is NOT re-queued here — the
        caller decides where it lands."""
        del self.specs[victim.name]
        if hasattr(self.executor, "exec_evict"):
            self.executor.exec_evict(victim.name, t)
        else:
            self.executor.exec_remove(victim.name, t)
        self.preemptions.append(victim.name)

    def _slo_slack(self, spec: TenantSpec) -> float:
        """Headroom between a resident's SLO and its estimated queue-adjusted
        latency at its *current* lease.  Tenants without an SLO (or without a
        latency model) report infinite slack — evicting them costs no
        attainment.  A tenant already blowing its SLO reports -inf."""
        est_fn = getattr(self.executor, "estimate_latency", None)
        if est_fn is None or spec.latency_slo is None:
            return float("inf")
        lease = self.pool.lease_of(spec.name)
        k = lease.n_cores if lease is not None else max(spec.min_cores, 1)
        est = est_fn(spec, k)
        if est is None:
            return float("inf")
        return spec.latency_slo - queueing_latency(est, spec.arrival_rate)

    def _try_preempt(self, spec: TenantSpec, t: float, *,
                     try_free: bool = False) -> bool:
        """Evict strictly-lower-priority residents until ``spec`` fits —
        lowest priority tier first, and *within* a tier the resident with
        the largest SLO slack first (it has the most latency headroom to
        give up; no-SLO tenants count as infinitely slack).  Ties break
        deterministically on youngest arrival, then name.  Victims re-queue
        at the head of the wait queue (earliest arrival first).  If even
        evicting every lower-priority resident cannot seat ``spec``, the
        evictions are rolled back: each victim is restored at exactly its
        pre-eviction core and kv-page lease (the resources it held are
        still free, so the restore cannot fail) — though it has paid the
        context switch."""
        if max(spec.min_cores, 1) > self.pool.n_healthy:
            return False    # could never fit even on an empty (healthy)
                            # pool: don't charge residents for a doomed try
        victims = sorted(
            (s for s in self.specs.values() if s.priority < spec.priority),
            key=lambda s: (s.priority, -self._slo_slack(s),
                           -s.arrived_at, s.name),
        )
        if not victims:
            return False
        # priority outranks queue fairness: when FIFO queue-jumping skipped
        # the regular admission attempt (try_free), seat the arrival from
        # free capacity first — never evict when admission alone works.  In
        # the non-jumped path _handle already tried (and failed) exactly
        # this admission, so re-evaluating the policy would be pure waste.
        if try_free and self._try_admit(spec, t):
            return True
        sizes: Dict[str, int] = {}
        kv_sizes: Dict[str, int] = {}
        evicted: List[TenantSpec] = []
        admitted = False
        for v in victims:
            sizes[v.name] = self.pool.lease_of(v.name).n_cores
            kv_sizes[v.name] = self.pool.kv_lease_of(v.name)
            self._evict(v, t)
            evicted.append(v)
            if self._try_admit(spec, t):
                admitted = True
                break
        by_arrival = sorted(evicted, key=lambda s: (s.arrived_at, s.name))
        if not admitted:
            for i, v in enumerate(by_arrival):      # exact rollback
                try:
                    self.executor.exec_admit(v, sizes[v.name], t)
                except HRPError as e:
                    # the pool shrank under us mid-rollback (e.g. a core
                    # failed between eviction and restore): exact
                    # restoration is impossible.  Abort LOUDLY but leave the
                    # invariants clean — every not-yet-restored victim parks
                    # at the head of the wait queue (its requests stay in
                    # the backlog / parked by the executor), nothing holds a
                    # partial lease.
                    for w in reversed(by_arrival[i:]):
                        self.waiting.insert(0, w)
                        self._displaced_at.setdefault(w.name, t)
                    raise HRPError(
                        f"preemption rollback could not restore "
                        f"{v.name} at {sizes[v.name]} cores (pool shrank "
                        f"mid-rollback); {len(by_arrival) - i} victim(s) "
                        f"parked at the wait-queue head") from e
                self.specs[v.name] = v
                if kv_sizes[v.name]:
                    self.pool.set_kv_lease(v.name, kv_sizes[v.name])
                self._flush_backlog(v.name, t)
            return False
        for v in reversed(by_arrival):
            self.waiting.insert(0, v)
        return True

    def _rebalance(self, t: float, mode: Optional[SwitchMode] = None) -> None:
        if not self.specs:
            return
        ctx = self._policy_ctx(list(self.specs.values()), t)
        targets = self.policy(ctx)
        self._apply(targets, t, mode=mode,
                    kv_targets=self.kv_policy(ctx, targets))

    def _apply_kv(self, kv_targets: Dict[str, int], t: float) -> None:
        """Carry the memory-dimension decision out: shrinks first (they free
        the pages the grows need — the same discipline as core resizes), and
        notify the executor through the optional ``exec_kv_resize`` hook."""
        current = dict(self.pool.kv_leases)
        changes = [
            (name, pages) for name, pages in sorted(kv_targets.items())
            if name in self.specs and self.pool.lease_of(name) is not None
            and pages != current.get(name, 0)
        ]
        notify = getattr(self.executor, "exec_kv_resize", None)
        for shrink_pass in (True, False):
            for name, pages in changes:
                if (pages < current.get(name, 0)) is not shrink_pass:
                    continue
                self.pool.set_kv_lease(name, pages)
                if notify is not None:
                    notify(name, pages, t)

    def _apply(self, targets: Dict[str, int], t: float, *,
               admit: Optional[Dict[str, TenantSpec]] = None,
               mode: Optional[SwitchMode] = None,
               kv_targets: Optional[Dict[str, int]] = None) -> None:
        """Carry a policy decision out through the executor: shrinks first
        (they free the cores the grows need), then grows, then admissions,
        then kv-page lease changes (which need the admitted core leases)."""
        admit = admit or {}
        mode = mode or self.switch_mode
        current = {
            name: lease.n_cores for name, lease in self.pool.leases.items()
        }
        resident = [n for n in sorted(targets) if n in current and n not in admit]
        for name in resident:
            if 0 < targets[name] < current[name]:
                self.executor.exec_resize(name, targets[name], t, mode)
        for name in resident:
            # >= not >: an equal target must still reach the executor so a
            # stale deferred (task-level) decision gets dropped
            if targets[name] >= current[name]:
                self.executor.exec_resize(name, targets[name], t, mode)
        for name, spec in admit.items():
            self.executor.exec_admit(spec, targets[name], t)
        if kv_targets is not None:
            # admissions just landed: record them before the kv pass so the
            # admitted tenant's pages pass the holds-a-core-lease check
            for name, spec in admit.items():
                self.specs.setdefault(name, spec)
            self._apply_kv(kv_targets, t)

    def _head_reservation(self) -> tuple:
        """EASY start-time guarantee: while the wait-queue head is blocked,
        anyone admitted past it must leave the head's floor in free cores
        AND free kv pages — capacity released by departures *accumulates*
        for the head instead of being endlessly re-consumed by backfill
        churn, so the head starts as soon as enough has drained (in
        whichever dimension is binding).  Plain ``backfill`` reserves
        nothing (that is exactly its starvation mode).  Returns
        ``(cores, kv_pages)``."""
        if self.admission != "easy" or not self.waiting:
            return (0, 0)
        head = self.waiting[0]
        return (max(head.min_cores, 1), max(head.min_kv_pages, 0))

    def _drain_waiting(self, t: float, mode: Optional[SwitchMode] = None) -> int:
        """Admit from the wait queue.  ``fifo``: head-of-line — stop at the
        first waiter that doesn't fit.  ``backfill``: one deterministic pass
        over the whole queue in order, so a small tenant may be admitted past
        a blocked head (EASY-style backfilling without reservations — the
        head keeps its queue position and is always offered capacity first,
        but churn can starve it).  ``easy``: the same walk, except everyone
        admitted past a blocked head must respect the head's reservation
        (:meth:`_head_reservation`) — the regression the plain backfill
        test suite pins down.  Returns how many were admitted — each
        admission already re-applied the policy over the full tenant set,
        so the caller skips its own rebalance when this is non-zero."""
        admitted = 0
        i = 0
        while i < len(self.waiting):
            reserve = self._head_reservation() if i > 0 else (0, 0)
            if self._try_admit(self.waiting[i], t, mode=mode,
                               reserve=reserve):
                self.waiting.pop(i)
                admitted += 1
            elif self.admission in ("backfill", "easy"):
                i += 1
            else:
                break
        return admitted
