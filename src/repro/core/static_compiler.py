"""Static compiler — paper §5.2.1 (offline deployment stage).

Given a workload (per-layer shape table) and the hardware configuration of the
*basic shareable unit*, the static compiler:

1. tiles every layer under **both** strategies (WIDTH and OC) into IFPs,
2. prices every IFP on the basic unit with the latency simulator, producing
   the latency LUT (both cold and on-chip-cached variants),
3. caches everything for the dynamic compiler.

This is the expensive stage (paper: 14.7-46.8 s for full instruction
generation).  Our instruction IR is lighter than real binary instruction
files, so absolute times are smaller, but the asymmetry static >> dynamic is
preserved and measured in benchmarks/bench_context_switch.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

from .allocator import partition_candidates
from .hwmodel import HardwareModel
from .ifp import IFP, Strategy, make_layer_ifps
from .isa import Op, Program
from .latency_sim import simulate
from .workloads import Workload


@dataclasses.dataclass
class LayerLUT:
    """Latency LUT rows of one (layer, strategy): per-IFP cold/cached costs
    plus the per-run reuse overhead the allocator charges once per core.
    ``precomputed`` is the (prefix sums, candidate makespans) pair the
    binary-search allocator consumes — built offline so the dynamic path
    never enumerates the O(N²) candidates."""

    ifps: List[IFP]
    cold: List[float]
    cached: List[float]
    precomputed: Tuple[List[float], List[float]] | None = None

    @property
    def run_overhead(self) -> float:
        if not self.cold:
            return 0.0
        return max(self.cold[0] - self.cached[0], 0.0)


@dataclasses.dataclass
class StaticArtifact:
    """Everything the dynamic compiler needs, cached at deployment time."""

    workload: Workload
    hw_unit: HardwareModel
    n_tiles: int
    luts: Dict[Tuple[int, str], LayerLUT]
    compile_seconds: float
    # untiled per-layer programs: the §6.3.3 single-core fast path, generated
    # by the *original* compiler during offline deployment.
    mono: List[Program] = dataclasses.field(default_factory=list)
    mono_latency: List[float] = dataclasses.field(default_factory=list)

    def lut(self, layer_idx: int, strategy: Strategy) -> LayerLUT:
        return self.luts[(layer_idx, strategy.value)]


def _cached_program(prog: Program, vmem_bytes: int) -> Program:
    """The program as it runs when the *shared* tensor of its (layer,
    strategy) is already on-chip: shared LOADs that fit on-chip memory are
    dropped (weights under WIDTH tiling, the replicated input map under OC —
    per-tile OC weight slices are never reusable and stay)."""
    out = Program()
    # vmem-fit is judged on the whole tensor (grouped chunk loads of one
    # tensor sum), matching dedupe_onchip's residency model.
    totals: Dict[tuple, float] = {}
    for ins in prog.instrs:
        if ins.op is Op.LOAD and ins.tag.get("key") is not None:
            kk = (ins.tag.get("kind"), ins.tag["key"])
            totals[kk] = totals.get(kk, 0.0) + ins.nbytes
    mapping: Dict[int, int | None] = {}
    for ins in prog.instrs:
        if (
            ins.op is Op.LOAD
            and ins.tag.get("shared")
            and ins.tag.get("key") is not None
            and totals[(ins.tag.get("kind"), ins.tag["key"])] <= vmem_bytes
        ):
            mapping[ins.iid] = None
            continue
        new_deps = [mapping[d] for d in ins.deps if mapping.get(d) is not None]
        new_iid = len(out.instrs)
        mapping[ins.iid] = new_iid
        out.instrs.append(dataclasses.replace(ins, iid=new_iid, deps=new_deps, tag=dict(ins.tag)))
    return out


class StaticCompiler:
    """Offline stage of the two-stage static-dynamic compilation."""

    def __init__(
        self,
        hw_unit: HardwareModel,
        *,
        n_tiles: int = 16,
        load_groups: int = 4,
    ) -> None:
        self.hw_unit = hw_unit
        self.n_tiles = n_tiles
        self.load_groups = load_groups

    def compile(self, workload: Workload) -> StaticArtifact:
        t0 = time.perf_counter()
        luts: Dict[Tuple[int, str], LayerLUT] = {}
        for li, layer in enumerate(workload):
            for strategy in (Strategy.WIDTH, Strategy.OC):
                ifps = make_layer_ifps(
                    layer, li, strategy, self.n_tiles, load_groups=self.load_groups
                )
                cold: List[float] = []
                cached: List[float] = []
                for ifp in ifps:
                    ifp.program.validate()
                    ifp.flops = ifp.program.total_flops
                    ifp.program_cached = _cached_program(
                        ifp.program, self.hw_unit.vmem_bytes
                    )
                    ifp.latency = simulate(ifp.program, self.hw_unit)
                    ifp.latency_cached = simulate(ifp.program_cached, self.hw_unit)
                    cold.append(ifp.latency)
                    cached.append(ifp.latency_cached)
                lut = LayerLUT(ifps=ifps, cold=cold, cached=cached)
                lut.precomputed = partition_candidates(
                    cached, run_overhead=lut.run_overhead
                )
                luts[(li, strategy.value)] = lut
        mono = compile_monolithic(workload, self.hw_unit, load_groups=2 * self.load_groups)
        mono_latency = [simulate(p, self.hw_unit) for p in mono]
        dt = time.perf_counter() - t0
        return StaticArtifact(
            workload=workload,
            hw_unit=self.hw_unit,
            n_tiles=self.n_tiles,
            luts=luts,
            compile_seconds=dt,
            mono=mono,
            mono_latency=mono_latency,
        )


def compile_monolithic(workload: Workload, hw: HardwareModel, *, load_groups: int = 8) -> List[Program]:
    """Single-core baseline: each layer as one untiled program (the paper's
    static single-core design, run on the large core)."""
    progs: List[Program] = []
    for li, layer in enumerate(workload):
        ifps = make_layer_ifps(layer, li, Strategy.WIDTH, 1, load_groups=load_groups)
        assert len(ifps) == 1
        progs.append(ifps[0].program)
    return progs
