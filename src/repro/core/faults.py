"""Seeded fault injection for the fault-domain hypervisor.

The paper's isolation story (§4.2.2) is about *performance*: disjoint
leases, per-DDR-group port budgets.  A production pool also needs *failure*
isolation — a dead core, a wedged DMA engine, or a flipped bit in cache
memory must stay contained to the fault domain (one DDR group / one
tenant's lease), never ripple into neighbours.  This module provides the
chaos half of that contract: a deterministic, seeded :class:`FaultInjector`
that turns fault models into ``FAILURE``/``RECOVERY`` events on the
hypervisor's global timeline.

Determinism contract (mirrors :class:`repro.core.events.PoissonTraffic`):
``FaultInjector(seed=s).schedule(h)`` returns the byte-identical fault list
on every call and every platform — the stream is drawn from a private
``random.Random(seed)`` re-seeded per call, and fault kinds are iterated in
a fixed order.  Same seed ⇒ same fault schedule ⇒ replayable chaos runs
(``benchmarks/bench_chaos.py`` leans on this for its two-run determinism
acceptance bit).

Fault models:

* ``CORE_DEATH``   — a core becomes unplaceable (``ResourcePool.mark_failed``);
  the owning tenant is displaced and re-placed by the hypervisor.  Repairs
  after ``duration`` via a ``RECOVERY`` event when ``repair=True``.
* ``CORE_SLOW``    — a core degrades by ``factor`` (e.g. thermal throttling);
  visible to the engine's straggler probes (``VirtualEngine.core_slowdown``),
  which is exactly the detection path the paper's §6.4 crosstalk experiment
  exercises.  Always repairs after ``duration``.
* ``KV_CORRUPT``   — a cache page's content is suspect (the serving-side
  analogue: the batcher's page-table audit quarantines the page and the
  NaN sentinel catches poisoned logits).  Delivered to the executor as an
  event; no pool-level state change.
"""

from __future__ import annotations

import dataclasses
import enum
import random
from typing import List, Optional

from .events import EventKind, EventQueue


class FaultKind(enum.Enum):
    """What breaks.  Iteration order is part of the determinism contract —
    :meth:`FaultInjector.schedule` draws streams per kind in this order."""

    CORE_DEATH = "core_death"
    CORE_SLOW = "core_slow"
    KV_CORRUPT = "kv_corrupt"


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: what, where, when, and for how long.

    ``core`` is the victim core index (``CORE_DEATH``/``CORE_SLOW``);
    ``page`` the victim kv page (``KV_CORRUPT``); ``factor`` the slowdown
    multiplier (``CORE_SLOW``).  ``duration`` is seconds until the matching
    ``RECOVERY`` event (``None`` = permanent).  ``fid`` is the injector's
    stable per-schedule id, usable as a correlation key in logs."""

    time: float
    kind: FaultKind
    fid: int
    core: Optional[int] = None
    page: Optional[int] = None
    factor: float = 1.0
    duration: Optional[float] = None


class FaultInjector:
    """Seeded Poisson fault process over a pool of ``n_cores`` cores.

    Per-kind rates are events/second across the whole pool (a fault then
    picks its victim core/page uniformly).  ``schedule(horizon)`` is pure:
    the same injector produces the identical schedule every call.
    """

    def __init__(
        self,
        n_cores: int,
        *,
        seed: int = 0,
        death_rate: float = 0.0,
        slow_rate: float = 0.0,
        corrupt_rate: float = 0.0,
        n_kv_pages: int = 0,
        repair_after: Optional[float] = 2.0,
        slow_factor: float = 3.0,
        start: float = 0.0,
    ) -> None:
        if n_cores <= 0:
            raise ValueError(f"n_cores must be positive, got {n_cores}")
        for name, rate in (("death_rate", death_rate),
                           ("slow_rate", slow_rate),
                           ("corrupt_rate", corrupt_rate)):
            if rate < 0:
                raise ValueError(f"{name} must be >= 0, got {rate}")
        if corrupt_rate > 0 and n_kv_pages <= 0:
            raise ValueError("corrupt_rate > 0 needs n_kv_pages > 0")
        self.n_cores = n_cores
        self.n_kv_pages = n_kv_pages
        self.seed = seed
        self.rates = {
            FaultKind.CORE_DEATH: death_rate,
            FaultKind.CORE_SLOW: slow_rate,
            FaultKind.KV_CORRUPT: corrupt_rate,
        }
        self.repair_after = repair_after
        self.slow_factor = slow_factor
        self.start = start

    def schedule(self, horizon: float) -> List[FaultSpec]:
        """The deterministic fault schedule up to ``horizon``, time-ordered.

        Each kind draws an independent Poisson stream from one private
        ``random.Random(seed)`` in fixed ``FaultKind`` order, so adding a
        rate for one kind never perturbs another kind's stream timing
        *within* the same kind (streams are drawn sequentially — the
        contract is per-(seed, rates) determinism, not per-kind isolation).
        """
        rng = random.Random(self.seed)
        faults: List[FaultSpec] = []
        for kind in FaultKind:           # fixed iteration order
            rate = self.rates[kind]
            if rate <= 0:
                continue
            t = self.start
            while True:
                t += rng.expovariate(rate)
                if t > horizon:
                    break
                if kind is FaultKind.KV_CORRUPT:
                    victim_core, page = None, rng.randrange(self.n_kv_pages)
                else:
                    victim_core, page = rng.randrange(self.n_cores), None
                if kind is FaultKind.CORE_DEATH:
                    duration = self.repair_after
                elif kind is FaultKind.CORE_SLOW:
                    duration = self.repair_after if self.repair_after is not None else 2.0
                else:
                    duration = None      # corruption repairs by quarantine
                faults.append(FaultSpec(
                    time=t, kind=kind, fid=0, core=victim_core, page=page,
                    factor=self.slow_factor if kind is FaultKind.CORE_SLOW else 1.0,
                    duration=duration,
                ))
        faults.sort(key=lambda f: f.time)
        return [dataclasses.replace(f, fid=i) for i, f in enumerate(faults)]

    def inject(self, queue: EventQueue, horizon: float) -> List[FaultSpec]:
        """Push the schedule onto ``queue`` as ``FAILURE`` events (plus a
        ``RECOVERY`` per repairable fault at ``time + duration``) and return
        it.  The hypervisor resolves the victim *tenant* at handling time —
        whoever owns the core when the fault fires."""
        faults = self.schedule(horizon)
        for f in faults:
            queue.schedule(EventKind.FAILURE, f.time, fault=f)
            if f.duration is not None and f.time + f.duration <= horizon:
                queue.schedule(EventKind.RECOVERY, f.time + f.duration, fault=f)
        return faults
