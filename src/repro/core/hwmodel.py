"""Hardware models for the latency simulator and the roofline machinery.

The paper's latency simulator (Eqs. 2-3) is parameterized by the accelerator's
compute parallelism and off-chip bandwidth.  We keep that structure but make it
generic over a :class:`HardwareModel`, with two concrete instantiations:

* :func:`fpga_core` — the paper's Angel-Eye-style ISA accelerator core on a
  Xilinx U200/VU9P: ``Parallelism = 2 * PP * ICP * OCP`` OPs/cycle @ 300 MHz,
  128-bit DDR port per small core (4 small cores share one 512-bit DDR bank).
  Used by the *faithful reproduction* benchmarks (Tables 2-3, Figs. 5-7).

* :func:`tpu_v5e_chip` — one TPU v5e chip: 197 TFLOP/s bf16, 819 GB/s HBM,
  ~50 GB/s/link ICI.  Used by the LM-serving virtualization stack and the
  roofline analysis.

A "core" is the basic shareable unit of the hardware resource pool (HRP): a
small FPGA core in the paper, a TPU chip in the adaptation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

# ---------------------------------------------------------------------------
# Generic hardware model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Per-core performance model of the basic shareable unit.

    Attributes
    ----------
    name:            human-readable identifier.
    flops_per_sec:   peak OPs/s of one core (MACs count as 2 OPs).
    mem_bw:          off-chip bandwidth of one core, bytes/s.
    bw_eff:          achievable fraction of ``mem_bw`` (paper Eq. 3 ``eff``).
    link_bw:         inter-core interconnect bandwidth, bytes/s (ICI on TPU;
                     on the FPGA the cores only synchronize, so this only
                     prices the sync signal and is effectively irrelevant).
    sync_latency:    fixed cost of a layer-wise multi-core barrier, seconds.
    instr_overhead:  fixed issue cost per instruction, seconds.
    compute_tile:    (PP, ICP, OCP)-like quantization of the compute unit.
                     Work is rounded up to multiples of each tile dim, which
                     models the utilization cliff of wide cores on narrow
                     layers (the reason the paper's 16x512 multi-core beats
                     the 1x8192 single core on ResNet50).
    vmem_bytes:      on-chip memory (BRAM/URAM pool, or VMEM on TPU).
    """

    name: str
    flops_per_sec: float
    mem_bw: float
    bw_eff: float = 0.85
    link_bw: float = 0.0
    sync_latency: float = 1e-6
    instr_overhead: float = 0.0
    compute_tile: Tuple[int, int, int] = (1, 1, 1)
    vmem_bytes: int = 0

    # -- Eq. 2 (generalized): compute time with tile quantization ----------
    def compute_time(self, flops: float, shape: Tuple[int, int, int] | None = None) -> float:
        """Time to execute ``flops`` OPs on one core.

        ``shape`` is the (pixels, in_channels, out_channels) extent of the
        work; when given, each dim is rounded up to the matching compute-tile
        multiple before the peak-rate division, reproducing Eq. 2's
        ``ceil(C_in/ICP) * ceil(C_out/OCP) * ...`` quantization.
        """
        if shape is not None:
            util = 1.0
            for extent, tile in zip(shape, self.compute_tile):
                if extent:   # 0 ⇒ dim not quantized (e.g. depthwise has no
                    util *= extent / (math.ceil(extent / tile) * tile)  # ICP)
            eff_flops = self.flops_per_sec * max(util, 1e-9)
        else:
            eff_flops = self.flops_per_sec
        return flops / eff_flops + self.instr_overhead

    # -- Eq. 3: data-movement time ------------------------------------------
    def memory_time(self, nbytes: float) -> float:
        return nbytes / (self.mem_bw * self.bw_eff) + self.instr_overhead

    def link_time(self, nbytes: float) -> float:
        if self.link_bw <= 0:
            return self.sync_latency
        return nbytes / self.link_bw + self.sync_latency

    def scaled(self, factor: float, name: str | None = None) -> "HardwareModel":
        """A core with ``factor``x compute and bandwidth (for ablations such
        as the paper's MobileNet 2x-bandwidth experiment)."""
        return dataclasses.replace(
            self,
            name=name or f"{self.name}-x{factor:g}",
            flops_per_sec=self.flops_per_sec * factor,
            mem_bw=self.mem_bw * factor,
        )

    def with_bandwidth(self, factor: float) -> "HardwareModel":
        return dataclasses.replace(
            self, name=f"{self.name}-bw{factor:g}x", mem_bw=self.mem_bw * factor
        )


# ---------------------------------------------------------------------------
# Paper constants — Angel-Eye-style FPGA core
# ---------------------------------------------------------------------------

FPGA_FREQ_HZ = 300e6  # all accelerators run at 300 MHz (paper §6.1)


def _split_parallelism(parallelism: int) -> Tuple[int, int, int]:
    """Pick (PP, ICP, OCP) with 2*PP*ICP*OCP == parallelism.

    Follows Angel-Eye practice: grow OCP first, then ICP, then PP, keeping
    OCP >= ICP >= PP.  parallelism must be a power of two >= 16.
    """
    assert parallelism >= 16 and (parallelism & (parallelism - 1)) == 0, parallelism
    budget = parallelism // 2  # PP*ICP*OCP
    pp, icp, ocp = 1, 1, 1
    # round-robin growth OCP -> ICP -> PP yields OCP >= ICP >= PP
    dims = ["ocp", "icp", "pp"]
    i = 0
    while pp * icp * ocp < budget:
        d = dims[i % 3]
        if d == "ocp":
            ocp *= 2
        elif d == "icp":
            icp *= 2
        else:
            pp *= 2
        i += 1
    return pp, icp, ocp


# Calibrated against the paper's measured ResNet50 row (Table 3) — see
# benchmarks/bench_calibration.py for the fit.  Real conv dataflows reach
# well under peak (im2col padding, pixel-edge stalls, DDR latency), which is
# exactly why the paper's 16x512 pool beats the 1x8192 core.
FPGA_COMPUTE_EFF = 0.48   # achieved fraction of 2*PP*ICP*OCP peak
FPGA_BW_EFF = 0.32        # achieved fraction of DDR port bandwidth


def fpga_core(
    parallelism: int = 512,
    ddr_port_bits: int = 128,
    *,
    compute_eff: float = FPGA_COMPUTE_EFF,
    bw_eff: float = FPGA_BW_EFF,
) -> HardwareModel:
    """One core of the paper's ISA-based CNN accelerator.

    parallelism:   OPs/cycle (= 2*PP*ICP*OCP, Eq. 1).  512 for a small core,
                   8192 for the static single large core.
    ddr_port_bits: DDR data-port width available to this core.  128 bits for a
                   small core; the single large core gets four 512-bit banks.
    """
    pp, icp, ocp = _split_parallelism(parallelism)
    return HardwareModel(
        name=f"fpga-{parallelism}",
        flops_per_sec=parallelism * FPGA_FREQ_HZ * compute_eff,
        mem_bw=ddr_port_bits / 8 * FPGA_FREQ_HZ,
        bw_eff=bw_eff,
        link_bw=0.0,
        sync_latency=2e-6,      # sync_local/sync_global handshake
        instr_overhead=40e-9,   # ~12 cycles instruction issue
        compute_tile=(pp, icp, ocp),
        vmem_bytes=4 << 20,     # BRAM+URAM pool of one small core, ~4 MiB
    )


def fpga_small_core() -> HardwareModel:
    """Basic shareable unit used in the paper's virtualized design (16x512)."""
    return fpga_core(parallelism=512, ddr_port_bits=128)


def fpga_large_core() -> HardwareModel:
    """Static single-core baseline (8192 parallelism, 4 DDR banks)."""
    return fpga_core(parallelism=8192, ddr_port_bits=4 * 512)


# ---------------------------------------------------------------------------
# TPU v5e constants (roofline targets per the brief)
# ---------------------------------------------------------------------------

TPU_V5E_PEAK_FLOPS = 197e12  # bf16 OPs/s per chip
TPU_V5E_HBM_BW = 819e9       # bytes/s per chip
TPU_V5E_ICI_BW = 50e9        # bytes/s per link (~)
TPU_V5E_VMEM = 128 << 20     # ~128 MiB VMEM per chip


def tpu_v5e_chip() -> HardwareModel:
    return HardwareModel(
        name="tpu-v5e",
        flops_per_sec=TPU_V5E_PEAK_FLOPS,
        mem_bw=TPU_V5E_HBM_BW,
        bw_eff=0.90,
        link_bw=TPU_V5E_ICI_BW,
        sync_latency=5e-6,
        instr_overhead=1e-6,   # per-program dispatch overhead
        compute_tile=(8, 128, 128),  # MXU-ish (sublane, lane, lane) tiling
        vmem_bytes=TPU_V5E_VMEM,
    )
