from .steps import TrainerConfig, make_loss_fn, make_train_step

__all__ = ["TrainerConfig", "make_loss_fn", "make_train_step"]
