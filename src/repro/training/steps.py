"""Step functions: loss, train_step, with remat/accumulation/compression.

``make_train_step`` builds the jit-able step for one (arch, trainer) config:

    params, opt_state, metrics = train_step(params, opt_state, batch)

Features (all config-selected, all exercised by tests):
  * family-aware loss (vlm patch embeddings, audio encoder, MoE aux loss)
  * remat policy over the period body ("none" | "dots" | "full")
  * gradient accumulation (lax.scan over microbatches, f32 accumulator)
  * global-norm clipping, AdamW (f32 or 8-bit moments), LR schedules
  * optional int8-compressed cross-pod gradient all-reduce via partial-manual
    shard_map (axis_names={"pod"}) — the inter-pod links are the slow ones,
    and this is the distributed-optimization trick the roofline's
    collective-bound cells care about.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import encoder_forward, forward, lm_loss
from repro.optim import adamw_update, clip_by_global_norm
from repro.optim.schedules import SCHEDULES


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    lr: float = 3e-4
    schedule: str = "constant"
    warmup: int = 100
    total_steps: int = 1000
    b1: float = 0.9
    b2: float = 0.95
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    aux_coef: float = 0.01          # MoE load-balance loss weight
    quantize_opt: bool = False      # 8-bit AdamW moments
    remat: str = "none"             # none | dots | full
    grad_accum: int = 1
    compress_pods: bool = False     # int8 cross-pod grad all-reduce
    attn_impl: str = "xla"          # xla | pallas | naive
    loss_chunk: int = 1024

    def lr_fn(self):
        sched = SCHEDULES[self.schedule]
        if self.schedule == "constant":
            return sched(self.lr)
        return sched(self.lr, self.warmup, self.total_steps)


def _forward_kwargs(cfg, batch: Dict[str, Any], *, impl, policy, remat):
    kw = dict(impl=impl, policy=policy, remat=remat)
    if cfg.family == "vlm":
        kw["extra_embeds"] = batch["extra_embeds"]
        kw["positions"] = batch["positions"]
    return kw


def make_loss_fn(cfg, tcfg: TrainerConfig, *, policy=None):
    """loss_fn(params, batch) -> (loss, metrics)."""

    def loss_fn(params, batch):
        kw = _forward_kwargs(cfg, batch, impl=tcfg.attn_impl, policy=policy,
                             remat=tcfg.remat)
        if cfg.family == "audio":
            kw["enc_out"] = encoder_forward(
                params, batch["frames"], cfg, impl=tcfg.attn_impl,
                policy=policy, remat=tcfg.remat,
            )
        out = forward(params, batch["tokens"], cfg, **kw)
        sum_loss, count = lm_loss(
            params, out.hidden, batch["labels"], cfg,
            chunk=tcfg.loss_chunk, policy=policy,
        )
        loss = sum_loss / jnp.maximum(count.astype(jnp.float32), 1.0)
        total = loss + tcfg.aux_coef * out.aux
        metrics = {"loss": loss, "aux": out.aux, "tokens": count}
        return total, metrics

    return loss_fn


def _accumulate_grads(loss_fn, params, batch, n_accum: int):
    """lax.scan over microbatches; f32 grad accumulator."""

    def split(x):
        if x.ndim == 0:
            return x
        # positions (3, B, S) carry batch on axis 1
        axis = 1 if x.ndim == 3 and x.shape[0] == 3 and x.dtype == jnp.int32 else 0
        B = x.shape[axis]
        mb = B // n_accum
        if axis == 0:
            return x.reshape(n_accum, mb, *x.shape[1:])
        return jnp.moveaxis(x.reshape(x.shape[0], n_accum, mb, *x.shape[2:]), 1, 0)

    split_batch = jax.tree.map(split, batch)

    def body(carry, mb):
        g_acc, loss_acc, tok_acc, aux_acc = carry
        (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (g_acc, loss_acc + metrics["loss"], tok_acc + metrics["tokens"],
                aux_acc + metrics["aux"]), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (g, loss, toks, aux), _ = jax.lax.scan(
        body, (g0, jnp.float32(0.0), jnp.int32(0), jnp.float32(0.0)), split_batch
    )
    g = jax.tree.map(lambda x: x / n_accum, g)
    return g, {"loss": loss / n_accum, "aux": aux / n_accum, "tokens": toks}


def make_train_step(cfg, tcfg: TrainerConfig, *, policy=None, mesh=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With ``tcfg.compress_pods`` and a mesh that has a "pod" axis, gradients
    are computed per-pod under partial-manual shard_map and combined with the
    int8 wire (distributed.compression); otherwise GSPMD's implicit all-reduce
    handles cross-pod combination.
    """
    loss_fn = make_loss_fn(cfg, tcfg, policy=policy)
    lr_fn = tcfg.lr_fn()

    def compute_grads(params, batch):
        if tcfg.grad_accum > 1:
            return _accumulate_grads(loss_fn, params, batch, tcfg.grad_accum)
        (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return g, metrics

    use_compressed = (
        tcfg.compress_pods and mesh is not None and "pod" in mesh.axis_names
        and mesh.shape["pod"] > 1
    )
    if use_compressed:
        from jax.sharding import PartitionSpec as P

        from repro.distributed.compression import pod_psum_compressed

        def per_pod(params, batch):
            g, metrics = compute_grads(params, batch)
            zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), g)
            g, _ = pod_psum_compressed(g, zeros, axis="pod")
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
            return g, metrics

        def grads_entry(params, batch):
            batch_specs = jax.tree.map(
                lambda x: P("pod") if getattr(x, "ndim", 0) and x.shape[0] != 3 else P(None, "pod"),
                batch,
            )
            return jax.shard_map(
                per_pod,
                mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P(), params), batch_specs),
                out_specs=(jax.tree.map(lambda _: P(), params), P()),
                axis_names={"pod"},
                check_vma=False,
            )(params, batch)
    else:
        grads_entry = compute_grads

    def train_step(params, opt_state, batch):
        grads, metrics = grads_entry(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        lr = lr_fn(opt_state.step)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=lr, b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay, quantized=tcfg.quantize_opt,
        )
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step
