"""Architecture/shape registry.

``get_config(arch)`` / ``get_reduced(arch)`` return the full and smoke-test
configs; ``SHAPES`` holds the four assigned input-shape cells; ``CELLS``
enumerates the 40 (arch x shape) dry-run cells with their run/skip status.
"""

from __future__ import annotations

from typing import List, Tuple

from .base import ModelConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES, round_up

from . import (
    command_r_plus_104b,
    qwen3_0_6b,
    starcoder2_7b,
    qwen3_32b,
    deepseek_moe_16b,
    mixtral_8x22b,
    mamba2_370m,
    jamba_1_5_large_398b,
    qwen2_vl_72b,
    whisper_base,
)

_MODULES = {
    "command-r-plus-104b": command_r_plus_104b,
    "qwen3-0.6b": qwen3_0_6b,
    "starcoder2-7b": starcoder2_7b,
    "qwen3-32b": qwen3_32b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "mixtral-8x22b": mixtral_8x22b,
    "mamba2-370m": mamba2_370m,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "qwen2-vl-72b": qwen2_vl_72b,
    "whisper-base": whisper_base,
}

ARCHS: List[str] = list(_MODULES.keys())


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    return _MODULES[arch].CONFIG


def get_reduced(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    return _MODULES[arch].REDUCED


def cell_status(arch: str, shape: str) -> Tuple[bool, str]:
    """(runs, reason).  long_500k only runs for sub-quadratic-decode archs."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k KV decode is quadratic-family (DESIGN.md skip)"
    return True, ""


def cells() -> List[dict]:
    """All 40 (arch x shape) cells with run/skip status."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            runs, reason = cell_status(arch, shape)
            out.append({"arch": arch, "shape": shape, "runs": runs, "reason": reason})
    return out


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
    "ARCHS", "get_config", "get_reduced", "cells", "cell_status", "round_up",
]
