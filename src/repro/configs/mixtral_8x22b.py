"""mixtral-8x22b — MoE 8 experts top-2 with sliding-window attention.
[arXiv:2401.04088]

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2, SWA.
SWA ⇒ O(window) decode KV ⇒ long_500k runs (window-clipped cache).
"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32_768,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    moe=MoEConfig(
        n_experts=8,
        top_k=2,
        expert_d_ff=16384,
        n_shared_experts=0,
        capacity_factor=1.25,
        every=1,
    ),
    subquadratic=True,   # sliding window bounds decode KV
    notes="8 experts top-2; sliding-window attention (window=4096)",
)

REDUCED = ModelConfig(
    name="mixtral-8x22b-reduced",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    sliding_window=64,
    moe=MoEConfig(capacity_factor=8.0, n_experts=4, top_k=2, expert_d_ff=256, every=1),
    subquadratic=True,
    notes="smoke-test reduction of mixtral-8x22b",
)
