"""qwen2-vl-72b — VLM transformer backbone with M-RoPE. [arXiv:2409.12191]

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
Per the brief the modality frontend is a STUB: ``input_specs()`` supplies
precomputed patch embeddings + 3D (temporal, h, w) position ids for M-RoPE.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152_064,
    m_rope=True,
    rope_theta=1_000_000.0,
    subquadratic=False,
    notes="M-RoPE (3D positions), dynamic-resolution frontend stubbed",
)

REDUCED = ModelConfig(
    name="qwen2-vl-72b-reduced",
    family="vlm",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=384,
    vocab=512,
    m_rope=True,
    rope_theta=1_000_000.0,
    notes="smoke-test reduction of qwen2-vl-72b",
)
