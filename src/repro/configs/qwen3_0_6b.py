"""qwen3-0.6b — dense GQA with qk_norm. [hf:Qwen/Qwen3-8B family]

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab=151_936,
    d_head=128,            # qwen3 uses d_head=128 (> d_model/n_heads)
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    subquadratic=False,
    notes="qk_norm (RMSNorm on q/k per-head), GQA kv=8",
)

REDUCED = ModelConfig(
    name="qwen3-0.6b-reduced",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=384,
    vocab=512,
    d_head=32,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    notes="smoke-test reduction of qwen3-0.6b",
)
