"""starcoder2-7b — dense GQA, RoPE. [arXiv:2402.19173]

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
36 heads % 16 != 0 → sharding rules use sequence-sharded attention on the
16-way model axis (see repro.distributed.sharding).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49_152,
    mlp_kind="gelu",        # StarCoder2 uses a plain 2-matrix GELU MLP
    qk_norm=False,
    rope_theta=100_000.0,
    subquadratic=False,
    notes="GQA kv=4, RoPE; 36 heads not divisible by 16-way model axis",
)

REDUCED = ModelConfig(
    name="starcoder2-7b-reduced",
    family="dense",
    n_layers=2,
    d_model=144,            # keeps the 36-head flavour: 6 heads x 24
    n_heads=6,
    n_kv_heads=2,
    d_ff=576,
    vocab=512,
    mlp_kind="gelu",
    rope_theta=100_000.0,
    notes="smoke-test reduction of starcoder2-7b",
)
