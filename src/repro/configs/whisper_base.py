"""whisper-base — encoder-decoder with conv frontend (stubbed).
[arXiv:2212.04356]

6L (decoder) d_model=512 8H d_ff=2048 vocab=51865; 6 encoder layers.
The conv frontend is a STUB per the brief: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, 1500, d_model).
Full attention + enc-dec ⇒ long_500k skipped.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,            # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51_865,
    mlp_kind="gelu",        # Whisper uses a plain 2-matrix GELU MLP
    rope_theta=0.0,        # whisper uses learned/sinusoidal positions; we use
                           # sinusoidal added at embed (no RoPE)
    n_enc_layers=6,
    enc_seq=1500,          # 30 s of audio at 50 Hz after the conv stub
    subquadratic=False,
    notes="enc-dec; conv frontend stub; sinusoidal positions (no RoPE)",
)

REDUCED = ModelConfig(
    name="whisper-base-reduced",
    family="audio",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab=512,
    mlp_kind="gelu",
    rope_theta=0.0,
    n_enc_layers=2,
    enc_seq=64,
    notes="smoke-test reduction of whisper-base",
)
