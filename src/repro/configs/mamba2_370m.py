"""mamba2-370m — attention-free SSM with state-space duality (SSD).
[arXiv:2405.21060]

48L d_model=1024 (attn-free) vocab=50280, ssm_state=128.
O(1) decode state ⇒ long_500k runs.
"""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,           # unused (attention-free); kept for uniform plumbing
    n_kv_heads=1,
    d_ff=0,
    vocab=50_280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
    subquadratic=True,
    notes="SSD (state-space duality); attention-free; O(1) decode state",
)

REDUCED = ModelConfig(
    name="mamba2-370m-reduced",
    family="ssm",
    n_layers=2,
    d_model=128,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab=512,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, n_groups=1, chunk=32),
    subquadratic=True,
    notes="smoke-test reduction of mamba2-370m",
)
