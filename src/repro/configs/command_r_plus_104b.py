"""command-r-plus-104b — dense GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01]

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.
Pure full attention → long_500k is skipped (DESIGN.md §Arch-applicability).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256_000,
    qk_norm=False,
    rope_theta=75_000.0,
    tie_embeddings=True,   # Cohere ties input/output embeddings
    subquadratic=False,
    notes="GQA kv=8, no biases, tied embeddings",
)

REDUCED = ModelConfig(
    name="command-r-plus-104b-reduced",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=352,
    vocab=512,
    rope_theta=75_000.0,
    tie_embeddings=True,
    notes="smoke-test reduction of command-r-plus-104b",
)
