"""qwen3-32b — dense GQA with qk_norm. [hf:Qwen/Qwen3-8B family]

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab=151_936,
    d_head=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    subquadratic=False,
    notes="qk_norm, GQA kv=8",
)

REDUCED = ModelConfig(
    name="qwen3-32b-reduced",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab=512,
    d_head=32,
    qk_norm=True,
    rope_theta=1_000_000.0,
    notes="smoke-test reduction of qwen3-32b",
)
