"""Config system: model architectures and input-shape cells.

Every assigned architecture is a :class:`ModelConfig`; every benchmark/dry-run
cell pairs one with a :class:`ShapeConfig`.  Configs are plain frozen
dataclasses — no magic, serializable, diffable — and carry *derived* helpers
(param counts, padded dims) used by the sharding rules and roofline analysis.

Padding policy (recorded per-arch in DESIGN.md):
  * ``vocab_padded`` rounds the embedding table up to a multiple of 512 so the
    vocab dim shards evenly over the 16-way "model" mesh axis (standard
    practice, cf. GPT-NeoX / Megatron).  Logits of padded slots are never
    selected by the data pipeline (labels are always < vocab).
  * Head counts are *not* padded; when ``n_heads % model_axis != 0`` the
    sharding rules fall back to sequence-sharded attention (see
    ``repro.distributed.sharding``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int            # routed experts
    top_k: int
    expert_d_ff: int          # d_ff of each routed expert
    n_shared_experts: int = 0  # always-on experts (DeepSeek-MoE style)
    shared_d_ff: int = 0       # d_ff of each shared expert
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    every: int = 1             # MoE replaces the MLP every `every`-th layer


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block hyperparameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256           # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  ``family`` selects the block wiring:

    dense  — attention + MLP every layer
    moe    — attention + MoE (per MoEConfig.every)
    ssm    — Mamba-2 (SSD) blocks only, attention-free
    hybrid — Mamba-2 with attention every ``attn_every``-th layer (+ MoE)
    vlm    — dense backbone with M-RoPE and a patch-embedding stub input
    audio  — encoder-decoder (Whisper-style) with a conv-frontend stub
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None     # defaults to d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    m_rope: bool = False             # multimodal 3D RoPE (Qwen2-VL)
    sliding_window: Optional[int] = None   # SWA width (Mixtral)
    mlp_kind: str = "swiglu"         # swiglu (3·d·dff) | gelu (2·d·dff)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 1              # hybrid: 1 attention per this many layers
    # encoder-decoder (audio family):
    n_enc_layers: int = 0
    enc_seq: int = 0                 # encoder frame count (frontend stub output)
    # numerics
    dtype: str = "bfloat16"
    # long_500k applicability: sub-quadratic decode memory?
    subquadratic: bool = False
    notes: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.d_head is None:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.family in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"), self.family
        if self.family in ("moe",):
            assert self.moe is not None
        if self.family in ("ssm", "hybrid"):
            assert self.ssm is not None

    # -- derived dims ---------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, 512)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid wiring: one attention layer per ``attn_every`` block,
        placed at the *end* of the block (Jamba puts attn mid-block; end-of-
        block keeps the scan structure identical — noted in DESIGN.md)."""
        if self.family in ("ssm",):
            return False
        if self.family != "hybrid":
            return True
        return (i + 1) % self.attn_every == 0

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return (i + 1) % self.moe.every == 0

    # -- parameter counting (analytic; cross-checked vs pytree in tests) --
    def _attn_params(self) -> int:
        qkv = self.d_model * (self.q_dim + 2 * self.kv_dim)
        out = self.q_dim * self.d_model
        qknorm = 2 * self.d_head if self.qk_norm else 0
        return qkv + out + qknorm

    def _mlp_params(self, d_ff: Optional[int] = None) -> int:
        d_ff = self.d_ff if d_ff is None else d_ff
        per = 3 if self.mlp_kind == "swiglu" else 2   # gate+up+down | up+down
        return per * self.d_model * d_ff

    def _moe_params(self) -> Tuple[int, int]:
        """(total, active) params of one MoE layer."""
        m = self.moe
        assert m is not None
        router = self.d_model * m.n_experts
        routed = m.n_experts * 3 * self.d_model * m.expert_d_ff
        shared = m.n_shared_experts * 3 * self.d_model * (m.shared_d_ff or m.expert_d_ff)
        total = router + routed + shared
        active = (
            router
            + m.top_k * 3 * self.d_model * m.expert_d_ff
            + m.n_shared_experts * 3 * self.d_model * (m.shared_d_ff or m.expert_d_ff)
        )
        return total, active

    def _ssm_params(self) -> int:
        s = self.ssm
        assert s is not None
        d_in = s.d_inner(self.d_model)
        nh = s.n_ssm_heads(self.d_model)
        d_bc = 2 * s.n_groups * s.d_state
        in_proj = self.d_model * (2 * d_in + d_bc + nh)   # z, x, B, C, dt
        conv = (d_in + d_bc) * s.d_conv
        out_proj = d_in * self.d_model
        extras = nh * 2 + d_in                            # A_log, D, norm
        return in_proj + conv + out_proj + extras

    def param_count(self, *, active_only: bool = False) -> int:
        """Analytic parameter count (embeddings included once; norms ignored
        at <0.01%).  ``active_only`` counts routed experts at top_k (MoE
        6*N_active*D roofline convention)."""
        n = 0
        emb = self.vocab_padded * self.d_model
        n += emb if self.tie_embeddings else 2 * emb
        layers = self.n_layers
        for i in range(layers):
            if self.family in ("ssm", "hybrid") and not self.is_attn_layer(i):
                n += self._ssm_params()
            else:
                n += self._attn_params()
            if self.family == "ssm":
                continue  # mamba block has no separate MLP
            if self.is_moe_layer(i):
                total, active = self._moe_params()
                n += active if active_only else total
            else:
                n += self._mlp_params()
        # encoder stack (audio family): attention + MLP, cross-attn in decoder
        if self.family == "audio":
            enc = self.n_enc_layers * (self._attn_params() + self._mlp_params())
            cross = self.n_layers * self._attn_params()   # decoder cross-attn
            n += enc + cross
        return n

    def flops_per_token(self, *, seq_len: int = 0) -> float:
        """Forward matmul FLOPs per token ~= 2 * N_active (+ attention)."""
        n_active = self.param_count(active_only=True)
        f = 2.0 * n_active
        if seq_len and self.family not in ("ssm",):
            attn_layers = sum(1 for i in range(self.n_layers) if self.is_attn_layer(i))
            ctx = min(seq_len, self.sliding_window) if self.sliding_window else seq_len
            f += attn_layers * 2.0 * 2.0 * ctx * self.q_dim   # QK^T + AV
        return f


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell.  ``kind`` picks the lowered step:
    train → train_step; prefill → prefill_step; decode → serve_step."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode")

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}
