"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with MoE.
[arXiv:2403.19887]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
KV only in 1/8 layers ⇒ long_500k runs (9 attention layers of KV).
"""

from .base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65_536,
    rope_theta=10_000.0,   # Jamba uses no RoPE on attn layers; we keep RoPE
                           # (positional handling noted in DESIGN.md)
    attn_every=8,          # 1 attention layer per 8 (7 mamba : 1 attn)
    moe=MoEConfig(
        n_experts=16,
        top_k=2,
        expert_d_ff=24576,
        n_shared_experts=0,
        capacity_factor=1.25,
        every=2,           # MoE replaces MLP every other layer
    ),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=128, n_groups=8, chunk=256),
    subquadratic=True,
    notes="Mamba+attn 1:7 interleave; MoE 16e top-2 every other layer",
)

REDUCED = ModelConfig(
    name="jamba-1.5-large-398b-reduced",
    family="hybrid",
    n_layers=4,            # one 1:3 hybrid block x 2 for the smoke test
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    attn_every=4,
    moe=MoEConfig(capacity_factor=8.0, n_experts=4, top_k=2, expert_d_ff=256, every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, n_groups=2, chunk=32),
    subquadratic=True,
    notes="smoke-test reduction of jamba-1.5-large-398b",
)
