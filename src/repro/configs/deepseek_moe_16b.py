"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6.
[arXiv:2401.06066]

28L d_model=2048 16H (GQA kv=16 = MHA) d_ff=1408 vocab=102400, MoE 64e top-6.
"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,          # dense-equivalent per-expert width
    vocab=102_400,
    rope_theta=10_000.0,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        expert_d_ff=1408,
        n_shared_experts=2,
        shared_d_ff=1408,
        capacity_factor=1.25,
        every=1,
    ),
    subquadratic=False,
    notes="2 shared + 64 routed top-6 fine-grained experts",
)

REDUCED = ModelConfig(
    name="deepseek-moe-16b-reduced",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab=512,
    moe=MoEConfig(capacity_factor=8.0, 
        n_experts=8,
        top_k=2,
        expert_d_ff=96,
        n_shared_experts=2,
        shared_d_ff=96,
        every=1,
    ),
    notes="smoke-test reduction of deepseek-moe-16b",
)
