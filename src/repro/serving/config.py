"""Unified serving configuration: one validated dataclass for the batcher.

``ContinuousBatcher`` grew one boolean/kwarg per feature as the serving
stack accreted modes — ``paged=``, ``prefix_cache=``, ``reserve_pages=``,
``audit=``, ``watchdog_s=``, and now ``speculative=``/``overlap=``.  Twelve
orthogonal-looking knobs are not orthogonal: the prefix cache rides on the
paged pool, speculation needs a verify-capable attention impl, the audit
reads paged tables.  :class:`ServingConfig` is the single place those
cross-field rules live, checked **at construction** against
:data:`~repro.models.attention.ATTN_CAPABILITIES` — the same fail-at-build
discipline as the paper's static compilation stage: invalid combinations
die before any program is traced, not three layers into a jit.

Model-dependent rules (pure-attention archs for prefix/speculative,
sliding-window gating) still live in ``ContinuousBatcher.__init__`` where
the model config is known.

The legacy kwargs constructor is kept as a thin deprecation shim::

    ContinuousBatcher(params, cfg, ServingConfig(slots=4, ...))   # new
    ContinuousBatcher(params, cfg, slots=4, ...)                  # shim,
                                                  # DeprecationWarning
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.models.attention import check_attn_impl


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Everything a :class:`~repro.serving.batcher.ContinuousBatcher` needs
    beyond (params, model cfg, policy, clock).

    Core shape:
      slots        — fixed decode batch (XLA shape requirement)
      prompt_len   — prompt bucket: prompts are left-padded to this length
      max_len      — per-slot cache capacity (prompt + decode budget)
      attn_impl    — "xla" | "pallas" | "naive" (capability-checked per mode)
      chunk        — max decode steps fused per device dispatch

    Paged KV pool (``paged=True``):
      page_size / n_pages / page_quota / reserve_pages — see
      ``serving.batcher`` module docs.  ``prefix_cache`` (bool or a shared
      ``PrefixCache`` instance) rides on the pool.

    Fault guards: ``watchdog_s`` (wall-time bound per chunk), ``audit``
    (page-table self-check; paged mode only, silently inert otherwise —
    shim compatibility).

    Speculative decoding (``speculative=True``): the chunk scan drafts
    ``draft_window - 1`` tokens per slot from an on-device n-gram history
    (``draft_ngram`` match length over the last ``draft_hist`` committed
    tokens) and verifies the whole window in one multi-query pass —
    token-identical to greedy decode by construction.  Requires a greedy,
    pure-attention, non-sliding-window setup and a verify-capable
    ``attn_impl``.

    ``overlap=True`` dispatches admission prefill concurrently with the
    in-flight decode chunk (one merge point per round) so prefill-heavy
    traffic overlaps host work with device decode instead of serializing.

    ``tp`` (tensor parallel width, default 1) shards the decode over a flat
    ``("tp",)`` device mesh: attention heads and MLP features split across
    the tenant's leased devices, slot bookkeeping replicated, two psums per
    layer.  ``tp > 1`` requires ``attn_impl="xla"`` (the Pallas kernels are
    single-device) and a pure-attention dense-MLP arch (checked against the
    model config in ``ContinuousBatcher.__init__``).
    """

    slots: int
    prompt_len: int
    max_len: int
    attn_impl: str = "xla"
    chunk: int = 8
    # paged KV pool
    paged: bool = False
    page_size: int = 16
    n_pages: Optional[int] = None
    page_quota: Optional[int] = None
    reserve_pages: bool = True
    prefix_cache: Any = None          # bool | PrefixCache | None
    # fault guards
    watchdog_s: Optional[float] = None
    audit: bool = False
    # speculative decode + admission/decode overlap
    speculative: bool = False
    draft_window: int = 4
    draft_ngram: int = 2
    draft_hist: int = 64
    overlap: bool = False
    # tensor-parallel width (devices per tenant sub-mesh)
    tp: int = 1

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.prompt_len < 1:
            raise ValueError(
                f"prompt_len must be >= 1, got {self.prompt_len}")
        if self.max_len <= self.prompt_len:
            raise ValueError(
                f"max_len ({self.max_len}) must exceed prompt_len "
                f"({self.prompt_len}) — there is no room to decode")
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        if self.tp < 1:
            raise ValueError(f"tp must be >= 1, got {self.tp}")
        if self.tp > 1 and self.attn_impl != "xla":
            raise ValueError(
                f"tp={self.tp} requires attn_impl='xla' (the "
                f"{self.attn_impl!r} kernels are single-device)")
        # one shared capability table gates every mode this config will
        # exercise, at construction (models.attention.ATTN_CAPABILITIES)
        check_attn_impl(self.attn_impl, "dense")
        if self.paged:
            check_attn_impl(self.attn_impl, "paged")
            if self.page_size < 1:
                raise ValueError(
                    f"page_size must be >= 1, got {self.page_size}")
            if self.n_pages is not None and self.n_pages < 1:
                raise ValueError(
                    f"n_pages must be >= 1, got {self.n_pages}")
        if self.prefix_cache:
            if not self.paged:
                raise ValueError("the prefix cache rides on the paged pool; "
                                 "pass paged=True")
            check_attn_impl(self.attn_impl, "prefix")
        if self.speculative:
            check_attn_impl(self.attn_impl, "verify")
            if self.draft_window < 2:
                raise ValueError(
                    f"draft_window must be >= 2 (one committed token plus "
                    f"at least one draft), got {self.draft_window}")
            if self.draft_ngram < 1:
                raise ValueError(
                    f"draft_ngram must be >= 1, got {self.draft_ngram}")
            if self.draft_hist < self.draft_ngram + self.draft_window:
                raise ValueError(
                    f"draft_hist ({self.draft_hist}) must hold at least "
                    f"draft_ngram + draft_window "
                    f"({self.draft_ngram + self.draft_window}) tokens")


def config_from_legacy_kwargs(**kwargs) -> ServingConfig:
    """Map the pre-:class:`ServingConfig` ``ContinuousBatcher`` kwargs onto
    a config.  Raises ``TypeError`` on unknown names so a typo'd kwarg
    fails like it always did instead of being swallowed."""
    fields = {f.name for f in dataclasses.fields(ServingConfig)}
    unknown = sorted(set(kwargs) - fields)
    if unknown:
        import difflib

        hints = []
        for name in unknown:
            close = difflib.get_close_matches(name, fields, n=1)
            if close:
                hints.append(f"{name!r} (did you mean {close[0]!r}?)")
            else:
                hints.append(repr(name))
        raise TypeError(
            f"unknown ContinuousBatcher argument(s): {', '.join(hints)}; "
            f"valid ServingConfig fields: {sorted(fields)}")
    return ServingConfig(**kwargs)
